"""Benchmark harness entry point: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only table4]``
prints ``name,value,derived`` CSV lines (value in µs for timings).
"""
import argparse
import importlib
import sys
import time
import traceback

TABLES = [
    ("table1_memory", "benchmarks.table1_memory"),
    ("table3_throughput", "benchmarks.table3_throughput"),
    ("table4_auc", "benchmarks.table4_auc"),
    ("table5_feature_auc", "benchmarks.table5_feature_auc"),
    ("table6_scalability", "benchmarks.table6_scalability"),
    ("roofline_report", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    print("name,value,derived")
    for name, mod_name in TABLES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            for line in mod.run():
                print(line, flush=True)
            print(f"{name}/bench_wall_s,{(time.time()-t0)*1e6:.0f},",
                  flush=True)
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc().splitlines()[-1]}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
