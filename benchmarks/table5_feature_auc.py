"""Table V analogue: feature-engineering parity — embeddings feed a
downstream logistic-regression node-classification task (predict the
node's community from its embedding); GPU(ours) vs the CPU(LINE-style
per-pair SGD) implementation must agree within ~0.1% train / better eval."""
import jax
import numpy as np

from repro.core import HybridConfig, HybridEmbeddingTrainer, build_episode_blocks
from repro.graph.csr import build_csr
from benchmarks.common import collect_epoch_pairs


def _sbm_with_labels(n=2500, k=10, seed=0):
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, k, n)
    src, dst = [], []
    for _ in range(40):
        a = rng.integers(0, n, 30000)
        b = rng.integers(0, n, 30000)
        keep = rng.random(30000) < np.where(comm[a] == comm[b], 0.06, 0.001)
        src.append(a[keep]); dst.append(b[keep])
    g = build_csr(np.stack([np.concatenate(src), np.concatenate(dst)], 1), n)
    return g, comm


def _cpu_line_embedding(g, pairs_by_epoch, d=32, lr=0.025, seed=0):
    """LINE-style CPU reference: per-pair sequential SGD (the paper's Table V
    baseline is a CPU implementation of LINE [5])."""
    rng = np.random.default_rng(seed)
    V = (rng.random((g.num_nodes, d), dtype=np.float32) - 0.5) / d
    C = np.zeros((g.num_nodes, d), np.float32)
    w = np.maximum(g.degrees().astype(np.float64) ** 0.75, 1e-9)
    w /= w.sum()
    sig = lambda x: 1.0 / (1.0 + np.exp(-x))
    E = len(pairs_by_epoch)
    for epoch, pairs in enumerate(pairs_by_epoch):
        a = lr * max(1 - epoch / E, 0.05)
        negs = rng.choice(g.num_nodes, size=(len(pairs), 5), p=w)
        for (u, v), ns in zip(pairs, negs):
            vu = V[u].copy()
            gp = sig(vu @ C[v]) - 1
            dv = gp * C[v]
            C[v] -= a * gp * vu
            for nn in ns:
                gn = sig(vu @ C[nn])
                dv += gn * C[nn]
                C[nn] -= a * gn * vu
            V[u] -= a * dv
    return V


def _downstream_auc(V, labels, *, seed=0):
    """One-vs-rest logistic regression on a train/eval split; macro AUC."""
    from repro.core.eval import auc_score
    rng = np.random.default_rng(seed)
    n = V.shape[0]
    idx = rng.permutation(n)
    tr, te = idx[: n // 2], idx[n // 2:]
    Vn = V / (np.linalg.norm(V, axis=1, keepdims=True) + 1e-9)
    aucs_tr, aucs_te = [], []
    for c in range(labels.max() + 1):
        y = (labels == c).astype(np.float32)
        wvec = np.zeros(V.shape[1])
        b = 0.0
        for _ in range(200):  # simple full-batch logistic regression
            z = Vn[tr] @ wvec + b
            p = 1 / (1 + np.exp(-z))
            gw = Vn[tr].T @ (p - y[tr]) / len(tr)
            gb = float(np.mean(p - y[tr]))
            wvec -= 0.5 * gw
            b -= 0.5 * gb
        aucs_tr.append(auc_score((Vn[tr] @ wvec + b)[y[tr] == 1],
                                 (Vn[tr] @ wvec + b)[y[tr] == 0]))
        aucs_te.append(auc_score((Vn[te] @ wvec + b)[y[te] == 1],
                                 (Vn[te] @ wvec + b)[y[te] == 0]))
    return float(np.mean(aucs_tr)), float(np.mean(aucs_te))


def run(epochs: int = 8):
    g, labels = _sbm_with_labels()
    pairs_by_epoch = [collect_epoch_pairs(g, e)[0] for e in range(epochs)]

    cfg = HybridConfig(dim=32, minibatch=32, negatives=5, subparts=2,
                       neg_pool=2048, lr=0.025)
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    hy = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    hy.init_embeddings()
    for epoch, pairs in enumerate(pairs_by_epoch):
        eb = build_episode_blocks(pairs, hy.part, pad_multiple=32)
        hy.train_episode(eb, lr=cfg.lr * max(1 - epoch / epochs, 0.05))
    tr_g, te_g = _downstream_auc(hy.embeddings(), labels)

    V_cpu = _cpu_line_embedding(g, pairs_by_epoch)
    tr_c, te_c = _downstream_auc(V_cpu, labels)

    return [
        f"table5/gpu_style_train_auc,{tr_g:.5f},eval={te_g:.5f}",
        f"table5/cpu_line_train_auc,{tr_c:.5f},eval={te_c:.5f}",
        f"table5/eval_delta,{te_g-te_c:+.5f},paper_claims_parity_or_better",
    ]
