"""Table I analogue: memory-cost model of the system for the paper's
billion-node network, checked against our partitioned layout."""
from repro.configs.tencent_embedding import CONFIG
from repro.core.partition import NodePartition


def run():
    rows = []
    nodes = CONFIG.num_nodes
    edges = 300e9
    aug = edges * 10  # walk distance x context length (paper: E' ~ 3T)
    d = CONFIG.dim
    rows.append(("nodes", nodes, f"{nodes*4/2**30:.2f}GB(int32 ids)"))
    rows.append(("edges", edges, f"{edges*8/2**40:.2f}TB"))
    rows.append(("augmented_edges", aug, f"{aug*8/2**40:.2f}TB"))
    rows.append(("vertex_embeddings", nodes * d, f"{nodes*d*4/2**30:.1f}GB"))
    rows.append(("context_embeddings", nodes * d, f"{nodes*d*4/2**30:.1f}GB"))
    # per-device budget on the production mesh (16x16, k=4)
    part = NodePartition(nodes, dims=(16, 16), subparts=CONFIG.subparts)
    per_dev = part.padded_rows_per_shard * d * 4 * 2  # vert+ctx
    rows.append(("per_device_embeddings(256 chips)", part.padded_rows_per_shard,
                 f"{per_dev/2**30:.2f}GB"))
    out = []
    for name, size, storage in rows:
        out.append(f"table1_memory/{name},{size:.4g},{storage}")
    return out
