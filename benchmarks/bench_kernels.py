"""SGNS kernel microbenchmark: the per-PR performance trajectory.

Sweeps ``ops.sgns_step`` over (B, d, S, block_b) for every impl and APPENDS
a timestamped run to ``BENCH_kernels.json`` (so the roofline trajectory is
an actual trajectory across PRs) with rows/s, a bytes-moved model, and the
roofline bound from ``launch/roofline.py`` (see benchmarks/README.md for
the field reference). On this CPU container the Pallas impls run in
interpret mode — Python-slow, so their absolute numbers only track
*relative* regressions in kernel structure; the ``ref`` impl numbers and
the roofline bound are the meaningful trajectory. On TPU the same harness
measures the real thing.

    PYTHONPATH=src python benchmarks/bench_kernels.py          # full sweep
    PYTHONPATH=src python benchmarks/bench_kernels.py --smoke  # CI: 1 shape
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from common import append_run, load_runs                     # noqa: E402,F401
from repro.kernels import ops                                # noqa: E402
from repro.launch import roofline                            # noqa: E402

IMPLS = ops.STEP_IMPLS

# (B, d, S, block_b): shared-negative minibatch geometry. The first entry is
# the hybrid trainer's production SMALL config shape.
FULL_SHAPES = [
    (64, 96, 16, 64),
    (128, 128, 16, 64),
    (256, 128, 32, 128),
    (512, 256, 32, 128),
    # past the old (B, B) equality-matrix wall: pallas_fused2 runs the
    # sort-based segment-sum combine here (ops.plan_fused_update)
    (2048, 128, 16, 256),
]
SMOKE_SHAPES = [
    (32, 32, 8, 16),
    (48, 32, 8, 16),   # odd multiple: exercises multi-tile pipelining
    (64, 64, 8, 32),
    (64, 64, 16, 64),
]


def bytes_moved_model(B: int, d: int, S: int, itemsize: int,
                      impl: str) -> int:
    """HBM bytes for one sgns_step under each impl's execution structure.

    Row traffic per step: gathers read (2B + S) rows; the SGD apply reads
    and writes the same rows (scatter-add is read-modify-write). The
    non-fused impls additionally round-trip the (B,d) dv/dc and (S,d) dn
    gradient tensors and the gathered copies through HBM between kernels;
    pallas_fused keeps the gather+grads on-chip but still scatters from HBM
    gradient tensors; pallas_fused2 moves each row exactly once each way.
    """
    row = d * itemsize
    grad_row = d * 4  # grads are f32
    table_rw = (2 * B + S) * row * 2            # gather reads + apply writes
    if impl == "pallas_fused2":
        return table_rw                          # one round-trip per row
    grads = (2 * B + S) * grad_row * 2          # grads written then re-read
    if impl == "pallas_fused":
        return table_rw + grads + (2 * B + S) * row  # scatter re-reads rows
    gathered = (2 * B + S) * row * 2            # gathered copies out + in
    return table_rw + grads + gathered


def roofline_bound_rows_s(B: int, d: int, S: int, itemsize: int) -> float:
    """Memory-bound rows/s ceiling: the paper's O(1) arithmetic-intensity
    analysis says HBM bandwidth is the binding term, so the bound is the
    minimal traffic (fused2's one round-trip per row) at full HBM_BW."""
    min_bytes = bytes_moved_model(B, d, S, itemsize, "pallas_fused2")
    return B / (min_bytes / roofline.HBM_BW)


def time_step(impl: str, B: int, d: int, S: int, block_b: int,
              iters: int, dtype=jnp.float32) -> dict:
    Nv = Nc = max(4 * B, 256)
    key = jax.random.PRNGKey(0)
    vert = (jax.random.normal(key, (Nv, d)) * 0.1).astype(dtype)
    ctx = (jax.random.normal(jax.random.fold_in(key, 1), (Nc, d))
           * 0.1).astype(dtype)
    iv = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0, Nv)
    ic = jax.random.randint(jax.random.fold_in(key, 3), (B,), 0, Nc)
    inn = jax.random.randint(jax.random.fold_in(key, 4), (S,), 0, Nc)
    mask = jnp.ones(B)
    lr = jnp.float32(0.025)

    def step(v, c):
        return ops.sgns_step(v, c, iv, ic, inn, mask, lr, impl=impl,
                             block_b=block_b)

    vert, ctx, loss = step(vert, ctx)            # compile + warm up
    jax.block_until_ready((vert, ctx, loss))
    loss0 = float(loss)       # first-step loss: impl-parity canary (identical
    t0 = time.perf_counter()  # inputs across impls; timed iterates diverge)
    for _ in range(iters):
        vert, ctx, loss = step(vert, ctx)
    jax.block_until_ready((vert, ctx, loss))
    dt = (time.perf_counter() - t0) / iters
    itemsize = jnp.dtype(dtype).itemsize
    moved = bytes_moved_model(B, d, S, itemsize, impl)
    bound = roofline_bound_rows_s(B, d, S, itemsize)
    return {
        "impl": impl,
        "step_s": dt,
        "rows_per_s": B / dt,
        "bytes_moved_model": moved,
        "achieved_gbps_model": moved / dt / 1e9,
        "roofline_bound_rows_per_s": bound,
        "frac_of_roofline": (B / dt) / bound,
        "first_step_loss": loss0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / 1 iter (CI regression canary)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_kernels.json"))
    ap.add_argument("--impls", default=",".join(IMPLS))
    args = ap.parse_args()

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    interpret = jax.default_backend() != "tpu"
    # interpret-mode pallas is Python-slow: 1 timed iter is plenty there
    ref_iters = args.iters or (2 if args.smoke else 10)
    pallas_iters = args.iters or 1 if interpret else ref_iters

    impls = tuple(args.impls.split(","))
    results = []
    for (B, d, S, bb) in shapes:
        for impl in impls:
            iters = ref_iters if impl == "ref" else pallas_iters
            r = time_step(impl, B, d, S, bb, iters)
            r.update(B=B, d=d, S=S, block_b=bb)
            results.append(r)
            print(f"B={B:4d} d={d:4d} S={S:3d} bb={bb:4d} {impl:14s} "
                  f"{r['rows_per_s']:12.1f} rows/s   "
                  f"{r['frac_of_roofline']*100:8.4f}% of roofline")

    # cross-impl parity on the last shape: the benchmark itself verifies the
    # fused path's numerics so a silent kernel break can't post a fast number
    losses = {r["impl"]: r["first_step_loss"] for r in results
              if (r["B"], r["d"], r["S"]) == shapes[-1][:3]}
    if "ref" in losses:
        for impl, lv in losses.items():
            assert abs(lv - losses["ref"]) <= 1e-3 * max(1.0, abs(
                losses["ref"])), (impl, lv, losses["ref"])

    run = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "interpret_mode": interpret,
        "dtype": "float32",
        "hbm_bw_model_bytes_per_s": roofline.HBM_BW,
        "note": ("interpret-mode pallas timings are Python-bound; compare "
                 "ref timings and structural byte counts across PRs, and "
                 "absolute pallas timings only on TPU"),
        "results": results,
    }
    n = append_run(args.out, "sgns_kernels", run)
    print(f"wrote {os.path.abspath(args.out)} "
          f"(run {n}, {len(results)} rows)")


if __name__ == "__main__":
    main()
