"""Retrieval-serving benchmark: the per-PR serving trajectory.

Sweeps ``ShardedEmbeddingStore.topk`` over (N, d, k, batch) for the serving
impls — including the two-tier ``quant`` tier (int8 first pass + exact
rescore) — and APPENDS a timestamped run to ``BENCH_serve.json`` (same
runs[] layout as the kernel/episode trajectories; see benchmarks/README.md
for the field reference). Two measurements per shape:

* **direct** — store.topk latency on a fixed query batch (p50/p99 over
  iterations) plus a table-scan byte model against the HBM roofline: a
  batch must read every byte of whichever tier it scans once (the shards'
  ACTUAL dtype itemsize — int8 for the quant tier, plus its f32 scales),
  and the quant tier additionally gathers ``m`` full-precision rows per
  query for the rescore (``rescore_bytes_model``, accounted separately).
  floor = (scan + rescore bytes) / HBM_BW; ``frac_of_roofline`` is
  floor/measured, same as ``bench_kernels.py``.
* **batched** — a seeded open-loop burst through ``MicroBatcher``:
  achieved QPS, request-latency percentiles, and the realized mean batch.

Every row also records recall@k against the numpy oracle (exact kernels ⇒
1.0; anything less is a correctness regression posting a fast number). On
this CPU container the pallas impl runs in interpret mode (Python-slow, so
its timings only track structure) — the ``xla`` impl is the meaningful CPU
trajectory; on TPU the same harness measures the real kernel.

    PYTHONPATH=src python benchmarks/bench_serve.py          # full sweep
    PYTHONPATH=src python benchmarks/bench_serve.py --smoke  # CI canary
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402

from common import append_run                                # noqa: E402
from repro.embed_serve import (MicroBatcher, ShardedEmbeddingStore,  # noqa: E402
                               drive_open_loop, overfetch_m, recall_at_k)
from repro.embed_serve import topk as tk                     # noqa: E402
from repro.launch import roofline                            # noqa: E402

# "quant" routes through the two-tier scan (int8 kernel on TPU, int8 jnp
# path on CPU — same auto rule as pallas/xla); "tiered" puts the hot-row
# exact tier (25% budget, powerlaw-ranked) in front of a compacted int8
# cold remainder — hot hits skip quantization entirely, recall stays 1.0
IMPLS = ("xla", "pallas", "quant", "tiered")

TIERED_BUDGET_FRAC = 0.25

# (N, d, k, batch): table rows x dim, top-k, queries per request batch
FULL_SHAPES = [
    (4096, 64, 10, 16),
    (4096, 128, 10, 64),
    (16384, 128, 10, 64),
    (16384, 128, 100, 64),
]
SMOKE_SHAPES = [(512, 32, 10, 8)]


def scan_bytes_model(store: ShardedEmbeddingStore, batch: int, k: int,
                     impl: str) -> tuple[int, int]:
    """(scan bytes, rescore bytes) one query batch must move; the (Q, k)
    outputs are noise next to the scan.

    Scan: every byte of the scanned tier once per resident query block —
    the shards' ACTUAL dtype itemsize (f32/bf16 exact shards, or the int8
    shards + their f32 row scales for the quant tier; do not assume f32).
    The pallas-kernel paths hold topk.DEFAULT_BLOCK_Q queries resident and
    re-scan per block; the jnp paths materialize all scores in one pass.
    Rescore (quant only): the tier-two gather reads m = ceil(k * overfetch)
    full-precision rows per query from the exact shards."""
    if impl == "tiered":
        # exact hot rows + compacted int8 cold remainder (value + f32 scale)
        tier_bytes = store.hot_tier_stats()["scan_bytes_tiered"]
    elif impl.startswith("quant"):
        tier_bytes = sum(
            int(np.prod(q8.shape)) * q8.dtype.itemsize
            + int(np.prod(sc.shape)) * sc.dtype.itemsize
            for q8, sc in store.qshards)
    else:
        tier_bytes = sum(int(np.prod(sh.shape)) * sh.dtype.itemsize
                         for sh in store.shards)
    kernel_path = impl == "pallas" or (
        impl in ("tiered",) + tuple(i for i in IMPLS if i.startswith("quant"))
        and jax.default_backend() == "tpu")
    scans = (-(-batch // tk.DEFAULT_BLOCK_Q)) if kernel_path else 1
    rescore = 0
    itemsize = store.shards[0].dtype.itemsize
    d = store.dim
    if impl == "tiered":
        # only the cold (quant) tier rescores; hot hits are already exact
        for t in store.hot_tiers:
            if t.cold_valid == 0:
                continue
            m = overfetch_m(k, store.overfetch, t.cold_valid)
            rescore += batch * m * d * itemsize
    elif impl.startswith("quant"):
        for s, sh in enumerate(store.shards):
            if store.valid[s] == 0:
                continue
            m = overfetch_m(k, store.overfetch, store.valid[s])
            rescore += batch * m * d * itemsize
    return tier_bytes * scans, rescore


def bench_one(impl: str, N: int, d: int, k: int, batch: int, *,
              iters: int, requests: int, dtype: str, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    table = rng.normal(0, 0.1, size=(N, d)).astype(np.float32)
    quant = "int8" if (impl.startswith("quant")
                       or impl == "tiered") else None
    store = ShardedEmbeddingStore.from_array(table, dtype=dtype, quant=quant)
    hot_rows = None
    if impl == "tiered":
        # powerlaw access counts (zipf-1.3 traffic over the id space, the
        # training side's hot-row shape) rank the hot set; 25% budget
        traffic = np.minimum(rng.zipf(1.3, size=8 * N), N) - 1
        hot_rows = store.enable_hot_tier(
            int(TIERED_BUDGET_FRAC * N),
            counts=np.bincount(traffic, minlength=N).astype(np.float64))
    queries = table[rng.integers(0, N, size=batch)]

    # direct path: fixed-batch latency + scan-bytes roofline
    vals, ids = store.topk(queries, k, impl=impl)      # compile + warm up
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        store.topk(queries, k, impl=impl)
        times.append(time.perf_counter() - t0)
    times = np.sort(times)
    direct_s = float(np.percentile(times, 50))
    scan_bytes, rescore_bytes = scan_bytes_model(store, batch, k, impl)
    bound_s = (scan_bytes + rescore_bytes) / roofline.HBM_BW
    oracle_vals, oracle_ids = store.oracle_topk(queries, k)
    # tie tolerance from ground-truth rescoring, not the kernel's claims
    recall = recall_at_k(ids, oracle_ids,
                         got_vals=store.score_ids(queries, ids),
                         oracle_vals=oracle_vals)

    # batched path: seeded open-loop burst through the frontend.
    # fixed_batch pins the backend shape to max_batch (compiled above by
    # the direct-path warm-up), so no retrace lands in a request latency
    stream = table[rng.integers(0, N, size=requests)]
    batcher = MicroBatcher(lambda q: store.topk(q, k, impl=impl), d,
                           max_batch=batch, window_ms=2.0, fixed_batch=True)
    _, req_lat, wall = drive_open_loop(batcher, stream)
    batcher.close()

    extra = {}
    if impl == "tiered":
        st = store.hot_tier_stats()
        extra = {
            "hot_rows": hot_rows,
            "hot_budget_frac": hot_rows / N,
            "returned_hot_frac": st["returned_hot_frac"],
            "scan_bytes_tiered": st["scan_bytes_tiered"],
            "scan_bytes_quant": st["scan_bytes_quant"],
        }
    return {
        **extra,
        "impl": impl,
        "N": N,
        "d": d,
        "k": k,
        "batch": batch,
        "dtype": dtype,
        "quant": store.quant,
        "overfetch": store.overfetch if store.quant else None,
        "shards": len(store.shards),
        "direct_p50_s": direct_s,
        "direct_p99_s": float(np.percentile(times, 99)),
        "queries_per_s_direct": batch / direct_s,
        "scan_bytes_model": scan_bytes,
        "rescore_bytes_model": rescore_bytes,
        "roofline_bound_s": bound_s,
        "frac_of_roofline": bound_s / direct_s,
        "recall_at_k": recall,
        "batched_requests": requests,
        "batched_qps": requests / wall,
        "batched_p50_s": float(np.percentile(req_lat, 50)),
        "batched_p99_s": float(np.percentile(req_lat, 99)),
        "batched_mean_batch": batcher.stats_snapshot().mean_batch,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape / few iters (CI regression canary)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--impls", default=",".join(IMPLS))
    # f32 default like the other CPU trajectories (bf16 is emulated and
    # ~30x slower on CPU XLA); pass --dtype bfloat16 on TPU
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    args = ap.parse_args()

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    interpret = jax.default_backend() != "tpu"
    iters = args.iters or (2 if args.smoke else 5)
    requests = args.requests or (32 if args.smoke else 256)

    results = []
    for (N, d, k, batch) in shapes:
        for impl in args.impls.split(","):
            # interpret-mode pallas is Python-slow: keep its sweep light
            it = 1 if (impl == "pallas" and interpret) else iters
            req = min(requests, 4 * batch) if (impl == "pallas"
                                               and interpret) else requests
            r = bench_one(impl, N, d, k, batch, iters=it, requests=req,
                          dtype=args.dtype)
            results.append(r)
            print(f"N={N:6d} d={d:4d} k={k:4d} B={batch:4d} {impl:7s} "
                  f"direct p50 {r['direct_p50_s']*1e3:9.2f}ms "
                  f"({r['queries_per_s_direct']:9.1f} q/s, "
                  f"{r['frac_of_roofline']*100:8.4f}% of roofline) | "
                  f"batched {r['batched_qps']:9.1f} QPS | "
                  f"recall@{k} {r['recall_at_k']:.4f}")
            assert r["recall_at_k"] == 1.0, (
                "serving recall regression", impl, N, d, k)

    run = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "interpret_mode": interpret,
        "dtype": args.dtype,
        "hbm_bw_model_bytes_per_s": roofline.HBM_BW,
        "note": ("interpret-mode pallas timings are Python-bound; compare "
                 "xla timings and the scan-byte model across PRs, absolute "
                 "pallas timings only on TPU"),
        "results": results,
    }
    n = append_run(args.out, "embed_serve", run)
    print(f"wrote {os.path.abspath(args.out)} "
          f"(run {n}, {len(results)} rows)")


if __name__ == "__main__":
    main()
