"""Episode-level benchmark: whole-episode samples/sec through core.hybrid.

GraphVite and PyTorch-BigGraph both report bucket/episode throughput — not
single-kernel microbenchmarks — as the number that matters at scale, and the
paper's 3-minute epochs are an episode-level claim. This harness times
``HybridEmbeddingTrainer.train_episode`` (ring rotation + sub-part pipeline
+ minibatch scan, i.e. everything a production step runs) over a sweep of
(impl, minibatch B, dim d, mesh shape) and APPENDS a timestamped run to
``BENCH_episode.json``, so every future perf PR moves an end-to-end number.

On this CPU container the Pallas impls run in interpret mode and multi-
device meshes are XLA host devices — absolute numbers are only comparable
across PRs on the same container; on TPU the same harness measures the real
thing.

    PYTHONPATH=src python benchmarks/bench_episode.py          # full sweep
    PYTHONPATH=src python benchmarks/bench_episode.py --smoke  # CI canary
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

# mesh-shape sweeps need >1 device on the CPU container; must be set before
# the first jax import
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FLAG}=2")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402

from common import append_run                                # noqa: E402
from repro import obs                                        # noqa: E402
from repro.core import (EpisodePipeline, HybridConfig,          # noqa: E402
                        HybridEmbeddingTrainer, TieredEmbeddingTrainer,
                        build_episode_blocks)
from repro.graph.generators import powerlaw_graph            # noqa: E402
from repro.runtime import FaultPlan, clear_plan, install_plan  # noqa: E402
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine  # noqa: E402

IMPLS = ("ref", "pallas", "pallas_fused2")

# (B, d): shared-negative minibatch rows x embedding dim
FULL_SHAPES = [(64, 64), (64, 128), (128, 128)]
SMOKE_SHAPES = [(32, 32)]
MESHES = [(1, 1), (1, 2)]

# the end-to-end dataflow comparison (walks + build + stage + train) measures
# the host pipeline, not the kernels — one impl is enough
DATAFLOW_SHAPES = [(64, 64)]
DATAFLOW_SMOKE_SHAPES = [(32, 32)]

# tiered-cache comparison (resident vs stream vs hot-row cache): like the
# dataflow rows, this measures dataflow structure, not kernels — one shape
CACHE_SHAPES = [(64, 64)]
CACHE_SMOKE_SHAPES = [(32, 32)]
CACHE_BUDGET_FRAC = 0.25     # HBM rows per table, as a fraction of all rows


def bench_one(impl: str, B: int, d: int, mesh_shape, *, nodes: int,
              samples: int, episodes: int, dtype: str, seed: int = 0):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = HybridConfig(dim=d, minibatch=B, negatives=8, subparts=2,
                       neg_pool=2048, impl=impl, dtype=dtype, seed=seed)
    trainer = HybridEmbeddingTrainer(nodes, mesh, cfg)
    trainer.init_embeddings()
    rng = np.random.default_rng(seed)
    pairs = rng.integers(0, nodes, size=(samples, 2), dtype=np.int64)
    eb = build_episode_blocks(pairs, trainer.part, pad_multiple=B)
    n_samples = int(eb.counts.sum())
    trainer.train_episode(eb)            # compile + warm up
    t0 = time.perf_counter()
    loss = 0.0
    for _ in range(episodes):
        loss = trainer.train_episode(eb)  # float() inside = full sync
    dt = (time.perf_counter() - t0) / episodes
    return {
        "impl": impl,
        "B": B,
        "d": d,
        "mesh": list(mesh_shape),
        "episode_s": dt,
        "samples_per_episode": n_samples,
        "samples_per_s": n_samples / dt,
        "loss": loss,                     # cross-impl sanity signal
    }


def _overlap_efficiency(train_s, wall_s):
    """Fraction of the timed epoch spent in device training rather than
    stalled on host dataflow stages: 1.0 = walks / block builds / staging
    fully hidden behind training, lower = the consumer sat waiting on host
    work. Both quantities are measured INSIDE the timed window and the
    formula is identical for sync and streamed rows, so the number is
    comparable across modes (busy-second columns like walk_s can include
    work that ran ahead of the window and would over-credit a ratio built
    from them)."""
    if wall_s <= 0:
        return 1.0
    return max(0.0, min(1.0, train_s / wall_s))


def bench_dataflow(impl: str, B: int, d: int, mesh_shape, *, nodes: int,
                   episodes: int, walk_workers: int, depth: int,
                   dtype: str, seed: int = 0):
    """End-to-end epoch through the full dataflow, sync vs streamed.

    sync        — serial walks (workers=1), then per episode: build, stage,
                  train, all on the consumer thread (the pre-PR-5 path).
    streamed    — multi-worker walk engine putting episodes as they complete
                  into a bounded store, consumed through the multi-stage
                  EpisodePipeline (walk-wait -> build -> device staging)
                  while the trainer runs.
    faults_idle — the streamed path again (same warm-start structure, later
                  epochs) with an inert FaultPlan installed: every
                  walk.chunk / store.put fault point runs the full matcher
                  but no spec ever fires. Gates the idle overhead of the
                  fault-injection layer against the streamed row.
    remote_walkers — the same consumer pipeline fed by TWO subprocess walk
                  producers over the episode transport (framing + chunk
                  assembly + ordered delivery), the paper's CPU-machines-
                  feed-GPU-trainers deployment shape. The row records wire
                  traffic (msgs/s, bytes, resend rate) for the timed epoch;
                  the gate warns when transport-fed throughput falls more
                  than 15% below the in-process streamed row.
    coordinator_failover — the remote_walkers shape with one mid-epoch
                  coordinator kill + recovering restart on the same port:
                  measures what a takeover (store-scan queue rebuild +
                  producer reconnect backoff) costs end to end, plus the
                  successor's time to first applied chunk. Warns when the
                  interrupted epoch's throughput drops >20% below the
                  uninterrupted remote_walkers row.
    obs_idle    — the streamed path once more with the telemetry layer live
                  (metrics registry + in-memory span tracer, no file sinks):
                  every instrumented hot path pays its enabled cost. Gated
                  within 5% of the streamed row — observability that taxes
                  the pipeline it observes is not cheap enough to leave on.

    Both modes time epoch 2 (identical sample stream — the chunk
    decomposition and RNG keying are worker-count-invariant) with the same
    pinned block_cap, so they compile once and train identical blocks; any
    cap overflow drops the same pairs in both modes (reported as `dropped`).
    """
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = HybridConfig(dim=d, minibatch=B, negatives=8, subparts=2,
                       neg_pool=2048, impl=impl, dtype=dtype, seed=seed)
    g = powerlaw_graph(nodes, 5, seed=seed)
    trainer = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                     degrees=g.degrees())
    trainer.init_embeddings()

    def wcfg(workers):
        # node2vec walks (rejection-sampled 2nd-order steps) — the paper's
        # production mode and a walk stage with real cost to overlap
        return WalkConfig(walk_length=8, window=4, episodes=episodes,
                          seed=seed, workers=workers, walks_per_node=2,
                          node2vec_p=0.5, node2vec_q=2.0,
                          chunk_size=max(256, nodes // 8))

    # pre-pass on epoch 0: pin the block shape (headroom so the measured
    # epoch rarely drops; any overflow drops identically in both modes
    # and is reported) and warm up the compile cache
    store = MemorySampleStore()
    WalkEngine(g, wcfg(1), store).run_epoch(0)
    cap = 0
    for ep in range(episodes):
        eb = build_episode_blocks(np.asarray(store.get(0, ep)), trainer.part,
                                  pad_multiple=B)
        cap = max(cap, int(eb.counts.max()))
    cap += B                                     # headroom for later epochs
    warm = build_episode_blocks(np.asarray(store.get(0, 0)), trainer.part,
                                block_cap=cap, pad_multiple=B)
    trainer.train_episode(warm)
    store.drop_epoch(0)

    rows = []

    # ---- sync: everything on the consumer thread, walks first. Times
    # epoch 2 — the same epoch (same sample stream) the streamed mode times.
    eng = WalkEngine(g, wcfg(1), store)
    t0 = time.perf_counter()
    eng.run_epoch(2)
    walk_s = sum(eng.episode_walk_s.values())
    build_s = stage_s = train_s = 0.0
    n_samples = dropped = 0
    for ep in range(episodes):
        pairs = np.asarray(store.get(2, ep))
        t = time.perf_counter()
        eb = build_episode_blocks(pairs, trainer.part, block_cap=cap,
                                  pad_multiple=B)
        build_s += time.perf_counter() - t
        t = time.perf_counter()
        staged = trainer.stage_blocks(eb)
        stage_s += time.perf_counter() - t
        t = time.perf_counter()
        trainer.train_episode(staged)            # float(loss) = full sync
        train_s += time.perf_counter() - t
        n_samples += staged.num_samples
        dropped += eb.dropped
    wall_s = time.perf_counter() - t0
    store.drop_epoch(2)
    rows.append({
        "mode": "sync", "impl": impl, "B": B, "d": d,
        "mesh": list(mesh_shape), "episodes": episodes,
        "walk_workers": 1, "pipeline_depth": 0,
        "walk_s": walk_s, "walk_wait_s": walk_s, "build_s": build_s,
        "stage_s": stage_s, "train_s": train_s, "wall_s": wall_s,
        "samples_per_epoch": n_samples, "dropped": dropped,
        "samples_per_s": n_samples / wall_s,
        "overlap_efficiency": _overlap_efficiency(train_s, wall_s),
        "peak_resident_episodes": None,
    })

    # ---- streamed: bounded store, async multi-worker walks, staged pipeline.
    # Steady-state timing: epoch 1 fills the pipeline and (as in production —
    # the paper walks one epoch ahead) epoch 2's walker starts as soon as
    # epoch 1's finishes, so the timed epoch sees the dataflow a long-running
    # job sees, not the one-time cold-start fill.
    store = MemorySampleStore(depth=depth + 1)
    pipe = EpisodePipeline(store, trainer.part, pad_multiple=B,
                           block_cap=cap, depth=depth,
                           stage_fn=trainer.stage_blocks, drop_consumed=True)
    eng = WalkEngine(g, wcfg(walk_workers), store)
    eng.start_async(1)
    eng2 = None
    for ep in range(episodes):                  # warm epoch (untimed)
        pipe.prefetch_window(1, ep, episodes)
        trainer.train_episode(pipe.get(1, ep))
        if eng2 is None and eng.finished():
            eng.join()
            eng2 = WalkEngine(g, wcfg(walk_workers), store)
            eng2.start_async(2)
    eng.join()
    if eng2 is None:
        eng2 = WalkEngine(g, wcfg(walk_workers), store)
        eng2.start_async(2)
    store.drop_epoch(1)

    t0 = time.perf_counter()
    walk_wait_s = build_s = stage_s = train_s = 0.0
    n_samples = dropped = 0
    for ep in range(episodes):                  # timed steady-state epoch
        pipe.prefetch_window(2, ep, episodes)
        staged = pipe.get(2, ep)
        times = pipe.pop_times(2, ep)
        t = time.perf_counter()
        trainer.train_episode(staged)
        train_s += time.perf_counter() - t
        walk_wait_s += times.get("walk_wait_s", 0.0)
        build_s += times.get("build_s", 0.0)
        stage_s += times.get("stage_s", 0.0)
        n_samples += staged.num_samples
        dropped += staged.dropped
    wall_s = time.perf_counter() - t0
    eng2.join()
    walk_s = sum(t for (e, _), t in eng2.episode_walk_s.items() if e == 2)
    store.drop_epoch(2)
    rows.append({
        "mode": "streamed", "impl": impl, "B": B, "d": d,
        "mesh": list(mesh_shape), "episodes": episodes,
        "walk_workers": walk_workers, "pipeline_depth": depth,
        "walk_s": walk_s, "walk_wait_s": walk_wait_s, "build_s": build_s,
        "stage_s": stage_s, "train_s": train_s, "wall_s": wall_s,
        "samples_per_epoch": n_samples, "dropped": dropped,
        "samples_per_s": n_samples / wall_s,
        "overlap_efficiency": _overlap_efficiency(train_s, wall_s),
        "peak_resident_episodes": store.peak_resident,
    })

    # ---- faults_idle: the streamed epoch again (epochs 3 warm, 4 timed —
    # same warm-start structure as above) with an inert plan installed. The
    # `at` ordinals are unreachable, so every walk.chunk / store.put
    # fault_point takes the full locked matcher path and nothing fires —
    # this row is the idle cost of the fault layer the runtime docs promise
    # is free.
    plan = FaultPlan(["walk.chunk:crash:at=1000000000",
                      "store.put:crash:at=1000000000"])
    install_plan(plan)
    try:
        eng3 = WalkEngine(g, wcfg(walk_workers), store)
        eng3.start_async(3)
        eng4 = None
        for ep in range(episodes):              # warm epoch (untimed)
            pipe.prefetch_window(3, ep, episodes)
            trainer.train_episode(pipe.get(3, ep))
            if eng4 is None and eng3.finished():
                eng3.join()
                eng4 = WalkEngine(g, wcfg(walk_workers), store)
                eng4.start_async(4)
        eng3.join()
        if eng4 is None:
            eng4 = WalkEngine(g, wcfg(walk_workers), store)
            eng4.start_async(4)
        store.drop_epoch(3)

        t0 = time.perf_counter()
        walk_wait_s = build_s = stage_s = train_s = 0.0
        n_samples = dropped = 0
        for ep in range(episodes):              # timed epoch, plan live
            pipe.prefetch_window(4, ep, episodes)
            staged = pipe.get(4, ep)
            times = pipe.pop_times(4, ep)
            t = time.perf_counter()
            trainer.train_episode(staged)
            train_s += time.perf_counter() - t
            walk_wait_s += times.get("walk_wait_s", 0.0)
            build_s += times.get("build_s", 0.0)
            stage_s += times.get("stage_s", 0.0)
            n_samples += staged.num_samples
            dropped += staged.dropped
        wall_s = time.perf_counter() - t0
        eng4.join()
        walk_s = sum(t for (e, _), t in eng4.episode_walk_s.items() if e == 4)
        store.drop_epoch(4)
    finally:
        clear_plan()
    rows.append({
        "mode": "faults_idle", "impl": impl, "B": B, "d": d,
        "mesh": list(mesh_shape), "episodes": episodes,
        "walk_workers": walk_workers, "pipeline_depth": depth,
        "walk_s": walk_s, "walk_wait_s": walk_wait_s, "build_s": build_s,
        "stage_s": stage_s, "train_s": train_s, "wall_s": wall_s,
        "samples_per_epoch": n_samples, "dropped": dropped,
        "samples_per_s": n_samples / wall_s,
        "overlap_efficiency": _overlap_efficiency(train_s, wall_s),
        "peak_resident_episodes": store.peak_resident,
        "fault_points_checked": (plan.count("walk.chunk")
                                 + plan.count("store.put")),
    })

    # ---- remote_walkers: same consumer pipeline, episodes produced by two
    # subprocess producers over the transport (epochs 5 warm / 6 timed, the
    # usual steady-state structure: both epochs are submitted up front so
    # epoch 6 production starts the instant epoch 5 fully lands).
    from repro.walk import RemoteWalkCoordinator
    coord = RemoteWalkCoordinator(g, wcfg(1), store, num_producers=2,
                                  heartbeat_s=0.5, lease_s=30.0,
                                  mode="process")
    coord.start()
    try:
        h5, h6 = coord.epoch_walker(), coord.epoch_walker()
        h5.start_async(5)
        h6.start_async(6)
        for ep in range(episodes):                  # warm epoch (untimed)
            pipe.prefetch_window(5, ep, episodes)
            trainer.train_episode(pipe.get(5, ep))
        h5.join()
        store.drop_epoch(5)

        st_before = coord.transport_stats()
        t0 = time.perf_counter()
        walk_wait_s = build_s = stage_s = train_s = 0.0
        n_samples = dropped = 0
        for ep in range(episodes):                  # timed steady-state epoch
            pipe.prefetch_window(6, ep, episodes)
            staged = pipe.get(6, ep)
            times = pipe.pop_times(6, ep)
            t = time.perf_counter()
            trainer.train_episode(staged)
            train_s += time.perf_counter() - t
            walk_wait_s += times.get("walk_wait_s", 0.0)
            build_s += times.get("build_s", 0.0)
            stage_s += times.get("stage_s", 0.0)
            n_samples += staged.num_samples
            dropped += staged.dropped
        wall_s = time.perf_counter() - t0
        h6.join()
        st_after = coord.transport_stats()
        store.drop_epoch(6)
    finally:
        coord.close()
    msgs = ((st_after["frames_recv"] + st_after["frames_sent"])
            - (st_before["frames_recv"] + st_before["frames_sent"]))
    rows.append({
        "mode": "remote_walkers", "impl": impl, "B": B, "d": d,
        "mesh": list(mesh_shape), "episodes": episodes,
        "walk_workers": 2, "pipeline_depth": depth,
        # walks run inside the producer subprocesses: no in-process walk
        # seconds to report — walk_wait_s still measures what the consumer
        # actually stalled on
        "walk_s": 0.0, "walk_wait_s": walk_wait_s, "build_s": build_s,
        "stage_s": stage_s, "train_s": train_s, "wall_s": wall_s,
        "samples_per_epoch": n_samples, "dropped": dropped,
        "samples_per_s": n_samples / wall_s,
        "overlap_efficiency": _overlap_efficiency(train_s, wall_s),
        "peak_resident_episodes": store.peak_resident,
        "transport_msgs_per_s": msgs / wall_s,
        "transport_wire_bytes": (st_after["bytes_recv"]
                                 - st_before["bytes_recv"]),
        "transport_resend_rate": st_after["resend_rate"],
        "transport_dup_chunks": st_after["dup_chunks"],
    })

    # ---- obs_idle: the streamed epoch again (epochs 7 warm / 8 timed, same
    # warm-start structure) with the telemetry layer LIVE: registry installed,
    # in-memory tracer recording every span, no file sinks. Every walk chunk,
    # store put/get, pipeline stage and train episode takes its instrumented
    # path — this row is the enabled cost of the obs layer, gated against the
    # streamed baseline (the DISABLED cost is the zero-allocation test).
    reg = obs.enable()
    tr_obs = obs.Tracer()
    obs.set_tracer(tr_obs)
    try:
        eng7 = WalkEngine(g, wcfg(walk_workers), store)
        eng7.start_async(7)
        eng8 = None
        for ep in range(episodes):              # warm epoch (untimed)
            pipe.prefetch_window(7, ep, episodes)
            trainer.train_episode(pipe.get(7, ep))
            if eng8 is None and eng7.finished():
                eng7.join()
                eng8 = WalkEngine(g, wcfg(walk_workers), store)
                eng8.start_async(8)
        eng7.join()
        if eng8 is None:
            eng8 = WalkEngine(g, wcfg(walk_workers), store)
            eng8.start_async(8)
        store.drop_epoch(7)

        t0 = time.perf_counter()
        walk_wait_s = build_s = stage_s = train_s = 0.0
        n_samples = dropped = 0
        for ep in range(episodes):              # timed epoch, telemetry live
            pipe.prefetch_window(8, ep, episodes)
            staged = pipe.get(8, ep)
            times = pipe.pop_times(8, ep)
            t = time.perf_counter()
            trainer.train_episode(staged)
            train_s += time.perf_counter() - t
            walk_wait_s += times.get("walk_wait_s", 0.0)
            build_s += times.get("build_s", 0.0)
            stage_s += times.get("stage_s", 0.0)
            n_samples += staged.num_samples
            dropped += staged.dropped
        wall_s = time.perf_counter() - t0
        eng8.join()
        walk_s = sum(t for (e, _), t in eng8.episode_walk_s.items() if e == 8)
        store.drop_epoch(8)
        snap = reg.snapshot()
    finally:
        obs.set_tracer(None)
        obs.disable()
    rows.append({
        "mode": "obs_idle", "impl": impl, "B": B, "d": d,
        "mesh": list(mesh_shape), "episodes": episodes,
        "walk_workers": walk_workers, "pipeline_depth": depth,
        "walk_s": walk_s, "walk_wait_s": walk_wait_s, "build_s": build_s,
        "stage_s": stage_s, "train_s": train_s, "wall_s": wall_s,
        "samples_per_epoch": n_samples, "dropped": dropped,
        "samples_per_s": n_samples / wall_s,
        "overlap_efficiency": _overlap_efficiency(train_s, wall_s),
        "peak_resident_episodes": store.peak_resident,
        "obs_trace_events": tr_obs.event_count(),
        "obs_metric_names": (len(snap["counters"]) + len(snap["gauges"])
                             + len(snap["histograms"])),
    })

    # ---- coordinator_failover: the remote_walkers row under one mid-epoch
    # coordinator kill + takeover (epochs 9 warm / 10 timed). Right after
    # the first timed episode is consumed, the episode server is killed and
    # a recovering successor starts on the same port: it rebuilds the work
    # queue from the store while the subprocess producers ride out the
    # outage in their jittered backoff loops and reattach. The row records
    # end-to-end samples/s ACROSS the takeover, the takeover wall time, and
    # the successor's time to its first applied chunk — gated against the
    # remote_walkers row (warn when the restart costs >20% throughput).
    coord = RemoteWalkCoordinator(g, wcfg(1), store, num_producers=2,
                                  heartbeat_s=0.5, lease_s=30.0,
                                  mode="process", server_grace_s=60.0)
    coord.start()
    try:
        h9 = coord.epoch_walker()
        h9.start_async(9)
        for ep in range(episodes):                  # warm epoch (untimed)
            pipe.prefetch_window(9, ep, episodes)
            trainer.train_episode(pipe.get(9, ep))
        h9.join()
        store.drop_epoch(9)

        st_before = coord.transport_stats()
        t0 = time.perf_counter()
        # open the timed epoch and kill the coordinator the moment its
        # first chunks are in flight: the epoch is produced almost entirely
        # by the recovering successor, so first_chunk_s measures the real
        # reattach-and-produce recovery latency
        h10 = coord.epoch_walker()
        h10.start_async(10)
        takeover_s = coord.restart_server()
        walk_wait_s = build_s = stage_s = train_s = 0.0
        n_samples = dropped = 0
        for ep in range(episodes):                  # timed epoch + takeover
            pipe.prefetch_window(10, ep, episodes)
            staged = pipe.get(10, ep)
            times = pipe.pop_times(10, ep)
            t = time.perf_counter()
            trainer.train_episode(staged)
            train_s += time.perf_counter() - t
            walk_wait_s += times.get("walk_wait_s", 0.0)
            build_s += times.get("build_s", 0.0)
            stage_s += times.get("stage_s", 0.0)
            n_samples += staged.num_samples
            dropped += staged.dropped
        wall_s = time.perf_counter() - t0
        h10.join()
        st_after = coord.transport_stats()
        fo = coord.failover_stats()
        store.drop_epoch(10)
    finally:
        coord.close()
    pipe.close()
    rows.append({
        "mode": "coordinator_failover", "impl": impl, "B": B, "d": d,
        "mesh": list(mesh_shape), "episodes": episodes,
        "walk_workers": 2, "pipeline_depth": depth,
        "walk_s": 0.0, "walk_wait_s": walk_wait_s, "build_s": build_s,
        "stage_s": stage_s, "train_s": train_s, "wall_s": wall_s,
        "samples_per_epoch": n_samples, "dropped": dropped,
        "samples_per_s": n_samples / wall_s,
        "overlap_efficiency": _overlap_efficiency(train_s, wall_s),
        "peak_resident_episodes": store.peak_resident,
        "takeover_s": takeover_s,
        # None when every episode had already landed before the kill and
        # the successor had nothing left to produce
        "recovery_first_chunk_s": fo.get("first_chunk_s"),
        "failover_recovered_episodes": fo["recovered_episodes"],
        "transport_resend_rate": st_after["resend_rate"],
        "transport_dup_chunks": (st_after["dup_chunks"]
                                 - st_before["dup_chunks"]),
    })
    return rows


def bench_cache(B: int, d: int, mesh_shape, *, nodes: int, samples: int,
                episodes: int, dtype: str, budget_frac: float = CACHE_BUDGET_FRAC,
                seed: int = 0):
    """Tiered hot-row cache vs the fully-resident trainer (``core.tiered``).

    Three trainers run the SAME powerlaw episode schedule (zipf-1.3
    endpoints — the paper's hot-vertex traffic shape) from the same init:

    cache_resident — HybridEmbeddingTrainer, both tables fully in device
                     memory: the throughput ceiling the cache must chase.
    cache_stream   — TieredEmbeddingTrainer with ``hbm_rows=0``: every
                     block's working set streams host→device→host, the
                     bytes floor any cache must beat.
    cache_tiered   — TieredEmbeddingTrainer with ``hbm_rows`` =
                     ``budget_frac`` of the table rows (default 25%): hot
                     rows update in place in the HBM cache, cold rows
                     stream in/out around them.

    All three must produce bitwise-identical embeddings — asserted hard; a
    fast cache that trains different numbers is a correctness regression
    posting a speedup. Timing includes staging/plan/write-back host work
    (each mode pays its real per-episode cost). Gates (warnings): tiered
    hit_rate >= 0.8 on the powerlaw stream, tiered samples/s within 20% of
    resident, and the byte model must show the cache cut host<->device
    traffic vs budget-0 streaming.
    """
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = HybridConfig(dim=d, minibatch=B, negatives=8, subparts=2,
                       neg_pool=2048, impl="ref", dtype=dtype, seed=seed)
    rng = np.random.default_rng(seed)

    def zipf_ids(n):
        # rank-frequency powerlaw over the id space; out-of-range draws
        # clip to the last id (it just becomes one more hot node)
        return (np.minimum(rng.zipf(1.3, size=n), nodes) - 1).astype(np.int64)

    # two untimed warm episodes: the first compiles the block step, the
    # second absorbs the cold-start promotion wave (the cache fills from
    # empty) and its residency-op compiles — the timed episodes then
    # measure the steady state a long-running job sees
    warm = 2
    eps_pairs = [np.stack([zipf_ids(samples), zipf_ids(samples)], axis=1)
                 for _ in range(episodes + warm)]
    # negative pools follow the observed traffic skew (deg^0.75, as the
    # trainers build them) — identical degrees in every mode keeps the
    # negative streams, and therefore the bitwise gate, aligned
    deg = np.bincount(np.concatenate(eps_pairs).ravel(), minlength=nodes)
    budget = int(budget_frac * nodes)

    def run_mode(mode, hbm_rows):
        if hbm_rows is None:
            tr = HybridEmbeddingTrainer(nodes, mesh, cfg, degrees=deg)
        else:
            tr = TieredEmbeddingTrainer(nodes, mesh, cfg, degrees=deg,
                                        hbm_rows=hbm_rows)
        tr.init_embeddings()
        # pin one block shape across episodes so each mode compiles once
        ebs = [build_episode_blocks(p, tr.part, pad_multiple=B)
               for p in eps_pairs]
        cap = max(eb.block_cap for eb in ebs)
        ebs = [build_episode_blocks(p, tr.part, block_cap=cap,
                                    pad_multiple=B) for p in eps_pairs]
        for eb in ebs[:warm]:                # warm episodes: untimed
            tr.train_episode(eb)
        t0 = time.perf_counter()
        loss = 0.0
        for eb in ebs[warm:]:
            loss = tr.train_episode(eb)      # float() inside = full sync
        dt = time.perf_counter() - t0
        n_samples = sum(int(eb.counts.sum()) for eb in ebs[warm:])
        row = {
            "mode": mode, "impl": cfg.impl, "B": B, "d": d,
            "mesh": list(mesh_shape), "nodes": nodes,
            "episodes": episodes, "samples_per_epoch": n_samples // episodes,
            "hbm_rows": hbm_rows, "budget_frac": (None if hbm_rows is None
                                                  else hbm_rows / nodes),
            "samples_per_s": n_samples / dt, "loss": loss,
        }
        if hbm_rows is not None:
            st = tr.cache_stats()
            row.update(hit_rate=st["hit_rate"],
                       hbm_bytes_moved=st["hbm_bytes_moved"],
                       host_bytes_moved=st["host_bytes_moved"],
                       promotions=(st["vertex"]["promotions"]
                                   + st["context"]["promotions"]),
                       evictions=(st["vertex"]["evictions"]
                                  + st["context"]["evictions"]))
        return tr, row

    res_tr, res_row = run_mode("cache_resident", None)
    str_tr, str_row = run_mode("cache_stream", 0)
    tie_tr, tie_row = run_mode("cache_tiered", budget)

    # the load-bearing gate: same numbers, to the bit, in every mode
    v_ref = res_tr.embeddings().view(np.uint8)
    c_ref = res_tr.context_embeddings().view(np.uint8)
    for name, tr in (("cache_stream", str_tr), ("cache_tiered", tie_tr)):
        assert np.array_equal(v_ref, tr.embeddings().view(np.uint8)), (
            "tiered trainer diverged from resident (vertex)", name)
        assert np.array_equal(c_ref, tr.context_embeddings().view(np.uint8)), (
            "tiered trainer diverged from resident (context)", name)

    if tie_row["hit_rate"] < 0.8:
        print(f"WARNING: cache hit rate {tie_row['hit_rate']:.3f} < 0.8 at "
              f"budget {budget}/{nodes} rows under powerlaw traffic")
    if tie_row["samples_per_s"] < 0.8 * res_row["samples_per_s"]:
        print(f"WARNING: tiered throughput >20% below resident: "
              f"{tie_row['samples_per_s']:.1f} < "
              f"{res_row['samples_per_s']:.1f} samples/s")
    if tie_row["host_bytes_moved"] >= str_row["host_bytes_moved"]:
        print(f"WARNING: cache moved no fewer host<->device bytes than "
              f"budget-0 streaming: {tie_row['host_bytes_moved']} >= "
              f"{str_row['host_bytes_moved']}")
    return [res_row, str_row, tie_row]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single mesh (CI regression canary)")
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--nodes", type=int, default=None)
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--impls", default=",".join(IMPLS))
    # f32 default on purpose (NOT the HybridConfig bf16 default): CPU XLA
    # emulates bf16 ~30x slower, which would drown the structural
    # comparison this trajectory exists for; pass --dtype bfloat16 on TPU
    # where it's native
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    # leave a core for the trainer: extra walker threads on a small box just
    # thrash the GIL (the node2vec rejection loop is Python-heavy)
    ap.add_argument("--walk-workers", type=int,
                    default=max(1, min(4, (os.cpu_count() or 2) - 1)))
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--dataflow-episodes", type=int, default=None)
    ap.add_argument("--no-dataflow", action="store_true",
                    help="skip the sync-vs-streamed dataflow comparison")
    ap.add_argument("--no-cache", action="store_true",
                    help="skip the tiered-cache comparison")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_episode.json"))
    args = ap.parse_args()

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    meshes = MESHES[:1] if args.smoke else MESHES
    nodes = args.nodes or (512 if args.smoke else 2048)
    samples = args.samples or (512 if args.smoke else 8192)
    episodes = args.episodes or (1 if args.smoke else 2)
    impls = tuple(args.impls.split(","))

    results = []
    for mesh_shape in meshes:
        for (B, d) in shapes:
            for impl in impls:
                r = bench_one(impl, B, d, mesh_shape, nodes=nodes,
                              samples=samples, episodes=episodes,
                              dtype=args.dtype)
                results.append(r)
                print(f"mesh={mesh_shape} B={B:4d} d={d:4d} {impl:14s} "
                      f"{r['samples_per_s']:10.1f} samples/s   "
                      f"({r['episode_s']*1e3:8.1f} ms/episode)")

    # the episode-level claim every perf PR must not regress: the fully
    # fused update path at least matches the separate-kernels pallas path
    by_key = {}
    for r in results:
        by_key.setdefault((tuple(r["mesh"]), r["B"], r["d"]), {})[
            r["impl"]] = r["samples_per_s"]
    for key, v in by_key.items():
        if "pallas" in v and "pallas_fused2" in v:
            if v["pallas_fused2"] < v["pallas"]:
                print(f"WARNING: fused2 slower than pallas at {key}: "
                      f"{v['pallas_fused2']:.1f} < {v['pallas']:.1f}")

    # ---- end-to-end dataflow: sync vs streamed over the same epoch
    dataflow_results = []
    if not args.no_dataflow:
        df_shapes = DATAFLOW_SMOKE_SHAPES if args.smoke else DATAFLOW_SHAPES
        # 4+ episodes so the warm epoch is long enough for the next epoch's
        # walker to get ahead (2 episodes end before it even starts)
        df_eps = args.dataflow_episodes or 4
        # below ~2048 nodes an epoch is <100 ms and fixed thread overhead
        # drowns the structural comparison — keep the dataflow rows at a
        # scale where per-stage times mean something, even in smoke
        df_nodes = args.nodes or 2048
        for (B, d) in df_shapes:
            rows = bench_dataflow(
                "ref", B, d, MESHES[0], nodes=df_nodes, episodes=df_eps,
                walk_workers=args.walk_workers, depth=args.pipeline_depth,
                dtype=args.dtype)
            dataflow_results.extend(rows)
            for r in rows:
                print(f"dataflow B={r['B']:4d} d={r['d']:4d} "
                      f"{r['mode']:8s} {r['samples_per_s']:10.1f} samples/s  "
                      f"walk {r['walk_s']:.2f}s build {r['build_s']:.2f}s "
                      f"stage {r['stage_s']:.2f}s train {r['train_s']:.2f}s "
                      f"wall {r['wall_s']:.2f}s "
                      f"overlap {r['overlap_efficiency']:.2f}")
            by_mode = {r["mode"]: r["samples_per_s"] for r in rows}
            if by_mode.get("streamed", 0) < by_mode.get("sync", 0):
                print(f"WARNING: streamed slower than sync at "
                      f"B={B} d={d}: {by_mode['streamed']:.1f} < "
                      f"{by_mode['sync']:.1f}")
            # the robustness PR's perf gate: an installed-but-idle fault
            # plan must cost nothing visible against walk noise
            if by_mode.get("faults_idle", 0) < 0.9 * by_mode.get("streamed", 0):
                print(f"WARNING: idle fault layer costs >10% streamed "
                      f"throughput at B={B} d={d}: "
                      f"{by_mode['faults_idle']:.1f} < "
                      f"{by_mode['streamed']:.1f}")
            # transport gate: subprocess producers over the wire must hold
            # within 15% of in-process streamed throughput (the protocol +
            # assembly overhead budget; resends under chaos are separate)
            if (by_mode.get("remote_walkers", 0)
                    < 0.85 * by_mode.get("streamed", 0)):
                print(f"WARNING: remote-walker transport costs >15% "
                      f"streamed throughput at B={B} d={d}: "
                      f"{by_mode['remote_walkers']:.1f} < "
                      f"{by_mode['streamed']:.1f}")
            # telemetry gate: the fully-instrumented pipeline with the
            # registry + tracer live must hold within 5% of streamed
            if by_mode.get("obs_idle", 0) < 0.95 * by_mode.get("streamed", 0):
                print(f"WARNING: live telemetry costs >5% streamed "
                      f"throughput at B={B} d={d}: "
                      f"{by_mode['obs_idle']:.1f} < "
                      f"{by_mode['streamed']:.1f}")
            # failover gate: one coordinator kill + store-reconstructed
            # takeover mid-epoch must cost <20% of the uninterrupted
            # remote-walker throughput (producer backoff + queue rebuild).
            # Only meaningful when the epoch is long enough to amortize the
            # fixed reattach latency — at --smoke scale a ~0.1s epoch is
            # dominated by it and the ratio says nothing.
            by_wall = {r["mode"]: r["wall_s"] for r in rows}
            if (by_wall.get("remote_walkers", 0) >= 1.0
                    and by_mode.get("coordinator_failover", 0)
                    < 0.80 * by_mode.get("remote_walkers", 0)):
                print(f"WARNING: coordinator failover costs >20% "
                      f"remote-walker throughput at B={B} d={d}: "
                      f"{by_mode['coordinator_failover']:.1f} < "
                      f"{by_mode['remote_walkers']:.1f}")

    # ---- tiered cache: resident vs stream vs hot-row cache, bitwise-gated
    cache_results = []
    if not args.no_cache:
        c_shapes = CACHE_SMOKE_SHAPES if args.smoke else CACHE_SHAPES
        c_nodes = args.nodes or (512 if args.smoke else 2048)
        c_samples = args.samples or (1024 if args.smoke else 8192)
        c_eps = args.episodes or (2 if args.smoke else 3)
        for (B, d) in c_shapes:
            rows = bench_cache(B, d, MESHES[0], nodes=c_nodes,
                               samples=c_samples, episodes=c_eps,
                               dtype=args.dtype)
            cache_results.extend(rows)
            for r in rows:
                extra = ""
                if r["hbm_rows"] is not None:
                    extra = (f"  hit_rate {r['hit_rate']:.3f} "
                             f"hbm_bytes {r['hbm_bytes_moved']} "
                             f"host_bytes {r['host_bytes_moved']}")
                print(f"cache    B={r['B']:4d} d={r['d']:4d} "
                      f"{r['mode']:14s} {r['samples_per_s']:10.1f} "
                      f"samples/s{extra}")

    run = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "dtype": args.dtype,
        "nodes": nodes,
        "samples": samples,
        "episodes_timed": episodes,
        "note": ("end-to-end episode step (ring rotation + sub-part "
                 "pipeline + minibatch scan); interpret-mode pallas on "
                 "CPU — compare across PRs on the same container, "
                 "absolute numbers on TPU"),
        "results": results,
        "dataflow_results": dataflow_results,
        "cache_results": cache_results,
    }
    n = append_run(args.out, "sgns_episode", run)
    print(f"wrote {os.path.abspath(args.out)} "
          f"(run {n}, {len(results)} rows)")


if __name__ == "__main__":
    main()
