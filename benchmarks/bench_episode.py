"""Episode-level benchmark: whole-episode samples/sec through core.hybrid.

GraphVite and PyTorch-BigGraph both report bucket/episode throughput — not
single-kernel microbenchmarks — as the number that matters at scale, and the
paper's 3-minute epochs are an episode-level claim. This harness times
``HybridEmbeddingTrainer.train_episode`` (ring rotation + sub-part pipeline
+ minibatch scan, i.e. everything a production step runs) over a sweep of
(impl, minibatch B, dim d, mesh shape) and APPENDS a timestamped run to
``BENCH_episode.json``, so every future perf PR moves an end-to-end number.

On this CPU container the Pallas impls run in interpret mode and multi-
device meshes are XLA host devices — absolute numbers are only comparable
across PRs on the same container; on TPU the same harness measures the real
thing.

    PYTHONPATH=src python benchmarks/bench_episode.py          # full sweep
    PYTHONPATH=src python benchmarks/bench_episode.py --smoke  # CI canary
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

# mesh-shape sweeps need >1 device on the CPU container; must be set before
# the first jax import
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FLAG}=2")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402

from common import append_run                                # noqa: E402
from repro.core import (HybridConfig, HybridEmbeddingTrainer,   # noqa: E402
                        build_episode_blocks)

IMPLS = ("ref", "pallas", "pallas_fused2")

# (B, d): shared-negative minibatch rows x embedding dim
FULL_SHAPES = [(64, 64), (64, 128), (128, 128)]
SMOKE_SHAPES = [(32, 32)]
MESHES = [(1, 1), (1, 2)]


def bench_one(impl: str, B: int, d: int, mesh_shape, *, nodes: int,
              samples: int, episodes: int, dtype: str, seed: int = 0):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg = HybridConfig(dim=d, minibatch=B, negatives=8, subparts=2,
                       neg_pool=2048, impl=impl, dtype=dtype, seed=seed)
    trainer = HybridEmbeddingTrainer(nodes, mesh, cfg)
    trainer.init_embeddings()
    rng = np.random.default_rng(seed)
    pairs = rng.integers(0, nodes, size=(samples, 2), dtype=np.int64)
    eb = build_episode_blocks(pairs, trainer.part, pad_multiple=B)
    n_samples = int(eb.counts.sum())
    trainer.train_episode(eb)            # compile + warm up
    t0 = time.perf_counter()
    loss = 0.0
    for _ in range(episodes):
        loss = trainer.train_episode(eb)  # float() inside = full sync
    dt = (time.perf_counter() - t0) / episodes
    return {
        "impl": impl,
        "B": B,
        "d": d,
        "mesh": list(mesh_shape),
        "episode_s": dt,
        "samples_per_episode": n_samples,
        "samples_per_s": n_samples / dt,
        "loss": loss,                     # cross-impl sanity signal
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single mesh (CI regression canary)")
    ap.add_argument("--episodes", type=int, default=None)
    ap.add_argument("--nodes", type=int, default=None)
    ap.add_argument("--samples", type=int, default=None)
    ap.add_argument("--impls", default=",".join(IMPLS))
    # f32 default on purpose (NOT the HybridConfig bf16 default): CPU XLA
    # emulates bf16 ~30x slower, which would drown the structural
    # comparison this trajectory exists for; pass --dtype bfloat16 on TPU
    # where it's native
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_episode.json"))
    args = ap.parse_args()

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    meshes = MESHES[:1] if args.smoke else MESHES
    nodes = args.nodes or (512 if args.smoke else 2048)
    samples = args.samples or (512 if args.smoke else 8192)
    episodes = args.episodes or (1 if args.smoke else 2)
    impls = tuple(args.impls.split(","))

    results = []
    for mesh_shape in meshes:
        for (B, d) in shapes:
            for impl in impls:
                r = bench_one(impl, B, d, mesh_shape, nodes=nodes,
                              samples=samples, episodes=episodes,
                              dtype=args.dtype)
                results.append(r)
                print(f"mesh={mesh_shape} B={B:4d} d={d:4d} {impl:14s} "
                      f"{r['samples_per_s']:10.1f} samples/s   "
                      f"({r['episode_s']*1e3:8.1f} ms/episode)")

    # the episode-level claim every perf PR must not regress: the fully
    # fused update path at least matches the separate-kernels pallas path
    by_key = {}
    for r in results:
        by_key.setdefault((tuple(r["mesh"]), r["B"], r["d"]), {})[
            r["impl"]] = r["samples_per_s"]
    for key, v in by_key.items():
        if "pallas" in v and "pallas_fused2" in v:
            if v["pallas_fused2"] < v["pallas"]:
                print(f"WARNING: fused2 slower than pallas at {key}: "
                      f"{v['pallas_fused2']:.1f} < {v['pallas']:.1f}")

    run = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "dtype": args.dtype,
        "nodes": nodes,
        "samples": samples,
        "episodes_timed": episodes,
        "note": ("end-to-end episode step (ring rotation + sub-part "
                 "pipeline + minibatch scan); interpret-mode pallas on "
                 "CPU — compare across PRs on the same container, "
                 "absolute numbers on TPU"),
        "results": results,
    }
    n = append_run(args.out, "sgns_episode", run)
    print(f"wrote {os.path.abspath(args.out)} "
          f"(run {n}, {len(results)} rows)")


if __name__ == "__main__":
    main()
