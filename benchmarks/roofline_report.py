"""Render the §Dry-run / §Roofline tables from the dry-run JSON artifacts
(deliverable g). Not a timing benchmark: numbers come from compiled HLO."""
import json
import os

from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS

EXP_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments")


def load(path):
    p = os.path.join(EXP_DIR, path)
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def run():
    out = []
    for fname, tag in (("dryrun_single.json", "1pod"),
                       ("dryrun_multipod.json", "2pod")):
        recs = load(fname)
        ok = [r for r in recs if "error" not in r]
        out.append(f"roofline/{tag}_pass,{len(ok)},of={len(recs)}")
        for r in ok:
            t = r["roofline"]
            dom = t["dominant"]
            out.append(
                f"roofline/{tag}/{r['arch']}/{r['shape']},"
                f"{t[dom + '_s'] * 1e3:.2f},"
                f"dom={dom};c={t['compute_s']*1e3:.2f}ms;"
                f"m={t['memory_s']*1e3:.2f}ms;x={t['collective_s']*1e3:.2f}ms;"
                f"useful={r['useful_flops_ratio']:.3f};"
                f"peak_gib={r['memory']['peak_bytes']/2**30:.1f}")
    return out
