"""Tables VI/VII + Fig. 6/7 analogue: intra-node scalability at 1/2/4/8
devices, ours vs the parameter-server baseline.

Device counts require fresh XLA processes (device count is locked at first
jax init), so each point runs in a subprocess with
--xla_force_host_platform_device_count=N. On one physical CPU core the
*compute* cannot speed up; what the benchmark shows is the per-device-count
dispatch/communication structure (ours: one jitted episode; PS baseline:
4*n^2*k host round-trips per epoch) and the paper's schedule invariance.
"""
import json
import os
import subprocess
import sys

_WORKER = r"""
import json, sys, time
import numpy as np, jax
from repro.core import HybridConfig, HybridEmbeddingTrainer, ParameterServerTrainer
from benchmarks.common import sbm_graph, time_epochs
n_dev = jax.device_count()
g = sbm_graph(n=2000, rounds=30)
cfg = HybridConfig(dim=64, minibatch=64, negatives=5, subparts=2,
                   neg_pool=2048, lr=0.025)
mesh = jax.make_mesh((1, n_dev), ('data', 'model'))
hy = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
hy.init_embeddings()
t_h, _ = time_epochs(hy, g, cfg, epochs=2)
ps = ParameterServerTrainer(g.num_nodes, n_dev, cfg, degrees=g.degrees())
t_p, _ = time_epochs(ps, g, cfg, epochs=2)
print(json.dumps({"devices": n_dev, "ours_s": t_h, "ps_s": t_p,
                  "ps_host_syncs": ps.counters.host_syncs}))
"""


def run():
    out = []
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for n in (1, 2, 4, 8):
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
                   PYTHONPATH=os.path.join(repo, "src") + ":" + repo)
        r = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                           capture_output=True, text=True, timeout=900)
        if r.returncode != 0:
            out.append(f"table6/devices{n},ERROR,{r.stderr.splitlines()[-1][:120]}")
            continue
        rec = json.loads(r.stdout.strip().splitlines()[-1])
        out.append(f"table6/ours_{n}dev_epoch_s,{rec['ours_s']*1e6:.0f},")
        out.append(f"table6/ps_{n}dev_epoch_s,{rec['ps_s']*1e6:.0f},"
                   f"host_syncs={rec['ps_host_syncs']}")
        out.append(f"table6/speedup_{n}dev,{rec['ps_s']/rec['ours_s']:.3f},")
    return out
