"""Table IV / Fig. 5 analogue: link-prediction AUC over epochs, ours vs the
parameter-server baseline with identical training settings (the paper keeps
GraphVite's settings; we keep the baseline's)."""
import jax
import numpy as np

from repro.core import (HybridConfig, HybridEmbeddingTrainer,
                        ParameterServerTrainer, build_episode_blocks)
from repro.core import eval as ev
from repro.graph.csr import build_csr
from benchmarks.common import collect_epoch_pairs, sbm_graph, vv_auc


def run(epochs: int = 15):
    g_full = sbm_graph(n=3000, rounds=40)
    train_e, test_e = ev.split_edges(g_full, 0.05, seed=1)
    g = build_csr(train_e, g_full.num_nodes, symmetrize=False, dedup=False)
    neg_e = ev.sample_negative_pairs(g_full, len(test_e), seed=3)
    cfg = HybridConfig(dim=64, minibatch=32, negatives=8, subparts=2,
                       neg_pool=2048, lr=0.025)

    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    hy = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    hy.init_embeddings()
    ps = ParameterServerTrainer(g.num_nodes, 1, cfg, degrees=g.degrees())

    curves = {"ours": [], "graphvite_ps": []}
    for epoch in range(epochs):
        lr = cfg.lr * max(1 - epoch / epochs, 0.05)
        for pairs in collect_epoch_pairs(g, epoch):
            eb_h = build_episode_blocks(pairs, hy.part, pad_multiple=32)
            hy.train_episode(eb_h, lr=lr)
            eb_p = build_episode_blocks(pairs, ps.part, pad_multiple=32)
            ps.train_episode(eb_p, lr=lr)
        curves["ours"].append(vv_auc(hy.embeddings(), test_e, neg_e))
        curves["graphvite_ps"].append(vv_auc(ps.embeddings(), test_e, neg_e))

    out = []
    for name, c in curves.items():
        out.append(f"table4/{name}_final_auc,{c[-1]:.4f},"
                   f"best={max(c):.4f}@ep{int(np.argmax(c))}")
    out.append(f"table4/auc_delta,{curves['ours'][-1]-curves['graphvite_ps'][-1]:.4f},"
               "paper_claims_competitive_or_better")
    return out
