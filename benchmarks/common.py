"""Shared benchmark scaffolding: graph fixtures + trainer drivers.

All throughput numbers on this CPU container are RELATIVE (ours vs the
GraphVite-style parameter-server baseline at identical device counts); the
paper's absolute V100 numbers are out of reach by construction and are not
claimed. Structural counters (host syncs, bytes staged through host) are
reported alongside, since they are what scales the gap on real hardware.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (HybridConfig, HybridEmbeddingTrainer,
                        ParameterServerTrainer, build_episode_blocks)
from repro.core import eval as ev
from repro.graph.csr import CSRGraph, build_csr
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


# ---------------------------------------------------------------------------
# trajectory files: every BENCH_*.json holds {"benchmark": ..., "runs": [...]}
# and every benchmark invocation APPENDS a timestamped run, so the numbers
# form an actual across-PR trajectory. All three bench harnesses (kernels,
# episode, serve) share this machinery.
# ---------------------------------------------------------------------------
def load_runs(path: str) -> list:
    """Existing runs from a trajectory file; migrates the PR-1 era
    single-run layout (top-level 'results') into runs[0]."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            old = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    if isinstance(old, dict) and isinstance(old.get("runs"), list):
        return old["runs"]
    if isinstance(old, dict) and "results" in old:   # legacy single run
        old.pop("benchmark", None)
        old.setdefault("timestamp", None)
        old.setdefault("smoke", False)
        return [old]
    return []


def append_run(path: str, benchmark: str, run: dict) -> int:
    """Append one timestamped run to a trajectory file; returns run count."""
    runs = load_runs(path)
    runs.append(run)
    with open(path, "w") as f:
        json.dump({"benchmark": benchmark, "runs": runs}, f, indent=2)
    return len(runs)


def sbm_graph(n=3000, k=20, seed=0, rounds=40, batch=40000):
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, k, n)
    src, dst = [], []
    for _ in range(rounds):
        a = rng.integers(0, n, batch)
        b = rng.integers(0, n, batch)
        keep = rng.random(batch) < np.where(comm[a] == comm[b], 0.05, 0.0008)
        src.append(a[keep]); dst.append(b[keep])
    return build_csr(np.stack([np.concatenate(src), np.concatenate(dst)], 1), n)


def collect_epoch_pairs(g: CSRGraph, epoch: int, *, episodes=1, walk_length=10,
                        window=5):
    store = MemorySampleStore()
    WalkEngine(g, WalkConfig(walk_length=walk_length, window=window,
                             episodes=episodes, seed=epoch),
               store).run_epoch(epoch)
    return [np.asarray(store.get(epoch, e)) for e in range(episodes)]


def time_epochs(trainer, g: CSRGraph, cfg: HybridConfig, epochs: int,
                *, warmup: int = 1):
    """Returns (mean epoch seconds, last loss). warmup epochs excluded."""
    times, loss = [], float("nan")
    for epoch in range(epochs + warmup):
        pairs_list = collect_epoch_pairs(g, epoch)
        t0 = time.perf_counter()
        for pairs in pairs_list:
            eb = build_episode_blocks(pairs, trainer.part,
                                      pad_multiple=cfg.minibatch)
            loss = trainer.train_episode(
                eb, lr=cfg.lr * max(1 - epoch / (epochs + warmup), 0.05))
        if epoch >= warmup:
            times.append(time.perf_counter() - t0)
    return float(np.mean(times)), loss


def vv_auc(V, test_e, neg_e):
    Vn = V / (np.linalg.norm(V, axis=1, keepdims=True) + 1e-9)
    return ev.auc_score(
        np.einsum("ij,ij->i", Vn[test_e[:, 0]], Vn[test_e[:, 1]]),
        np.einsum("ij,ij->i", Vn[neg_e[:, 0]], Vn[neg_e[:, 1]]))
