"""Table III analogue: per-epoch training time, ours vs the GraphVite-style
parameter-server baseline, on one device (CPU) — relative speedup +
structural counters. Multi-device scaling is table6."""
import time

from repro.core import HybridConfig, HybridEmbeddingTrainer, ParameterServerTrainer
from benchmarks.common import sbm_graph, time_epochs


def run():
    g = sbm_graph(n=4000, rounds=60)
    cfg = HybridConfig(dim=96, minibatch=64, negatives=5, subparts=2,
                       neg_pool=4096, lr=0.025)
    out = []

    hy = HybridEmbeddingTrainer(g.num_nodes, _mesh(), cfg,
                                degrees=g.degrees())
    hy.init_embeddings()
    t_h, loss_h = time_epochs(hy, g, cfg, epochs=3)

    ps = ParameterServerTrainer(g.num_nodes, 1, cfg, degrees=g.degrees())
    t_p, loss_p = time_epochs(ps, g, cfg, epochs=3)

    out.append(f"table3/ours_epoch_s,{t_h*1e6:.0f},loss={loss_h:.3f}")
    out.append(f"table3/graphvite_ps_epoch_s,{t_p*1e6:.0f},loss={loss_p:.3f}")
    out.append(f"table3/speedup,{t_p/t_h:.3f},edges={g.num_edges}")
    out.append(f"table3/ps_host_syncs,{ps.counters.host_syncs},"
               f"bytes_through_host={ps.counters.bytes_through_host}")
    return out


def _mesh():
    import jax
    return jax.make_mesh((1, jax.device_count()), ("data", "model"))
