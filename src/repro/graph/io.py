"""Edge-list I/O for the walk engine (CPU side)."""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, build_csr


def load_edge_list(path: str, num_nodes: int | None = None, **kw) -> CSRGraph:
    """Load a whitespace-separated `src dst` text file or an .npy (m,2) array."""
    if path.endswith(".npy"):
        edges = np.load(path)
    else:
        edges = np.loadtxt(path, dtype=np.int64, ndmin=2)
    if num_nodes is None:
        num_nodes = int(edges.max()) + 1
    return build_csr(edges, num_nodes, **kw)


def save_edge_list(graph: CSRGraph, path: str) -> None:
    np.save(path, graph.edge_list())
