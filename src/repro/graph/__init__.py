from repro.graph.csr import CSRGraph, build_csr
from repro.graph.generators import (rmat_graph, powerlaw_graph, mesh_graph,
                                    sbm_graph)

__all__ = ["CSRGraph", "build_csr", "rmat_graph", "powerlaw_graph",
           "mesh_graph", "sbm_graph"]
