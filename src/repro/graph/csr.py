"""CSR graph structure.

The walk engine is a CPU component (paper §IV-A): graphs live in host memory
as numpy CSR. Edges are directed internally; undirected graphs are stored
with both directions.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Compressed-sparse-row adjacency. indptr: (n+1,) int64, indices: (m,) int32/int64."""

    indptr: np.ndarray
    indices: np.ndarray

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def edge_list(self) -> np.ndarray:
        """(m, 2) array of (src, dst)."""
        src = np.repeat(np.arange(self.num_nodes, dtype=self.indices.dtype), self.degrees())
        return np.stack([src, self.indices], axis=1)

    def save(self, path: str) -> None:
        np.savez_compressed(path, indptr=self.indptr, indices=self.indices)

    @staticmethod
    def load(path: str) -> "CSRGraph":
        with np.load(path) as f:
            return CSRGraph(indptr=f["indptr"], indices=f["indices"])


def build_csr(edges: np.ndarray, num_nodes: int, *, symmetrize: bool = True,
              dedup: bool = True) -> CSRGraph:
    """Build a CSR graph from an (m, 2) edge array."""
    edges = np.asarray(edges)
    if edges.size == 0:
        return CSRGraph(np.zeros(num_nodes + 1, np.int64), np.zeros(0, np.int32))
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    # drop self loops
    edges = edges[edges[:, 0] != edges[:, 1]]
    if dedup:
        key = edges[:, 0].astype(np.int64) * num_nodes + edges[:, 1].astype(np.int64)
        _, idx = np.unique(key, return_index=True)
        edges = edges[idx]
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    edges = edges[order]
    counts = np.bincount(edges[:, 0], minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=edges[:, 1].astype(np.int32))
