"""Synthetic graph generators mirroring the paper's benchmark networks.

* :func:`rmat_graph`      — Kronecker/R-MAT scale-free graph ("kron" in Table II).
* :func:`mesh_graph`      — uniform-degree 2D mesh ("delaunay"-like topology).
* :func:`powerlaw_graph`  — preferential-attachment social-network-like graph
  (the "generated A/B/C" family: "resemble the topology of real-world social
  networks").
* :func:`sbm_graph`       — stochastic block model with planted communities;
  the topology behind the paper's link-prediction AUC claims (Table IV) —
  held-out edges are predictable from learned embeddings, which makes it the
  graph to use when an AUC number has to MEAN something (CI sanity gates).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, build_csr


def rmat_graph(scale: int, edge_factor: int = 16, *, a: float = 0.57, b: float = 0.19,
               c: float = 0.19, seed: int = 0) -> CSRGraph:
    """R-MAT generator: 2**scale nodes, edge_factor * n edges (pre-dedup)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = r >= a + b  # dst high bit
        go_down = ((r >= a) & (r < a + b)) | (r >= a + b + c)  # src high bit... see below
        # standard R-MAT: quadrants (0,0)=a (0,1)=b (1,0)=c (1,1)=d
        q_b = (r >= a) & (r < a + b)
        q_c = (r >= a + b) & (r < a + b + c)
        q_d = r >= a + b + c
        src |= ((q_c | q_d).astype(np.int64)) << level
        dst |= ((q_b | q_d).astype(np.int64)) << level
        del go_right, go_down
    edges = np.stack([src, dst], axis=1)
    return build_csr(edges, n)


def mesh_graph(side: int) -> CSRGraph:
    """side*side 2D grid — uniform degree distribution (delaunay-like)."""
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).ravel()
    right = vid[(jj < side - 1).ravel()]
    down = vid[(ii < side - 1).ravel()]
    edges = np.concatenate(
        [np.stack([right, right + 1], 1), np.stack([down, down + side], 1)], axis=0
    )
    return build_csr(edges, n)


def powerlaw_graph(n: int, m_per_node: int = 4, *, seed: int = 0) -> CSRGraph:
    """Barabási–Albert-style preferential attachment (vectorized approximation).

    Matches the skewed degree distribution of the paper's social networks.
    """
    rng = np.random.default_rng(seed)
    n0 = max(m_per_node + 1, 4)
    src_list = [np.repeat(np.arange(n0), n0 - 1)]
    dst0 = np.concatenate([np.delete(np.arange(n0), i) for i in range(n0)])
    dst_list = [dst0]
    # repeated-nodes trick: sample targets from the flat edge endpoint list
    endpoint_pool = [np.concatenate([src_list[0], dst_list[0]])]
    pool_size = endpoint_pool[0].size
    batch = max(1024, n // 64)
    v = n0
    while v < n:
        nb = min(batch, n - v)
        new_src = np.repeat(np.arange(v, v + nb), m_per_node)
        pool = np.concatenate(endpoint_pool)
        targets = pool[rng.integers(0, pool.size, size=nb * m_per_node)]
        # attach (approximate: pool not updated within the batch)
        src_list.append(new_src)
        dst_list.append(targets)
        endpoint_pool.append(np.concatenate([new_src, targets]))
        pool_size += 2 * nb * m_per_node
        v += nb
    edges = np.stack([np.concatenate(src_list), np.concatenate(dst_list)], axis=1)
    return build_csr(edges, n)


def sbm_graph(n: int, communities: int = 12, *, p_in: float = 0.08,
              p_out: float = 0.001, rounds: int = 30, batch: int = 20000,
              seed: int = 0) -> CSRGraph:
    """Stochastic block model: `communities` planted groups, intra-community
    edges kept with `p_in`, cross-community with `p_out` (rejection-sampled
    in `rounds` batches of `batch` candidate pairs, so expected edges scale
    with rounds·batch rather than n²)."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, communities, n)
    src, dst = [], []
    for _ in range(rounds):
        a = rng.integers(0, n, batch)
        b = rng.integers(0, n, batch)
        keep = rng.random(batch) < np.where(comm[a] == comm[b], p_in, p_out)
        src.append(a[keep])
        dst.append(b[keep])
    edges = np.stack([np.concatenate(src), np.concatenate(dst)], axis=1)
    return build_csr(edges, n)
