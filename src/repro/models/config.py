"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / MLA / SSM / hybrid / enc-dec / VLM /
audio stacks; per-arch files in `repro.configs` instantiate it with the exact
assigned hyperparameters. Reduced variants (for CPU smoke tests) come from
:meth:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio | embedding
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5

    # ---- MoE ----
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    moe_num_shared: int = 0           # deepseek shared experts
    moe_layer_start: int = 0          # first MoE layer (deepseek: 3 dense first)
    moe_layer_period: int = 1         # jamba: MoE every 2nd layer
    moe_capacity_factor: float = 1.25

    # ---- MLA (deepseek) ----
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False                 # multi-token-prediction extra head

    # ---- SSM / hybrid ----
    layer_pattern: str = ""           # per-period layer types, e.g. "AMMMMMMM"
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128              # SSD chunk length

    # ---- enc-dec / modality ----
    encoder_layers: int = 0           # >0 -> encoder-decoder
    modality: str = "text"            # text | vision | audio
    frontend_len_cap: int = 8192      # stubbed frontends cap their seq length

    # ---- serving / long-context ----
    sliding_window: int = 0           # >0 -> windowed attention (sub-quadratic)

    # ---- distribution (filled in by launch/steps for the active mesh) ----
    tp_size: int = 1                  # size of the "model" axis

    # ---- numerics / memory policy ----
    param_dtype: str = "float32"      # smoke tests; dry-run overrides to bf16
    compute_dtype: str = "float32"
    optimizer: str = "adamw"          # adamw | adafactor | sgd
    remat: bool = True
    train_microbatches: int = 1       # grad-accumulation splits per step
    prefill_chunk: int = 0            # 0 -> whole-seq prefill

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    def layer_types(self) -> list[str]:
        """Per-layer mixer type: 'A' attention or 'M' mamba."""
        if not self.layer_pattern:
            return ["M" if self.arch_type == "ssm" else "A"] * self.num_layers
        pat = self.layer_pattern
        return [pat[i % len(pat)] for i in range(self.num_layers)]

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        return i >= self.moe_layer_start and \
            (i - self.moe_layer_start) % self.moe_layer_period == 0

    def reduced(self, *, layers: int = 2, d_model: int = 256,
                experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (spec: 2 layers,
        d_model<=512, <=4 experts)."""
        heads = max(2, min(self.num_heads, d_model // 64))
        kv = heads if self.num_kv_heads == self.num_heads else max(1, heads // 2)
        changes = dict(
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64,
            d_ff=2 * d_model,
            vocab_size=min(self.vocab_size, 1024),
            train_microbatches=1,
            prefill_chunk=0,
            frontend_len_cap=256,
        )
        if self.moe_num_experts:
            changes.update(
                moe_num_experts=min(self.moe_num_experts, experts),
                moe_top_k=min(self.moe_top_k, 2),
                moe_d_ff=d_model,
                moe_layer_start=min(self.moe_layer_start, 1),
            )
        if self.mla:
            changes.update(q_lora_rank=min(self.q_lora_rank, 128) or 0,
                           kv_lora_rank=128, qk_nope_head_dim=64,
                           qk_rope_head_dim=32, v_head_dim=64)
        if self.ssm_state:
            changes.update(ssm_state=min(self.ssm_state, 32), ssm_head_dim=32,
                           ssm_chunk=32)
        if self.encoder_layers:
            changes.update(encoder_layers=layers)
        if self.layer_pattern:
            # keep the hybrid mix visible even at 2 layers: one of each
            changes.update(layer_pattern="AM"[:layers] if layers <= 2 else
                           self.layer_pattern)
        if self.sliding_window:
            changes.update(sliding_window=min(self.sliding_window, 64))
        return dataclasses.replace(self, **changes)
