"""Unified decoder / encoder-decoder stack covering all assigned archs.

Layers are grouped into **segments** of identical structure; each segment is
a `lax.scan` over stacked per-layer params (bounded HLO + compile time even
for 61-64-layer archs), with the within-period slots unrolled:

  * dense/moe/ssm archs: one segment, period 1;
  * jamba: one segment, period 8 ("AMMMMMMM" mixers, MoE every 2nd layer);
  * deepseek: two segments (3 dense layers, then 58 MoE layers).

Three entry points:
  * :func:`forward_train`    — full-seq forward + LM loss (+ MoE aux, MTP).
  * :func:`prefill`          — chunked-prefill building decode caches.
  * :func:`decode_step`      — one-token serve step against the caches.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mamba as ssm
from repro.models import mlp
from repro.models.common import embed_init, rms_norm
from repro.models.config import ModelConfig


# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Segment:
    groups: int                      # scan length
    sig: tuple                       # per-slot (ltype, is_moe)


def segments_of(cfg: ModelConfig, num_layers: int | None = None,
                layer_offset: int = 0) -> list[Segment]:
    L = num_layers if num_layers is not None else cfg.num_layers
    types = cfg.layer_types()
    sigs = [(types[layer_offset + i], cfg.is_moe_layer(layer_offset + i))
            for i in range(L)]
    for p in range(1, min(16, L) + 1):
        # p == L would be a full unroll; prefer run-splitting instead
        if (p < L or L == 1) and L % p == 0 and \
                all(sigs[i] == sigs[i % p] for i in range(L)):
            return [Segment(groups=L // p, sig=tuple(sigs[:p]))]
    # fall back to maximal constant runs (deepseek: 3 dense + 58 moe)
    segs, i = [], 0
    while i < L:
        j = i
        while j < L and sigs[j] == sigs[i]:
            j += 1
        segs.append(Segment(groups=j - i, sig=(sigs[i],)))
        i = j
    return segs


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _init_slot(key, ltype: str, is_moe: bool, cfg: ModelConfig,
               cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if ltype == "A":
        p["attn"] = attn.init_attention_params(ks[0], cfg)
    else:
        p["mixer"] = ssm.init_mamba_params(ks[0], cfg)
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), dt)
        p["cross"] = attn.init_attention_params(ks[1], cfg, cross=True)
    if cfg.d_ff > 0 or is_moe:
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        if is_moe:
            p["moe"] = mlp.init_moe_params(ks[2], cfg)
        else:
            p["ffn"] = mlp.init_ffn_params(ks[2], cfg.d_model, cfg.d_ff, dt)
    return p


def _init_segment(key, seg: Segment, cfg: ModelConfig, cross: bool) -> dict:
    """Stacked params: tree with leading `groups` dim per slot."""
    slots = []
    for s, (ltype, is_moe) in enumerate(seg.sig):
        gk = jax.random.split(jax.random.fold_in(key, s), seg.groups)
        per_group = [_init_slot(gk[g], ltype, is_moe, cfg, cross)
                     for g in range(seg.groups)]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
    return {"slots": slots}


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "segments": [
            _init_segment(jax.random.fold_in(ks[1], i), seg, cfg,
                          cross=cfg.is_encdec)
            for i, seg in enumerate(segments_of(cfg))
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[2], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(cfg, mla=False)
        params["enc_segments"] = [
            _init_segment(jax.random.fold_in(ks[3], i), seg, enc_cfg,
                          cross=False)
            for i, seg in enumerate(segments_of(cfg, cfg.encoder_layers))
        ]
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    if cfg.mtp:
        params["mtp_proj"] = embed_init(ks[4], (2 * cfg.d_model, cfg.d_model), dt)
        params["mtp_layer"] = _init_slot(ks[5], "A", False, cfg)
        params["mtp_norm"] = jnp.ones((cfg.d_model,), dt)
    return params


# --------------------------------------------------------------------------
# layer body
# --------------------------------------------------------------------------
def _layer_fwd(p, x, ltype, is_moe, cfg: ModelConfig, *, mesh=None,
               data_axes=("data",), enc_out=None, cross=False,
               positions=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if ltype == "A":
        h = attn.attention_forward(p["attn"], h, cfg, mesh=mesh,
                                   positions=positions)
    else:
        h = ssm.mamba_forward(p["mixer"], h, cfg)
    x = x + h
    if cross:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + attn.attention_forward(p["cross"], hx, cfg, enc_out=enc_out,
                                       mesh=mesh)
    aux = jnp.float32(0.0)
    if "moe" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, aux = mlp.moe_forward(p["moe"], h2, cfg, mesh=mesh,
                                 data_axes=data_axes)
        x = x + y
    elif "ffn" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp.ffn_forward(p["ffn"], h2)
    return x, aux


def _run_segments(segments_params, segs, x, cfg: ModelConfig, *, mesh=None,
                  data_axes=("data",), enc_out=None, cross=False,
                  positions=None):
    aux_total = jnp.float32(0.0)

    for seg_p, seg in zip(segments_params, segs):
        def body(carry, slot_params, seg=seg):
            x, aux = carry
            for s, (ltype, is_moe) in enumerate(seg.sig):
                fwd = functools.partial(
                    _layer_fwd, ltype=ltype, is_moe=is_moe, cfg=cfg,
                    mesh=mesh, data_axes=data_axes, enc_out=enc_out,
                    cross=cross, positions=positions)
                if cfg.remat:
                    fwd = jax.checkpoint(fwd)
                x, a = fwd(slot_params[s], x)
                aux = aux + a
            return (x, aux), None

        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), seg_p["slots"])
    return x, aux_total


# --------------------------------------------------------------------------
# embedding / heads (vocab-sharded; the paper-technique tie-in)
# --------------------------------------------------------------------------
def _embed_tokens(params, tokens, cfg: ModelConfig):
    return jnp.take(params["embed"], tokens, axis=0)


def _lm_logits(params, x, cfg: ModelConfig):
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)


def softmax_xent(logits, labels, mask):
    """logits (B,S,V) f32, labels (B,S) int32, mask (B,S)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------
def _encoder_forward(params, frames, cfg: ModelConfig, *, mesh=None,
                     data_axes=("data",)):
    segs = segments_of(cfg, cfg.encoder_layers)
    x, aux = _run_segments(params["enc_segments"], segs, frames, cfg,
                           mesh=mesh, data_axes=data_axes)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps), aux


def forward_train(params, batch: dict, cfg: ModelConfig, *, mesh=None,
                  data_axes=("data",)):
    """Returns (loss, metrics). batch keys:
      tokens (B,S) int32 [all archs];
      patch_embeds (B,P,d) [vlm: prepended to the token stream];
      frames (B,Se,d) [audio enc-dec: encoder input].
    Loss: next-token xent on the token positions (+0.01*aux +0.3*mtp)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens, cfg)
    enc_out, aux_enc, prefix = None, 0.0, 0
    if cfg.modality == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        prefix = pe.shape[1]
    if cfg.is_encdec:
        enc_out, aux_enc = _encoder_forward(
            params, batch["frames"].astype(x.dtype), cfg, mesh=mesh,
            data_axes=data_axes)

    segs = segments_of(cfg)
    # runtime positions (when the batch provides them) keep the causal masks
    # out of XLA's constant/"wide" hoisting — EXPERIMENTS.md §Perf "runtime
    # positions". Prefix (VLM) streams extend them on the left.
    positions = batch.get("positions")
    if positions is not None and x.shape[1] != positions.shape[1]:
        pre = x.shape[1] - positions.shape[1]
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(pre), (x.shape[0], pre)),
             positions + pre], axis=1)
    x, aux = _run_segments(params["segments"], segs, x, cfg, mesh=mesh,
                           data_axes=data_axes, enc_out=enc_out,
                           cross=cfg.is_encdec, positions=positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if prefix:
        x = x[:, prefix:]
    logits = _lm_logits(params, x, cfg)
    logits = jax.lax.with_sharding_constraint(
        logits, P(data_axes, None, "model")) if mesh is not None else logits
    labels = tokens[:, 1:]
    mask = batch.get("loss_mask", jnp.ones_like(tokens, jnp.float32))[:, 1:]
    loss = softmax_xent(logits[:, :-1], labels, mask)
    metrics = {"xent": loss, "aux": aux + aux_enc}
    loss = loss + 0.01 * (aux + aux_enc)

    if cfg.mtp:  # DeepSeek multi-token prediction: predict t+2 as well
        h = x[:, :-2]
        nxt = _embed_tokens(params, tokens[:, 1:-1], cfg)
        hm = jnp.einsum("bsd,dk->bsk",
                        jnp.concatenate([h, nxt], axis=-1).astype(x.dtype),
                        params["mtp_proj"])
        hm, _ = _layer_fwd(params["mtp_layer"], hm, "A", False, cfg,
                           mesh=mesh, data_axes=data_axes)
        hm = rms_norm(hm, params["mtp_norm"], cfg.norm_eps)
        mtp_logits = _lm_logits(params, hm, cfg)
        mtp_loss = softmax_xent(mtp_logits, tokens[:, 2:], mask[:, 1:])
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss

    return loss, metrics


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------
def _layer_extend(p, x, cache, ltype, cfg: ModelConfig, *, mesh=None,
                  data_axes=("data",), cross=False):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if ltype == "A":
        h, cache = attn.attention_extend(p["attn"], h, cache, cfg, mesh=mesh)
    else:
        h, st, tail = ssm.mamba_forward(p["mixer"], h, cfg,
                                        init_state=cache["state"],
                                        conv_init=cache["conv"],
                                        return_state=True)
        cache = dict(cache, state=st, conv=tail.astype(cache["conv"].dtype))
    x = x + h
    if cross:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        cx, _ = attn.attention_decode(p["cross"], hx, cache, cfg, cross=True)
        x = x + cx
    if "moe" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = mlp.moe_forward(p["moe"], h2, cfg, mesh=mesh,
                               data_axes=data_axes)
        x = x + y
    elif "ffn" in p:
        x = x + mlp.ffn_forward(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, cache


def _layer_decode(p, x1, cache, ltype, cfg: ModelConfig, *, mesh=None,
                  data_axes=("data",), cross=False):
    h = rms_norm(x1, p["ln1"], cfg.norm_eps)
    if ltype == "A":
        h, cache = attn.attention_decode(p["attn"], h, cache, cfg)
    else:
        h, cache = ssm.mamba_decode(p["mixer"], h, cache, cfg)
    x1 = x1 + h
    if cross:
        hx = rms_norm(x1, p["ln_x"], cfg.norm_eps)
        cx, _ = attn.attention_decode(p["cross"], hx, cache, cfg, cross=True)
        x1 = x1 + cx
    if "moe" in p:
        h2 = rms_norm(x1, p["ln2"], cfg.norm_eps)
        y, _ = mlp.moe_forward(p["moe"], h2, cfg, mesh=mesh,
                               data_axes=data_axes)
        x1 = x1 + y
    elif "ffn" in p:
        x1 = x1 + mlp.ffn_forward(p["ffn"], rms_norm(x1, p["ln2"], cfg.norm_eps))
    return x1, cache


def init_caches(params, cfg: ModelConfig, batch: int, cache_len: int,
                *, enc_out=None):
    """Per-segment stacked caches matching the scan layout. For enc-dec,
    per-layer cross k/v are projected from ``enc_out`` once and cached."""
    caches = []
    for seg_p, seg in zip(params["segments"], segments_of(cfg)):
        slot_caches = []
        for s, (ltype, _) in enumerate(seg.sig):
            if ltype == "A":
                one = attn.init_cache(cfg, batch, cache_len)
            else:
                one = ssm.init_mamba_cache(cfg, batch)
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.groups, *x.shape)), one)
            if cfg.is_encdec and enc_out is not None:
                ek, ev = jax.vmap(attn.cross_kv, in_axes=(0, None))(
                    seg_p["slots"][s]["cross"], enc_out)
                stacked["enc_k"] = ek
                stacked["enc_v"] = ev
            slot_caches.append(stacked)
        caches.append(slot_caches)
    return caches


def _run_segments_cached(params, x, caches, cfg: ModelConfig, layer_step, *,
                         mesh=None, data_axes=("data",)):
    """Shared scan driver for prefill-extend and decode: group-major layer
    order (matching `_run_segments`), caches threaded as scan xs/ys."""
    segs = segments_of(cfg)
    new_caches = []
    for seg_p, seg, seg_cache in zip(params["segments"], segs, caches):
        def body(x, inp, seg=seg):
            slot_params, slot_caches = inp
            outs = []
            for s, (ltype, is_moe) in enumerate(seg.sig):
                x, c = layer_step(slot_params[s], x, slot_caches[s], ltype)
                outs.append(c)
            return x, tuple(outs)

        x, upd = jax.lax.scan(body, x,
                              (tuple(seg_p["slots"]), tuple(seg_cache)))
        new_caches.append(list(upd))
    return x, new_caches


def extend_chunk(params, x, caches, cfg: ModelConfig, *, mesh=None,
                 data_axes=("data",)):
    """Run one chunk of tokens through all layers, updating caches."""
    def step(p, x, c, ltype):
        return _layer_extend(p, x, c, ltype, cfg, mesh=mesh,
                             data_axes=data_axes, cross=cfg.is_encdec)
    return _run_segments_cached(params, x, caches, cfg, step, mesh=mesh,
                                data_axes=data_axes)


def prefill(params, batch: dict, cfg: ModelConfig, cache_len: int, *,
            mesh=None, data_axes=("data",)):
    """Chunked prefill. Returns (last-token logits, caches)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    if cfg.modality == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    enc_out = None
    if cfg.is_encdec:
        enc_out, _ = _encoder_forward(params, batch["frames"].astype(x.dtype),
                                      cfg, mesh=mesh, data_axes=data_axes)
    caches = init_caches(params, cfg, B, cache_len, enc_out=enc_out)
    Sx = x.shape[1]
    chunk = cfg.prefill_chunk or Sx
    if Sx % chunk != 0:
        raise ValueError(f"prefill length {Sx} not divisible by chunk {chunk}")
    n = Sx // chunk
    if n == 1:
        x, caches = extend_chunk(params, x, caches, cfg, mesh=mesh,
                                 data_axes=data_axes)
        h_last = x[:, -1:]
    else:
        # scan over chunks: caches are the carry, HLO stays one-chunk-sized
        xc = x.reshape(B, n, chunk, -1).swapaxes(0, 1)

        def chunk_body(caches, xi):
            xi, caches = extend_chunk(params, xi, caches, cfg, mesh=mesh,
                                      data_axes=data_axes)
            return caches, xi[:, -1:]

        caches, lasts = jax.lax.scan(chunk_body, caches, xc)
        h_last = lasts[-1]
    h_last = rms_norm(h_last, params["final_norm"], cfg.norm_eps)
    return _lm_logits(params, h_last, cfg), caches


def decode_step(params, token1, caches, cfg: ModelConfig, *, mesh=None,
                data_axes=("data",)):
    """One serve step: token1 (B,1) int32 -> (logits (B,1,V), caches)."""
    x = _embed_tokens(params, token1, cfg)

    def step(p, x, c, ltype):
        return _layer_decode(p, x, c, ltype, cfg, mesh=mesh,
                             data_axes=data_axes, cross=cfg.is_encdec)

    x, new_caches = _run_segments_cached(params, x, caches, cfg, step,
                                         mesh=mesh, data_axes=data_axes)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _lm_logits(params, x, cfg), new_caches
