from repro.models.config import ModelConfig
from repro.models import transformer, attention, mamba, mlp, common

__all__ = ["ModelConfig", "transformer", "attention", "mamba", "mlp", "common"]
