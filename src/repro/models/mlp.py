"""FFN layers: gated dense MLP and expert-parallel MoE.

The MoE layer is the architecture-pool feature closest to the paper's
technique: like the paper's 2D-partitioned embedding blocks, tokens are
data-parallel while the expert weights are model-parallel, and the exchange
that pairs them is an explicit collective (all-to-all here, ring ppermute in
the paper). It is implemented as a `shard_map` island over the ``"model"``
axis with capacity-based dispatch:

  1. split the local sequence over "model" (token slicing),
  2. route: top-k over router logits,
  3. bucket tokens by destination shard (rank-via-cumsum), pad to capacity,
  4. `all_to_all` over "model",
  5. bucket received tokens by local expert, batched expert matmuls (MXU),
  6. reverse `all_to_all`, weighted combine.

Overflowing tokens are dropped (standard capacity semantics); the router's
load-balance auxiliary loss (Switch-style) keeps drops rare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init
from repro.models.config import ModelConfig
from repro.sharding import compat


# --------------------------------------------------------------------------
# dense gated FFN
# --------------------------------------------------------------------------
def init_ffn_params(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), in_axis=0, dtype=dtype),
    }


def ffn_forward(params, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def init_moe_params(key, cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, ff), dtype=dt),
        "w_up": dense_init(ks[2], (E, d, ff), dtype=dt),
        "w_down": dense_init(ks[3], (E, ff, d), in_axis=1, dtype=dt),
    }
    if cfg.moe_num_shared:
        p["shared"] = init_ffn_params(ks[4], d, cfg.moe_d_ff * cfg.moe_num_shared, dt)
    return p


def _rank_in_group(group_ids: jax.Array, num_groups: int) -> jax.Array:
    """rank of each element within its group (stable, 0-based). (R,) int32."""
    onehot = jax.nn.one_hot(group_ids, num_groups, dtype=jnp.int32)  # (R, G)
    ranks = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(ranks, group_ids[:, None], axis=1)[:, 0]


def moe_ref(params, x, cfg: ModelConfig):
    """Dense oracle: every expert computes every token, gated combine.
    Used by tests and by single-device smoke runs."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.moe_top_k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    y_all = jnp.einsum("bsef,efd->bsed", h, params["w_down"])
    # dense gate tensor from top-k
    gates = jnp.zeros(probs.shape, jnp.float32)
    b_idx = jnp.arange(probs.shape[0])[:, None, None]
    s_idx = jnp.arange(probs.shape[1])[None, :, None]
    gates = gates.at[b_idx, s_idx, topi].set(topw)
    y = jnp.einsum("bsed,bse->bsd", y_all.astype(jnp.float32), gates)
    aux = _aux_loss(probs, gates, cfg)
    return y.astype(x.dtype), aux


def _aux_loss(probs, gates, cfg: ModelConfig):
    """Switch-style load balance: E * Σ_e f_e · p̄_e."""
    E = cfg.moe_num_experts
    frac = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1))  # (E,)
    pbar = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(frac * pbar)


def _device_moe(params, x, cfg: ModelConfig, ep_axes, ep_sizes,
                quota: int | None = None):
    """Per-device body of the expert-parallel MoE (inside shard_map).

    x: (B_loc, S_slice, d) — this device's token slice. If the sequence is
    too short to slice over "model" (decode), x arrives replicated and
    ``quota`` assigns each rank a disjoint token range instead.
    params["w_*"]: (E_loc, ...) — this device's experts.
    ep_sizes: static mesh extents of ``ep_axes`` (jax.lax.axis_size is
    missing on older jax, and these must be python ints anyway).
    """
    M = 1
    for n in ep_sizes:
        M *= n
    m_idx = compat.axis_flat_index(ep_axes, ep_sizes)
    E_loc = params["w_gate"].shape[0]
    E = E_loc * M
    k = cfg.moe_top_k
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                    # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    R = T * k
    flat_e = topi.reshape(R)                                 # global expert ids
    flat_w = topw.reshape(R)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    if quota is not None:
        # replicated-token mode: rank m owns tokens [m*quota, (m+1)*quota)
        mine = (flat_tok >= m_idx * quota) & (flat_tok < (m_idx + 1) * quota)
        flat_e = jnp.where(mine, flat_e, E)  # E = invalid -> dropped

    # ---- bucket by destination model shard, pad to send capacity ----
    # floor of 8 (MXU sublane), NOT a large round number: with 256
    # destinations a floor of 64 quadruples both the all-to-all payload and
    # the expert matmul padding (Perf B.3)
    dest = jnp.minimum(flat_e // E_loc, M)                   # (R,) M = drop
    cap_send = max(8, int(-(-(quota * k if quota else R) // M)
                          * cfg.moe_capacity_factor))
    rank_d = _rank_in_group(dest, M + 1)   # spare group M = dropped rows
    ok = (rank_d < cap_send) & (dest < M)
    send_x = jnp.zeros((M, cap_send, d), x.dtype)
    send_e = jnp.full((M, cap_send), -1, jnp.int32)          # local expert id
    # mode="drop": overflowing ranks fall off the buffer instead of clipping
    send_x = send_x.at[dest, rank_d].set(xt[flat_tok], mode="drop")
    send_e = send_e.at[dest, rank_d].set(flat_e % E_loc, mode="drop")

    recv_x = jax.lax.all_to_all(send_x, ep_axes, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, ep_axes, 0, 0, tiled=False)

    # ---- bucket received rows by local expert ----
    Rr = M * cap_send
    re = recv_e.reshape(Rr)
    rx = recv_x.reshape(Rr, d)
    valid = re >= 0
    re_safe = jnp.where(valid, re, 0)
    # cap_send already carries the capacity factor; compounding it here
    # would pad the expert matmuls by factor^2 (Perf B.3)
    cap_e = max(8, -(-Rr // E_loc))
    # rank within local expert; invalid rows are counted in their own spare
    # group (E_loc) so they neither consume real capacity nor shift ranks
    rank_e = _rank_in_group(jnp.where(valid, re, E_loc), E_loc + 1)
    rank_e = jnp.where(valid, rank_e, cap_e)
    ok_e = valid & (rank_e < cap_e)
    buf = jnp.zeros((E_loc, cap_e, d), x.dtype)
    buf = buf.at[jnp.where(ok_e, re_safe, E_loc), rank_e].set(rx, mode="drop")

    # ---- batched expert matmuls (MXU) ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # ---- un-bucket, reverse all_to_all, combine ----
    y_rows = jnp.where(
        ok_e[:, None],
        y_buf[re_safe, jnp.minimum(rank_e, cap_e - 1)],
        0.0).reshape(M, cap_send, d)
    back = jax.lax.all_to_all(y_rows, ep_axes, 0, 0, tiled=False)
    # back[m, c] corresponds to send slot (m, c); scatter-add to tokens
    y_tok = jnp.zeros((T, d), jnp.float32)
    contrib = jnp.where(ok[:, None], back[dest, rank_d].astype(jnp.float32), 0.0)
    y_tok = y_tok.at[flat_tok].add(contrib * flat_w[:, None])

    gates = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], topi].set(topw)
    aux = _aux_loss(probs[None], gates[None], cfg)
    return y_tok.reshape(B, S, d).astype(x.dtype), aux


def moe_forward(params, x, cfg: ModelConfig, *, mesh=None,
                data_axes=("data",), model_axis="model"):
    """Expert-parallel MoE over the "model" axis (shard_map island).

    Falls back to the dense oracle when no mesh is given (smoke tests)."""
    if mesh is None or cfg.moe_num_experts <= 1:
        return moe_ref(params, x, cfg)

    model_size = mesh.shape[model_axis]
    if cfg.moe_num_experts % model_size != 0:
        return moe_ref(params, x, cfg)
    # 2-D expert parallelism (§Perf B.2): when the expert count divides the
    # WHOLE mesh (deepseek: 256 experts on 256 chips), shard experts over
    # (data x model) jointly — expert weights become fully resident (no FSDP
    # all-gathers) and the all-to-all spans both axes.
    B, S = x.shape[0], x.shape[1]
    # batch axes: as many slow axes as divide the (global) batch
    b_axes: tuple = ()
    for kk in range(len(data_axes), 0, -1):
        n = int(np.prod([mesh.shape[a] for a in data_axes[:kk]]))
        if B % n == 0 and n > 1:
            b_axes = data_axes[:kk]
            break
    B_loc = B // int(np.prod([mesh.shape[a] for a in b_axes])) if b_axes else B

    total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    if cfg.moe_num_experts % total == 0 and tuple(b_axes) == tuple(data_axes):
        # tokens fully sharded across the data axes -> combined-axis EP is
        # well-defined (every token has exactly one owner)
        ep_axes = (*data_axes, model_axis)
    else:
        ep_axes = (model_axis,)
    ep_size = int(np.prod([mesh.shape[a] for a in ep_axes]))
    if S % model_size == 0:
        quota = None
        pspec_x = P(b_axes or None, model_axis, None)  # seq sliced over model
    else:
        # short sequences (decode): tokens replicated over "model"; each rank
        # owns a disjoint quota of them, outputs psum-combined.
        # quota mode: tokens are replicated over "model" only, so EP must
        # stay model-axis-local (a combined-axis quota would mis-assign the
        # data-sharded tokens)
        ep_axes = (model_axis,)
        ep_size = model_size
        quota = max(1, -(-(B_loc * S) // ep_size))
        pspec_x = P(b_axes or None, None, None)

    ep_sizes = tuple(mesh.shape[a] for a in ep_axes)

    def body(params, x):
        y, aux = _device_moe(params, x, cfg, ep_axes, ep_sizes, quota=quota)
        if quota is not None:
            y = jax.lax.psum(y, model_axis)
        return y, jax.lax.pmean(aux, (*data_axes, model_axis))
    ep_spec = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    pspec_params = {
        "router": P(),
        "w_gate": P(ep_spec, None, None),
        "w_up": P(ep_spec, None, None),
        "w_down": P(ep_spec, None, None),
    }
    if "shared" in params:
        pspec_params["shared"] = {k: P() for k in params["shared"]}

    shared_y = ffn_forward(params["shared"], x) if "shared" in params else None

    y, aux = compat.shard_map(
        body, mesh,
        ({k: pspec_params[k] for k in params if k != "shared"}, pspec_x),
        (pspec_x, P()),
    )({k: v for k, v in params.items() if k != "shared"}, x)
    if shared_y is not None:
        y = y + shared_y
    return y, aux
