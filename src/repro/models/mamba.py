"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD: within-chunk attention-like term via the 1-semiseparable mask,
across-chunk recurrence on the (H, P, N) state carried by a `lax.scan`. The
decode path is the O(1) recurrent update on the same state — this is what
makes `long_500k` trivial for SSM archs.

Jamba's Mamba-1 (S6) layers are implemented with the same machinery:
SSD with scalar-per-head A generalizes the S6 recurrence (the "duality" of
the paper's title); DESIGN.md records this hardware adaptation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig


def init_mamba_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner_ssm
    H = cfg.ssm_heads
    N = cfg.ssm_state
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    # in_proj emits [z (di), x (di), B (N), C (N), dt (H)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * N + H), dtype=dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di + 2 * N), dtype=dt),
        "conv_b": jnp.zeros((di + 2 * N,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),     # softplus ≈ 0.12
        "norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], (di, d), in_axis=0, dtype=dt),
    }


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (j<i)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk: int, init_state=None):
    """SSD scan. x: (b,S,H,P), dt: (b,S,H) (post-softplus), A: (H,) (<0),
    B/C: (b,S,N). Returns (y (b,S,H,P), final_state (b,H,P,N))."""
    b, S, H, Pd = x.shape
    N = B.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    xc = x.reshape(b, nc, chunk, H, Pd)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                     # (b,nc,l,H)
    dA_cs = jnp.cumsum(dA, axis=2)                        # inclusive
    # ---- within-chunk (diagonal) term ----
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (b,nc,H,l,l)
    G = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)             # (b,nc,l,s)
    M = G[:, :, None] * L                                 # (b,nc,H,l,s)
    y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp", M, dtc, xc)

    # ---- chunk states ----
    decay_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)      # (b,nc,l,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_end * dtc, xc)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (b,nc,H)

    def scan_fn(prev, inp):
        st, dec = inp                                     # (b,H,P,N), (b,H)
        new = st + dec[..., None, None] * prev
        return new, prev                                  # emit state BEFORE chunk

    init = (jnp.zeros((b, H, Pd, N), x.dtype) if init_state is None
            else init_state.astype(x.dtype))
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (b,nc,H,P,N)

    # ---- off-diagonal (carried state) term ----
    state_decay = jnp.exp(dA_cs)                          # (b,nc,l,H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, Sp, H, Pd)[:, :S]
    return y, final


def mamba_forward(params, x, cfg: ModelConfig, *, init_state=None,
                  conv_init=None, return_state: bool = False):
    """Full-sequence Mamba-2 block. x: (B,S,d) -> (B,S,d).

    ``init_state``/``conv_init`` continue a previous chunk (chunked prefill);
    with ``return_state`` the updated (ssm state, conv tail) are returned.
    """
    di, H, N = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_state
    Pd = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xs, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N],
                                  axis=-1)
    # causal depthwise conv over (x, B, C)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    hist = (conv_init if conv_init is not None else
            jnp.zeros((xbc.shape[0], cfg.ssm_conv - 1, xbc.shape[-1]), xbc.dtype))
    conv_tail = jnp.concatenate([hist.astype(xbc.dtype), xbc],
                                axis=1)[:, -(cfg.ssm_conv - 1):]
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"], history=hist)
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xs = jax.nn.silu(xs)
    Bm, Cm = jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(*xs.shape[:2], H, Pd)
    y, state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           chunk=cfg.ssm_chunk, init_state=init_state)
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(*xs.shape[:2], di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if return_state:
        return out, state, conv_tail
    return out


def _causal_conv(x, w, b, history=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). ``history``: (B,K-1,C)
    inputs preceding x (zeros when None)."""
    K = w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


# --------------------------------------------------------------------------
# decode: O(1) recurrent step
# --------------------------------------------------------------------------
def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                           cfg.d_inner_ssm + 2 * cfg.ssm_state), dtype),
    }


def mamba_decode(params, x1, cache, cfg: ModelConfig):
    """One-token step. x1: (B,1,d)."""
    di, H, N, Pd = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x1, params["in_proj"])[:, 0]
    z, xs, Bm, Cm, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N],
                                  axis=-1)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)            # (B, C)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, params["conv_w"]) + params["conv_b"]
    cache["conv"] = hist[:, 1:]
    xs, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    xs = jax.nn.silu(xs)
    Bm, Cm = jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                           # (B,H)
    xh = xs.reshape(-1, H, Pd).astype(jnp.float32)
    st = cache["state"]
    st = st * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bm.astype(jnp.float32))
    cache["state"] = st
    y = jnp.einsum("bhpn,bn->bhp", st, Cm.astype(jnp.float32))
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(-1, di).astype(x1.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None], cache
