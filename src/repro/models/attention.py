"""Attention layers: GQA (opt. QKV bias), MLA (DeepSeek), sliding-window,
cross-attention, with chunked-query training/prefill and ring-buffer KV-cache
decode (absorbed-MLA decode over the compressed cache).

All functions are stateless: ``params`` are plain dicts of arrays.
Shapes: x (B, S, d); caches are dicts with a scalar position counter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope, dense_init, rms_norm
from repro.models.config import ModelConfig
from repro.sharding import compat

NEG_INF = -1e30

UNC = jax.sharding.PartitionSpec.UNCONSTRAINED


def _constrain_heads(x, cfg: ModelConfig, head_axis: int = 2,
                     role: str = "q"):
    """Pin the heads dim of (B, S, H, hd) activations to the "model" axis.

    Without this, the MLA nope/rope split-and-concat (and the GQA grouped
    reshape) break GSPMD's sharding propagation: it all-gathers Q over
    "model" and computes attention with the *contracting* head_dim sharded,
    psumming full score tensors (§Perf hillclimb B.1).

    When the head count does not divide the "model" axis (qwen1.5-4b: 20H,
    qwen2.5-32b: 40H on a 16-wide axis) we fall back to **sequence
    parallelism**: q's sequence dim is sharded and the (small, GQA) k/v are
    all-gathered — otherwise GSPMD replicates attention and materializes
    full (B,H,S,S) score tensors per device (§Perf hillclimb C.1)."""
    del role
    tp = cfg.tp_size
    if tp <= 1 or x.ndim <= head_axis or x.shape[head_axis] % tp != 0:
        return x
    spec = [UNC] * x.ndim
    spec[head_axis] = "model"
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except Exception:   # no mesh in scope (single-device smoke tests)
        return x


def _use_seq_parallel(cfg: ModelConfig, H: int, S: int, mesh) -> bool:
    tp = cfg.tp_size
    return (mesh is not None and tp > 1 and H % tp != 0 and S % tp == 0
            and S > tp)


def _seq_parallel_attention(q, k, v, positions, kv_pos, cfg: ModelConfig,
                            mesh, chunk_attn, q_chunk: int):
    """shard_map island: q sharded on its sequence dim over "model", k/v
    replicated over "model" (kept sharded over the batch axes). Each device
    runs plain chunked attention on its query slice — no score psums, no
    (B,H,S,S) replication (§Perf hillclimb C.1)."""
    from jax.sharding import PartitionSpec as P
    import numpy as np
    B = q.shape[0]
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    b_axes: tuple = ()
    for kk in range(len(data_axes), 0, -1):
        n = int(np.prod([mesh.shape[a] for a in data_axes[:kk]]))
        if B % n == 0 and n > 1:
            b_axes = data_axes[:kk]
            break
    bspec = b_axes or None

    def body(q, k, v, positions, kv_pos):
        b, Sl = q.shape[0], q.shape[1]

        def attn(qc, qpos):
            return chunk_attn(qc, qpos, k, v, kv_pos)

        if Sl <= q_chunk:
            return attn(q, positions)
        nq = -(-Sl // q_chunk)
        pad = nq * q_chunk - Sl
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pp = jnp.pad(positions, ((0, 0), (0, pad)))
        qp = qp.reshape(b, nq, q_chunk, *q.shape[2:]).swapaxes(0, 1)
        pp = pp.reshape(b, nq, q_chunk).swapaxes(0, 1)
        out = jax.lax.map(lambda t: attn(*t), (qp, pp))
        return out.swapaxes(0, 1).reshape(b, nq * q_chunk,
                                          *out.shape[3:])[:, :Sl]

    return compat.shard_map(
        body, mesh,
        (P(bspec, "model", None, None), P(bspec, None, None, None),
         P(bspec, None, None, None), P(bspec, "model"), P(bspec, None)),
        P(bspec, "model", None, None))(q, k, v, positions, kv_pos)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------
def init_attention_params(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    d = cfg.d_model
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    if cfg.mla and not cross:
        p = {
            "wdq": dense_init(ks[0], (d, cfg.q_lora_rank), dtype=dt),
            "q_norm": jnp.ones((cfg.q_lora_rank,), dt),
            "wuq": dense_init(ks[1], (cfg.q_lora_rank, H,
                                      cfg.qk_nope_head_dim + cfg.qk_rope_head_dim),
                              in_axis=0, dtype=dt),
            "wdkv": dense_init(ks[2], (d, cfg.kv_lora_rank), dtype=dt),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dt),
            "wkr": dense_init(ks[3], (d, cfg.qk_rope_head_dim), dtype=dt),
            "wuk": dense_init(ks[4], (cfg.kv_lora_rank, H, cfg.qk_nope_head_dim),
                              in_axis=0, dtype=dt),
            "wuv": dense_init(ks[5], (cfg.kv_lora_rank, H, cfg.v_head_dim),
                              in_axis=0, dtype=dt),
            "wo": dense_init(ks[6], (H, cfg.v_head_dim, d), in_axis=1, dtype=dt),
        }
        return p
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype=dt),
        "wk": dense_init(ks[1], (d, Hkv, hd), dtype=dt),
        "wv": dense_init(ks[2], (d, Hkv, hd), dtype=dt),
        "wo": dense_init(ks[3], (H, hd, d), in_axis=1, dtype=dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((Hkv, hd), dt)
        p["bv"] = jnp.zeros((Hkv, hd), dt)
    return p


# --------------------------------------------------------------------------
# core attention math (q against k/v with mask), grouped heads
# --------------------------------------------------------------------------
def _gqa_scores_combine(q, k, v, mask):
    """q: (B,Sq,H,hd) k/v: (B,Skv,Hkv,hd) mask: (B,1,Sq,Skv) bool."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    q = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1])  # v head dim may differ (MLA)


def attention_forward(params, x, cfg: ModelConfig, *, positions=None,
                      q_chunk: int = 1024, enc_out=None,
                      mesh=None) -> jax.Array:
    """Full-sequence attention (training / whole-seq prefill).

    Causal with optional sliding window; if ``enc_out`` is given this is
    cross-attention (no causal mask, kv from encoder output).
    """
    B, S, d = x.shape
    cross = enc_out is not None
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    if cfg.mla and not cross:
        q, k, v = _mla_qkv(params, x, positions, cfg)
        q = _constrain_heads(q, cfg)
        k = _constrain_heads(k, cfg)
        v = _constrain_heads(v, cfg)
    else:
        src = enc_out if cross else x
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
        if "bq" in params:
            q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
        if not cross:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        q = _constrain_heads(q, cfg)
        k = _constrain_heads(k, cfg)
        v = _constrain_heads(v, cfg)

    Skv = k.shape[1]
    kv_pos = positions if not cross else jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    out = _attend(q, k, v, positions, kv_pos, cfg, mesh=mesh,
                  q_chunk=q_chunk, cross=cross)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])


def _attend(q, k, v, positions, kv_pos, cfg: ModelConfig, *, mesh=None,
            q_chunk: int = 1024, cross: bool = False):
    """Chunked-query attention of q against (k, v) with position-derived
    masking. kv entries with kv_pos < 0 are invalid (ring-buffer slots).
    Dispatches to the sequence-parallel shard_map island when heads do not
    divide the "model" axis (§Perf C.1)."""
    B, S = q.shape[0], q.shape[1]

    def chunk_attn_kv(qc, qpos, k, v, kv_pos):
        # qc: (b, Sq, H, hd); qpos: (b, Sq)
        if cross:
            mask = jnp.ones((qc.shape[0], 1, qc.shape[1], k.shape[1]), bool)
        else:
            mask = (kv_pos[:, None, None, :] >= 0) & \
                (qpos[:, None, :, None] >= kv_pos[:, None, None, :])
            if cfg.sliding_window:
                mask &= (qpos[:, None, :, None] - kv_pos[:, None, None, :]
                         < cfg.sliding_window)
        return _gqa_scores_combine(qc, k, v, mask)

    if _use_seq_parallel(cfg, q.shape[2], S, mesh) and not cross:
        return _seq_parallel_attention(q, k, v, positions, kv_pos, cfg, mesh,
                                       chunk_attn_kv, q_chunk)
    if S <= q_chunk:
        return chunk_attn_kv(q, positions, k, v, kv_pos)
    nq = -(-S // q_chunk)
    pad = nq * q_chunk - S
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pp = jnp.pad(positions, ((0, 0), (0, pad)))
    qp = qp.reshape(B, nq, q_chunk, *q.shape[2:]).swapaxes(0, 1)
    pp = pp.reshape(B, nq, q_chunk).swapaxes(0, 1)
    out = jax.lax.map(lambda t: chunk_attn_kv(t[0], t[1], k, v, kv_pos),
                      (qp, pp))
    return out.swapaxes(0, 1).reshape(B, nq * q_chunk, *out.shape[3:])[:, :S]


def _mla_qkv(params, x, positions, cfg: ModelConfig):
    """MLA projections for full-sequence mode (uncompressed k/v)."""
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wdq"]),
                  params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"])
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wdkv"]),
                   params["kv_norm"], cfg.norm_eps)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, params["wuv"])
    k_rope = apply_rope(jnp.einsum("bsd,dk->bsk", x, params["wkr"])[:, :, None, :],
                        positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (*k_nope.shape[:3], cfg.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    # pad v's head_dim up to qk dim so GQA combine works uniformly
    return q_full, k_full, v


def attention_extend(params, x, cache: dict, cfg: ModelConfig,
                     *, mesh=None):
    """Chunked-prefill step: process S_c tokens attending to the cache plus
    themselves (causal), then write them into the ring buffer.

    Returns (out (B,S_c,d), cache). MLA uses the expanded cache here (the
    absorbed path is decode-only); GQA attends to ring k/v directly.
    """
    B, Sc, _ = x.shape
    t = cache["t"]
    W = cache["pos"].shape[1]
    positions = t + jnp.broadcast_to(jnp.arange(Sc), (B, Sc))

    if cfg.mla:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wdq"]),
                      params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"])
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wdkv"]),
                       params["kv_norm"], cfg.norm_eps)
        kr = apply_rope(jnp.einsum("bsd,dk->bsk", x, params["wkr"])[:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0, :]
        cache = _ring_write(cache, {"ckv": ckv, "kr": kr}, positions)
        # expand compressed cache to k/v for chunk scoring
        k_nope = jnp.einsum("bwr,rhk->bwhk", cache["ckv"].astype(x.dtype),
                            params["wuk"])
        kr_c = jnp.broadcast_to(cache["kr"][:, :, None, :],
                                (*k_nope.shape[:3], cfg.qk_rope_head_dim)
                                ).astype(x.dtype)
        k = jnp.concatenate([k_nope, kr_c], axis=-1)
        v = jnp.einsum("bwr,rhk->bwhk", cache["ckv"].astype(x.dtype),
                       params["wuv"])
        q_for_attn = q_full
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        k1 = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v1 = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if "bq" in params:
            q, k1, v1 = q + params["bq"], k1 + params["bk"], v1 + params["bv"]
        q = apply_rope(q, positions, cfg.rope_theta)
        k1 = apply_rope(k1, positions, cfg.rope_theta)
        cache = _ring_write(cache, {"k": k1, "v": v1}, positions)
        k, v = cache["k"], cache["v"]
        q_for_attn = q

    out = _attend(q_for_attn, k, v, positions, cache["pos"], cfg,
                  mesh=mesh, q_chunk=1024)
    cache["t"] = t + Sc
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"]), cache


def cross_kv(params, enc_out):
    """Precompute cross-attention k/v from encoder output (cached once)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    return k, v


def _ring_write(cache: dict, new: dict, positions):
    """Write S_c new entries at positions%W (assumes S_c <= W or takes the
    last W)."""
    B = positions.shape[0]
    W = cache["pos"].shape[1]
    take = min(positions.shape[1], W)
    slots = positions[:, -take:] % W
    bidx = jnp.arange(B)[:, None]
    for name, val in new.items():
        cache[name] = cache[name].at[bidx, slots].set(
            val[:, -take:].astype(cache[name].dtype))
    cache["pos"] = cache["pos"].at[bidx, slots].set(positions[:, -take:])
    return cache


# --------------------------------------------------------------------------
# decode with ring-buffer cache
# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, length: int, *, cross_len: int = 0):
    """Ring-buffer cache. `length` = window size for sliding-window decode or
    full context length. Positions initialised to -1 (invalid)."""
    dt = jnp.dtype(cfg.compute_dtype)
    W = min(length, cfg.sliding_window) if cfg.sliding_window else length
    if cfg.mla:
        cache = {
            "ckv": jnp.zeros((batch, W, cfg.kv_lora_rank), dt),
            "kr": jnp.zeros((batch, W, cfg.qk_rope_head_dim), dt),
        }
    else:
        Hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache = {
            "k": jnp.zeros((batch, W, Hkv, hd), dt),
            "v": jnp.zeros((batch, W, Hkv, hd), dt),
        }
    cache["pos"] = jnp.full((batch, W), -1, jnp.int32)
    cache["t"] = jnp.zeros((), jnp.int32)
    if cross_len:
        cache["enc_k"] = jnp.zeros((batch, cross_len, cfg.num_kv_heads,
                                    cfg.resolved_head_dim), dt)
        cache["enc_v"] = jnp.zeros_like(cache["enc_k"])
    return cache


def fill_cache(params, cache: dict, tokens_x: jax.Array, cfg: ModelConfig,
               start: int = 0):
    """Prefill: run full-seq projections and write the last W entries into the
    ring buffer (used by serve prefill)."""
    B, S, _ = tokens_x.shape
    positions = start + jnp.broadcast_to(jnp.arange(S), (B, S))
    if cfg.mla:
        ckv = rms_norm(jnp.einsum("bsd,dr->bsr", tokens_x, params["wdkv"]),
                       params["kv_norm"], cfg.norm_eps)
        kr = apply_rope(jnp.einsum("bsd,dk->bsk", tokens_x,
                                   params["wkr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
        new = {"ckv": ckv, "kr": kr}
    else:
        k = jnp.einsum("bsd,dhk->bshk", tokens_x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", tokens_x, params["wv"])
        if "bk" in params:
            k, v = k + params["bk"], v + params["bv"]
        k = apply_rope(k, positions, cfg.rope_theta)
        new = {"k": k, "v": v}
    W = cache["pos"].shape[1]
    take = min(S, W)
    slots = (positions[:, -take:]) % W
    for name, val in new.items():
        cache[name] = cache[name].at[jnp.arange(B)[:, None], slots].set(
            val[:, -take:].astype(cache[name].dtype))
    cache["pos"] = cache["pos"].at[jnp.arange(B)[:, None], slots].set(
        positions[:, -take:])
    cache["t"] = jnp.asarray(start + S, jnp.int32)
    return cache


def attention_decode(params, x1, cache: dict, cfg: ModelConfig, *,
                     cross: bool = False):
    """One-token decode. x1: (B, 1, d). Returns (out (B,1,d), new cache).

    GQA: ring-buffer k/v attention. MLA: absorbed decode — scores and values
    are computed against the *compressed* ckv cache (never expanding k/v),
    which is the reason MLA's cache is small.
    """
    B = x1.shape[0]
    t = cache["t"]
    W = cache["pos"].shape[1]
    pos1 = jnp.broadcast_to(t[None, None], (B, 1))

    if cross:
        k, v = cache["enc_k"], cache["enc_v"]
        q = jnp.einsum("bsd,dhk->bshk", x1, params["wq"])
        if "bq" in params:
            q = q + params["bq"]
        mask = jnp.ones((B, 1, q.shape[1], k.shape[1]), bool)
        out = _gqa_scores_combine(q, k, v, mask)
        return jnp.einsum("bshk,hkd->bsd", out.astype(x1.dtype), params["wo"]), cache

    slot = (t % W).astype(jnp.int32)
    valid = cache["pos"] >= 0                                  # (B, W)
    if cfg.sliding_window:
        valid &= (t - cache["pos"]) < cfg.sliding_window

    if cfg.mla:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x1, params["wdq"]),
                      params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"])
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
        q_rope = apply_rope(q_rope, pos1, cfg.rope_theta)
        ckv1 = rms_norm(jnp.einsum("bsd,dr->bsr", x1, params["wdkv"]),
                        params["kv_norm"], cfg.norm_eps)
        kr1 = apply_rope(jnp.einsum("bsd,dk->bsk", x1,
                                    params["wkr"])[:, :, None, :], pos1,
                         cfg.rope_theta)[:, :, 0, :]
        cache["ckv"] = cache["ckv"].at[:, slot].set(ckv1[:, 0].astype(cache["ckv"].dtype))
        cache["kr"] = cache["kr"].at[:, slot].set(kr1[:, 0].astype(cache["kr"].dtype))
        cache["pos"] = cache["pos"].at[:, slot].set(t)
        valid = cache["pos"] >= 0
        if cfg.sliding_window:
            valid &= (t - cache["pos"]) < cfg.sliding_window
        # absorbed scores: q_nope^T Wuk^T ckv  +  q_rope^T kr
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope.astype(jnp.float32),
                           params["wuk"].astype(jnp.float32))     # (B,1,H,r)
        sc = (jnp.einsum("bshr,bwr->bhw", q_abs,
                         cache["ckv"].astype(jnp.float32))
              + jnp.einsum("bshk,bwk->bhw", q_rope.astype(jnp.float32),
                           cache["kr"].astype(jnp.float32)))
        sc = sc / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        sc = jnp.where(valid[:, None, :], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)                           # (B,H,W)
        ctx = jnp.einsum("bhw,bwr->bhr", p, cache["ckv"].astype(jnp.float32))
        out = jnp.einsum("bhr,rhk->bhk", ctx, params["wuv"].astype(jnp.float32))
        out = out[:, None]                                        # (B,1,H,vd)
        cache["t"] = t + 1
        return jnp.einsum("bshk,hkd->bsd", out.astype(x1.dtype), params["wo"]), cache

    q = jnp.einsum("bsd,dhk->bshk", x1, params["wq"])
    k1 = jnp.einsum("bsd,dhk->bshk", x1, params["wk"])
    v1 = jnp.einsum("bsd,dhk->bshk", x1, params["wv"])
    if "bq" in params:
        q, k1, v1 = q + params["bq"], k1 + params["bk"], v1 + params["bv"]
    q = apply_rope(q, pos1, cfg.rope_theta)
    k1 = apply_rope(k1, pos1, cfg.rope_theta)
    cache["k"] = cache["k"].at[:, slot].set(k1[:, 0].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, slot].set(v1[:, 0].astype(cache["v"].dtype))
    cache["pos"] = cache["pos"].at[:, slot].set(t)
    valid = cache["pos"] >= 0
    if cfg.sliding_window:
        valid &= (t - cache["pos"]) < cfg.sliding_window
    out = _gqa_scores_combine(q, cache["k"], cache["v"], valid[:, None, None, :])
    cache["t"] = t + 1
    return jnp.einsum("bshk,hkd->bsd", out.astype(x1.dtype), params["wo"]), cache
