"""Shared model building blocks: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) rotary on last dim; positions: broadcastable (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))                 # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def cast_pytree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
