"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed experts top-8, MTP
[arXiv:2412.19437].

The assignment table lists d_ff=2048 — that is the per-expert hidden dim;
the first 3 layers are dense with d_ff=18432, per the paper. MLA dims are
the paper's: q_lora 1536, kv_lora 512, nope/rope head dims 128/64, v 128.
`long_500k` decode keeps FULL attention: the compressed MLA cache for 524k
tokens is only ~0.6 GB (the architecture's selling point).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,                # dense layers (first 3)
    vocab_size=129280,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp=True,
    moe_num_experts=256,
    moe_top_k=8,
    moe_d_ff=2048,
    moe_num_shared=1,
    moe_layer_start=3,
    moe_layer_period=1,
    optimizer="adafactor",
    train_microbatches=8,
    prefill_chunk=2048,
)
