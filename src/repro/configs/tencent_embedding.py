"""tencent-embedding — the paper's own workload (Anonymized A, Table III).

1.05B nodes, d=128, 5 negatives — trained with the hybrid model-data
parallel episode step (`repro.core.hybrid`). This is the reproduction
target, exposed as an `--arch` like the assigned pool.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EmbeddingArchConfig:
    name: str = "tencent-embedding"
    arch_type: str = "embedding"
    num_nodes: int = 1_050_000_000
    dim: int = 128
    negatives: int = 5
    minibatch: int = 256
    subparts: int = 4            # paper's k
    neg_pool: int = 65536
    lr: float = 0.025
    # per-device episode geometry for the dry-run (see DESIGN.md §5):
    # each device holds (rounds x subparts) blocks of block_cap samples.
    block_cap: int = 8192
    dtype: str = "float32"       # paper-faithful; "bfloat16" = §Perf A.3


CONFIG = EmbeddingArchConfig()

# small variant for smoke tests / benchmarks on CPU
SMALL = dataclasses.replace(
    CONFIG, name="tencent-embedding-small", num_nodes=20000, neg_pool=4096,
    block_cap=512, minibatch=64, subparts=2)
