"""Architecture registry: ``--arch <id>`` selection + per-shape input specs.

Every assigned architecture is a module exporting ``CONFIG``; this package
maps public ids to configs, derives per-shape adjusted configs
(:func:`for_shape`) and builds the ShapeDtypeStruct input specs the dry-run
lowers against (:func:`input_specs` — no device allocation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, InputShape
from repro.models.config import ModelConfig

from repro.configs import (jamba_v0_1_52b, qwen1_5_4b, qwen2_5_32b,
                           qwen1_5_0_5b, granite_3_2b, deepseek_v3_671b,
                           llava_next_mistral_7b, mamba2_1_3b,
                           seamless_m4t_large_v2, phi3_5_moe_42b,
                           tencent_embedding)

ARCHS = {
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "qwen2.5-32b": qwen2_5_32b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "granite-3-2b": granite_3_2b.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "mamba2-1.3b": mamba2_1_3b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b.CONFIG,
    "tencent-embedding": tencent_embedding.CONFIG,
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)


# --------------------------------------------------------------------------
# per-shape config adjustment
# --------------------------------------------------------------------------
def for_shape(cfg: ModelConfig, shape: InputShape, *,
              dtype: str = "bfloat16") -> ModelConfig:
    """Adjust a full config for one input shape (dry-run numerics: bf16).

    long_500k policy (DESIGN.md §4): SSM/hybrid/MLA archs decode the full
    524k context natively (O(1) state / few attn layers / compressed cache);
    plain-GQA archs switch to an 8192 sliding window — the explicitly
    implemented sub-quadratic variant.
    """
    changes: dict = dict(param_dtype=dtype, compute_dtype=dtype)
    if shape.kind == "decode":
        changes["remat"] = False
        changes["train_microbatches"] = 1
    if shape.name == "long_500k":
        native_long = (cfg.arch_type in ("ssm", "hybrid")) or cfg.mla
        if not native_long:
            changes["sliding_window"] = 8192
    if shape.kind == "prefill" and cfg.prefill_chunk:
        # chunk must divide the (possibly prefix-extended) prefill length
        changes["prefill_chunk"] = cfg.prefill_chunk
    return dataclasses.replace(cfg, **changes)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; nothing is allocated)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Model inputs for (arch, shape) as ShapeDtypeStructs.

    Train/prefill: the token budget per sequence is `seq_len`; VLM spends
    `frontend_len_cap` of it on stub patch embeddings, audio splits it
    half frames / half tokens (DESIGN.md §4).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.compute_dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.modality == "vision":
            P = cfg.frontend_len_cap
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
                "positions": jax.ShapeDtypeStruct((B, S - P), i32),
                "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), dt),
            }
        if cfg.modality == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), dt),
                "tokens": jax.ShapeDtypeStruct((B, S // 2), i32),
                "positions": jax.ShapeDtypeStruct((B, S // 2), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "positions": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of length S (built separately)
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
