"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]. d_inner = 2*d_model = 4096, 64 heads x 64 head_dim,
d_state=128. `long_500k` is native: decode state is O(1) in context length.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                    # attention-free, no FFN (Mamba-2 block only)
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
    train_microbatches=4,
)
