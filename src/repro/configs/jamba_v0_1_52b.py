"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every 2nd
layer, 16 experts top-2 [arXiv:2403.19887].

Long-context note: only 4 of 32 layers are attention, so `long_500k` decode
runs with FULL attention caches (the architecture's selling point) — the
per-device KV slice fits comfortably (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    # Jamba period-8 block: attention at slot 4, Mamba elsewhere (1:7)
    layer_pattern="MMMMAMMM",
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    # MoE every 2nd layer, 16 experts top-2, expert ff = d_ff
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_layer_start=1,
    moe_layer_period=2,
    optimizer="adafactor",
    train_microbatches=4,
    prefill_chunk=2048,
)
