"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The ViT/SigLIP vision tower + projector are STUBS per the assignment:
`input_specs()` supplies precomputed patch embeddings (anyres: base 576
patches + 576 per tile, we use 1152 = base + one tile) already projected to
d_model; the language transformer here consumes them as a prefix.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1e6,
    modality="vision",
    frontend_len_cap=1152,     # anyres patches supplied by the stub frontend
    train_microbatches=4,
)
