"""qwen1.5-0.5b [dense] — MHA, QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    train_microbatches=4,
)
