"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal
[arXiv:2308.11596].

The mel-spectrogram + conformer feature frontend is a STUB per the
assignment: `input_specs()` supplies precomputed frame embeddings (B, Se, d)
to the 24-layer encoder; the 24-layer decoder cross-attends. For train/
prefill shapes the seq budget is split S/2 frames + S/2 tokens; for decode
shapes the encoder memory is capped at `frontend_len_cap` frames.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    num_layers=24,             # decoder
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    modality="audio",
    frontend_len_cap=8192,
    train_microbatches=4,
)
