"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2, GQA kv=8
[hf:microsoft/Phi-3.5-MoE-instruct]. Every layer is MoE (expert ff 6400)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=6400,
    moe_layer_start=0,
    moe_layer_period=1,
    optimizer="adafactor",
    train_microbatches=4,
    prefill_chunk=2048,
)
