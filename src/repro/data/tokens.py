"""Synthetic-but-structured token pipeline for the LM examples.

Generates documents from a small order-1 Markov chain over the vocab so the
LM has actual structure to learn (loss visibly decreases), packs them into
fixed-length sequences, and prefetches batches on a worker thread — the same
producer/consumer decoupling the paper uses between its walk engine and
trainer.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.models.config import ModelConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 seed: int = 0, states: int = 64, prefetch: int = 2):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        rng = np.random.default_rng(seed)
        V = cfg.vocab_size
        self._states = states
        # sparse-ish Markov transition over `states` latent states, each
        # emitting a zipf-weighted slice of the vocab
        self._trans = rng.dirichlet(np.full(states, 0.3), size=states)
        emit = rng.zipf(1.4, size=(states, 32))
        self._emit = np.minimum(emit + np.arange(states)[:, None] * 17,
                                V - 1).astype(np.int32)
        self._rng = rng
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _sample(self):
        B, S = self.batch, self.seq
        rng = self._rng
        st = rng.integers(0, self._states, B)
        toks = np.zeros((B, S), np.int32)
        for t in range(S):
            # vectorized markov step
            u = rng.random(B)
            cdf = np.cumsum(self._trans[st], axis=1)
            st = (u[:, None] < cdf).argmax(axis=1)
            toks[:, t] = self._emit[st, rng.integers(0, 32, B)]
        out = {"tokens": toks}
        if self.cfg.modality == "vision":
            P = self.cfg.frontend_len_cap
            out["patch_embeds"] = rng.normal(
                0, 1, (B, P, self.cfg.d_model)).astype(np.float32)
        if self.cfg.modality == "audio":
            out["frames"] = rng.normal(
                0, 1, (B, S, self.cfg.d_model)).astype(np.float32)
        return out

    def _worker(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._sample(), timeout=1.0)
            except queue.Full:
                continue

    def next(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
