"""Training step: loss + grad + optimizer update, with gradient-accumulation
microbatching (the memory policy that keeps MoE dispatch buffers and logits
bounded on 16 GB chips — DESIGN.md §5)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.train.optimizer import make_optimizer


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0):
    """Random-token batch with zipf-ish marginals (data pipeline stand-in)."""
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.modality == "vision":
        P = min(cfg.frontend_len_cap, seq // 2)
        out["patch_embeds"] = rng.normal(0, 1, (batch, P, cfg.d_model)).astype(
            np.dtype(cfg.compute_dtype))
        seq = seq - P
    if cfg.modality == "audio":
        out["frames"] = rng.normal(0, 1, (batch, seq // 2, cfg.d_model)).astype(
            np.dtype(cfg.compute_dtype))
        seq = seq // 2
    z = rng.zipf(1.3, size=(batch, seq))
    out["tokens"] = np.minimum(z, cfg.vocab_size - 1).astype(np.int32)
    out["positions"] = np.broadcast_to(np.arange(seq, dtype=np.int32),
                                       (batch, seq)).copy()
    return out


def make_train_step(cfg: ModelConfig, *, mesh=None, data_axes=("data",),
                    lr: float = 1e-4):
    """Returns train_step(params, opt_state, step, batch) ->
    (params, opt_state, metrics)."""
    opt = make_optimizer(cfg.optimizer, lr=lr)

    def loss_fn(params, mb):
        loss, metrics = tfm.forward_train(params, mb, cfg, mesh=mesh,
                                          data_axes=data_axes)
        return loss, metrics

    def train_step(params, opt_state, step, batch):
        k = cfg.train_microbatches
        if k <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # grad accumulation: scan over k microbatches
            def split(x):
                b = x.shape[0]
                return x.reshape(k, b // k, *x.shape[1:])
            mbs = jax.tree.map(split, batch)
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_sum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     g_sum, g)
                return (g_sum, l_sum + loss), None

            (grads, loss), _ = jax.lax.scan(acc, (zero_g, jnp.float32(0.0)),
                                            mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss / k
            metrics = {"xent": loss}
        params, opt_state = opt.update(grads, opt_state, params, step)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step, opt
