from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step, synthetic_batch
from repro.train.serve_step import make_prefill_step, make_decode_step

__all__ = ["make_optimizer", "make_train_step", "synthetic_batch",
           "make_prefill_step", "make_decode_step"]
