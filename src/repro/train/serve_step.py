"""Serving steps: chunked prefill and one-token decode (+ cache shardings)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.sharding.specs import batch_spec


def make_prefill_step(cfg: ModelConfig, cache_len: int, *, mesh=None,
                      data_axes=("data",)):
    def prefill_step(params, batch):
        return tfm.prefill(params, batch, cfg, cache_len, mesh=mesh,
                           data_axes=data_axes)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, mesh=None, data_axes=("data",)):
    def decode(params, token, caches):
        return tfm.decode_step(params, token, caches, cfg, mesh=mesh,
                               data_axes=data_axes)
    return decode


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer length: the sliding window if set, else the full context."""
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int, params_struct):
    """Abstract cache pytree for the dry-run (ShapeDtypeStructs). Enc-dec
    archs decode against a cross-attention memory of `frontend_len_cap`
    frames (DESIGN.md §4)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_len_cap, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    return jax.eval_shape(
        lambda p, e: tfm.init_caches(p, cfg, batch, cache_len, enc_out=e),
        params_struct, enc_out)


def cache_shardings(caches_struct, global_batch: int, mesh):
    """Cache shardings: batch over the slow axes, heads (or head_dim / ssm
    heads) over "model". Leading dim of every leaf is the scan-group dim."""
    bs = batch_spec(global_batch, mesh)
    bspec = bs[0] if len(bs) else None
    model_n = mesh.shape.get("model", 1)
    # preferred model-axis dims per cache leaf (after the (G, B, ...) prefix):
    # kv heads first, then head_dim; ssm state prefers heads.
    pref = {"k": (3, 4), "v": (3, 4), "enc_k": (3, 4), "enc_v": (3, 4),
            "ckv": (3,), "kr": (3,), "state": (2, 3, 4), "conv": (3,)}

    def one(path, leaf):
        name = ""
        for e in reversed(path):
            if hasattr(e, "key"):
                name = str(e.key)
                break
        shp = leaf.shape  # (G, B, ...)
        if name == "t" or len(shp) < 2:
            return NamedSharding(mesh, P())
        spec = [None, bspec] + [None] * (len(shp) - 2)
        for dim in pref.get(name, ()):
            if dim < len(shp) and shp[dim] % model_n == 0 and model_n > 1:
                spec[dim] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, caches_struct)
