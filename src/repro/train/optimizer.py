"""Optimizers: SGD, AdamW, Adafactor (factored second moment).

Pure-pytree implementations (no optax dependency). Adafactor (beta1=0,
factored v) is the memory-policy choice for the 42-671B archs: optimizer
state is ~(rows+cols) instead of 2x params (DESIGN.md §5 memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def _map_leaves(fn, params, *trees):
    """Map over params' leaves; other trees may hold subtrees (e.g. factored
    state dicts) at params' leaf positions."""
    p_leaves, treedef = jax.tree.flatten(params)
    others = [treedef.flatten_up_to(t) for t in trees]
    outs = [fn(p, *rest) for p, *rest in zip(p_leaves, *others)]
    if isinstance(outs[0], tuple):
        return tuple(jax.tree.unflatten(treedef, [o[i] for o in outs])
                     for i in range(len(outs[0])))
    return jax.tree.unflatten(treedef, outs)


def make_optimizer(name: str, lr: float = 1e-4, *, wd: float = 0.0,
                   b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    if name == "sgd":
        def init(params):
            return {"_": jnp.zeros(())}

        def update(grads, state, params, step):
            new = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                             - lr * g.astype(jnp.float32)
                                             ).astype(p.dtype), params, grads)
            return new, state
        return Optimizer("sgd", init, update)

    if name == "adamw":
        def init(params):
            z = lambda p: jnp.zeros(p.shape, jnp.float32)
            return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

        def update(grads, state, params, step):
            t = step.astype(jnp.float32) + 1.0

            def upd(p, g, m, v):
                g = g.astype(jnp.float32)
                m = b1 * m + (1 - b1) * g
                v = b2 * v + (1 - b2) * g * g
                mh = m / (1 - b1 ** t)
                vh = v / (1 - b2 ** t)
                delta = lr * (mh / (jnp.sqrt(vh) + eps)
                              + wd * p.astype(jnp.float32))
                return (p.astype(jnp.float32) - delta).astype(p.dtype), m, v

            new_p, new_m, new_v = _map_leaves(upd, params, grads,
                                              state["m"], state["v"])
            return new_p, {"m": new_m, "v": new_v}
        return Optimizer("adamw", init, update)

    if name == "adafactor":
        def init(params):
            def state_of(p):
                if p.ndim >= 2:
                    return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                            "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                            jnp.float32)}
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {"f": jax.tree.map(state_of, params)}

        def update(grads, state, params, step):
            t = step.astype(jnp.float32) + 1.0
            beta2t = 1.0 - t ** -0.8

            def upd(p, g, s):
                g = g.astype(jnp.float32)
                g2 = g * g + 1e-30
                if p.ndim >= 2:
                    vr = beta2t * s["vr"] + (1 - beta2t) * jnp.mean(g2, axis=-1)
                    vc = beta2t * s["vc"] + (1 - beta2t) * jnp.mean(g2, axis=-2)
                    r = vr / jnp.mean(vr, axis=-1, keepdims=True)
                    u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                             + 1e-30)
                    ns = {"vr": vr, "vc": vc}
                else:
                    v = beta2t * s["v"] + (1 - beta2t) * g2
                    u = g / (jnp.sqrt(v) + 1e-30)
                    ns = {"v": v}
                # RMS clip to 1.0 (adafactor's relative step clipping)
                rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
                u = u / jnp.maximum(1.0, rms)
                newp = (p.astype(jnp.float32) - lr * u
                        - lr * wd * p.astype(jnp.float32)).astype(p.dtype)
                return newp, ns

            new_p, new_f = _map_leaves(upd, params, grads, state["f"])
            return new_p, {"f": new_f}
        return Optimizer("adafactor", init, update)

    raise ValueError(f"unknown optimizer {name}")
