"""Flat-npz checkpointing for param/optimizer pytrees (single-host).

Leaves are keyed by their tree path; restore rebuilds into the template's
structure (and dtype) so checkpoints survive config-compatible code changes.

Extension dtypes (bfloat16 — the embedding tables' default since the AUC
parity gate) need special care: ``np.savez`` stores them as raw void bytes
("|V2") and loses the type, so save records each such leaf's dtype name
under a ``__dtype__:<key>`` entry and load view-casts the bytes back —
bitwise, which is what the serving store's round-trip guarantee relies on.
"""
from __future__ import annotations

import os

import jax
import numpy as np

_DTYPE_PREFIX = "__dtype__:"


def _named_dtype(name: str) -> np.dtype:
    """Dtype from its saved name, including ml_dtypes extension types."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree, *, step: int | None = None) -> None:
    arrs = _flatten_with_names(tree)
    if step is not None:
        arrs["__step__"] = np.asarray(step)
    # extension dtypes (kind "V": bfloat16 & friends) lose their identity in
    # the npz; record the name so load_arrays can view-cast the bytes back
    for key, arr in list(arrs.items()):
        if arr.dtype.kind == "V":
            arrs[_DTYPE_PREFIX + key] = np.asarray(arr.dtype.name)
    tmp = path + ".tmp"
    np.savez(tmp, **arrs)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_arrays(path: str):
    """Raw ``key -> array`` view of a checkpoint, plus its step.

    This is the loading path for consumers that know the key they want but
    not the full tree template (e.g. ``embed_serve.store`` pulling one
    embedding table out of a training checkpoint). Extension-dtype leaves
    come back bitwise in their original dtype.
    """
    with np.load(path) as f:
        data = {k: f[k] for k in f.files}
    step = int(data.pop("__step__", -1))
    names = {k[len(_DTYPE_PREFIX):]: str(data.pop(k).item())
             for k in list(data) if k.startswith(_DTYPE_PREFIX)}
    for key, name in names.items():
        if key in data and data[key].dtype.kind == "V":
            data[key] = data[key].view(_named_dtype(name))
    return data, step


def restore_checkpoint(path: str, template):
    data, step = load_arrays(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves), step
