"""Flat-npz checkpointing for param/optimizer pytrees (single-host).

Leaves are keyed by their tree path; restore rebuilds into the template's
structure (and dtype) so checkpoints survive config-compatible code changes.
"""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree, *, step: int | None = None) -> None:
    arrs = _flatten_with_names(tree)
    if step is not None:
        arrs["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    np.savez(tmp, **arrs)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore_checkpoint(path: str, template):
    with np.load(path) as f:
        data = {k: f[k] for k in f.files}
    step = int(data.pop("__step__", -1))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves), step
