"""Flat-npz checkpointing for param/optimizer pytrees (single-host).

Leaves are keyed by their tree path; restore rebuilds into the template's
structure (and dtype) so checkpoints survive config-compatible code changes.

Extension dtypes (bfloat16 — the embedding tables' default since the AUC
parity gate) need special care: ``np.savez`` stores them as raw void bytes
("|V2") and loses the type, so save records each such leaf's dtype name
under a ``__dtype__:<key>`` entry and load view-casts the bytes back —
bitwise, which is what the serving store's round-trip guarantee relies on.

Integrity: save records a ``__manifest__`` (the expected key list) and a
``__crc__:<key>`` (crc32, byte length) entry per array, all written via
tmp + ``os.replace`` so a crash mid-save never clobbers the previous good
checkpoint. ``load_arrays(verify=True)`` — the default — checks every
entry against its checksum *as stored* (before any dtype view-cast) and
raises :class:`CheckpointCorrupt` on mismatch or missing keys, so a torn
or bit-flipped resume file fails loudly instead of resuming from garbage.
"""
from __future__ import annotations

import os
import zipfile
import zlib

import jax
import numpy as np

_DTYPE_PREFIX = "__dtype__:"
_CRC_PREFIX = "__crc__:"


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed its manifest/checksum verification."""

    def __init__(self, path: str, reason: str):
        self.path = path
        self.reason = reason
        super().__init__(f"checkpoint {path} corrupt: {reason}")


def _named_dtype(name: str) -> np.dtype:
    """Dtype from its saved name, including ml_dtypes extension types."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                       for e in path)
        out[key] = np.asarray(leaf)
    return out


def _crc(arr: np.ndarray) -> np.ndarray:
    b = np.ascontiguousarray(arr).tobytes()
    return np.asarray([zlib.crc32(b), len(b)], dtype=np.int64)


def save_checkpoint(path: str, tree, *, step: int | None = None,
                    extra: dict | None = None) -> None:
    """Atomically write the tree (plus optional ``extra`` arrays, e.g. a
    resume cursor) with a per-entry checksum manifest."""
    arrs = _flatten_with_names(tree)
    if step is not None:
        arrs["__step__"] = np.asarray(step)
    for key, val in (extra or {}).items():
        arrs[key] = np.asarray(val)
    # extension dtypes (kind "V": bfloat16 & friends) lose their identity in
    # the npz; record the name so load_arrays can view-cast the bytes back
    for key, arr in list(arrs.items()):
        if arr.dtype.kind == "V":
            arrs[_DTYPE_PREFIX + key] = np.asarray(arr.dtype.name)
    for key, arr in list(arrs.items()):
        arrs[_CRC_PREFIX + key] = _crc(arr)
    arrs["__manifest__"] = np.asarray(sorted(k for k in arrs
                                             if not k.startswith(_CRC_PREFIX)))
    tmp = path + ".tmp"
    np.savez(tmp, **arrs)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_arrays(path: str, *, verify: bool = True):
    """Raw ``key -> array`` view of a checkpoint, plus its step.

    This is the loading path for consumers that know the key they want but
    not the full tree template (e.g. ``embed_serve.store`` pulling one
    embedding table out of a training checkpoint). Extension-dtype leaves
    come back bitwise in their original dtype. ``verify`` (default) checks
    the manifest and per-entry checksums — bytes as stored, before any
    view-cast — raising :class:`CheckpointCorrupt` on any mismatch;
    pre-manifest checkpoints (no ``__manifest__`` entry) load unverified
    for compatibility.
    """
    try:
        with np.load(path) as f:
            data = {k: f[k] for k in f.files}
    except (ValueError, EOFError, OSError, zipfile.BadZipFile) as e:
        raise CheckpointCorrupt(path, f"unreadable npz: {e}") from e
    crcs = {k[len(_CRC_PREFIX):]: data.pop(k)
            for k in list(data) if k.startswith(_CRC_PREFIX)}
    manifest = data.pop("__manifest__", None)
    if verify and manifest is not None:
        want = set(str(k) for k in manifest.tolist())
        have = set(data)
        if want != have:
            missing, stray = sorted(want - have), sorted(have - want)
            raise CheckpointCorrupt(
                path, f"manifest mismatch: missing={missing} stray={stray}")
        for key, arr in data.items():
            got = _crc(arr)
            exp = crcs.get(key)
            if exp is None or not np.array_equal(got, np.asarray(exp)):
                raise CheckpointCorrupt(
                    path, f"checksum mismatch for {key!r} "
                          f"(got {got.tolist()}, want "
                          f"{None if exp is None else np.asarray(exp).tolist()})")
    step = int(data.pop("__step__", -1))
    names = {k[len(_DTYPE_PREFIX):]: str(data.pop(k).item())
             for k in list(data) if k.startswith(_DTYPE_PREFIX)}
    for key, name in names.items():
        if key in data and data[key].dtype.kind == "V":
            data[key] = data[key].view(_named_dtype(name))
    return data, step


def restore_checkpoint(path: str, template, *, verify: bool = True):
    data, step = load_arrays(path, verify=verify)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves), step
