"""Fault-tolerant episode transport: framing, idempotent chunk assembly,
and per-host health leases.

The paper's deployment decouples CPU walk machines from GPU trainers across
a cluster; this module is the wire layer that crossing that process/host
boundary needs. Three pieces, each independently testable:

* :class:`FramedSocket` — length-prefixed, CRC32-checksummed message frames
  over a stream socket, with the ``net.*`` fault sites injected in the send
  path (``net.delay`` sleeps, ``net.drop`` swallows the frame,
  ``net.duplicate`` sends it twice, ``net.reorder`` holds it back one
  frame, ``net.disconnect`` closes the socket mid-conversation). Every
  failure is deterministic and replayable — a spec fires on the site's
  invocation ordinal or on the frame's message key, never on wall-clock.
* :class:`ChunkAssembler` — exactly-once assembly of episode chunks keyed
  by the idempotence key ``(seed, epoch, episode, chunk)``. Reconnect-and-
  resend after a drop is safe by construction: a chunk that already landed
  is acknowledged and discarded (``dup``), an episode that already
  assembled never assembles twice, and assembly concatenates in CHUNK
  order regardless of arrival order, so the assembled bytes are bitwise
  identical to in-process production.
* :class:`HostHealth` — heartbeat/lease registry replacing the in-process
  ``WalkEngine.alive`` probe as the store watchdog's producer-liveness
  source. ``any_alive`` is the probe; ``describe`` names each host and its
  lease staleness, so a ``StoreStalled`` diagnostic says WHICH machine
  died. ``expired()`` is what the coordinator polls to reassign a dead
  host's unfinished episodes to survivors.
"""
from __future__ import annotations

import socket
import struct
import threading
import time
import zlib

import numpy as np

from repro.obs import counter_add
from repro.runtime.errors import TransportError
from repro.runtime.faults import fault_point

#: frame magic + protocol version; a peer speaking anything else fails the
#: very first recv instead of mis-parsing garbage lengths
MAGIC = b"EWT1"

#: frame header: magic, crc32(header_json + body), header_json length,
#: body length
_FRAME = struct.Struct("!4sIIQ")

#: refuse absurd frames instead of attempting a multi-GB recv on a torn
#: length field that happened to pass the magic check
MAX_BODY_BYTES = 1 << 31


def _dumps(msg: dict) -> bytes:
    # repr/eval-free minimal JSON: stdlib json keeps the dependency surface
    # at zero and the headers are tiny (the payload rides in the body)
    import json
    return json.dumps(msg, separators=(",", ":")).encode()


def _loads(blob: bytes) -> dict:
    import json
    return json.loads(blob.decode())


def pack_frame(msg: dict, body: bytes = b"") -> bytes:
    hdr = _dumps(msg)
    crc = zlib.crc32(body, zlib.crc32(hdr))
    return _FRAME.pack(MAGIC, crc, len(hdr), len(body)) + hdr + body


class FramedSocket:
    """One message-framed connection end.

    ``send(msg, body, key=..., inject=True)`` runs the ``net.*`` fault
    sites with the given invocation key before/while writing — injection is
    opt-in PER SEND so that only the deterministic chunk stream consumes
    fault ordinals (control traffic like heartbeats and acks is timing-
    dependent and would make ``at=N`` specs non-replayable). ``recv()``
    verifies length and checksum and raises :class:`TransportError` on a
    torn or corrupt frame, ``ConnectionError`` on EOF. Counters
    (`frames_sent`, `bytes_sent`, `frames_dropped`, `frames_duplicated`,
    ...) feed the transport stats row in ``BENCH_episode.json``.
    """

    def __init__(self, sock):
        self.sock = sock
        self._held: bytes | None = None      # net.reorder holds one frame
        self._send_mu = threading.Lock()
        self.frames_sent = 0
        self.bytes_sent = 0
        self.frames_dropped = 0
        self.frames_duplicated = 0
        self.frames_recv = 0
        self.bytes_recv = 0

    # --------------------------------------------------------------- send
    def send(self, msg: dict, body: bytes = b"", *, key=None,
             inject: bool = False) -> None:
        frame = pack_frame(msg, body)
        if inject:
            fault_point("net.delay", key)          # delay kind sleeps
            if fault_point("net.disconnect", key):
                self.close()
                raise TransportError(f"injected disconnect (key={key!r})")
            if fault_point("net.drop", key):
                self.frames_dropped += 1
                counter_add("transport.frames_dropped")
                return                             # the wire ate it
            dup = fault_point("net.duplicate", key)
            reorder = fault_point("net.reorder", key)
        else:
            dup = reorder = False
        with self._send_mu:
            if reorder and self._held is None:
                self._held = frame                 # goes out AFTER the next
                return
            self._sendall(frame)
            if dup:
                self.frames_duplicated += 1
                counter_add("transport.frames_duplicated")
                self._sendall(frame)
            if self._held is not None:
                held, self._held = self._held, None
                self._sendall(held)

    def _sendall(self, frame: bytes) -> None:
        try:
            self.sock.sendall(frame)
        except OSError as e:
            raise TransportError(f"send failed: {e}") from e
        self.frames_sent += 1
        self.bytes_sent += len(frame)
        # process-wide mirrors: per-connection ints above stay the canonical
        # per-socket view (aggregated by RemoteEpisodeServer.transport_stats);
        # the registry counters are the cross-connection totals
        counter_add("transport.frames_sent")
        counter_add("transport.bytes_sent", len(frame))

    # --------------------------------------------------------------- recv
    def _read_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            try:
                got = self.sock.recv(min(1 << 20, n - len(buf)))
            except OSError as e:
                raise TransportError(f"recv failed: {e}") from e
            if not got:
                raise ConnectionError(
                    f"peer closed mid-frame ({len(buf)}/{n} bytes)")
            buf += got
        return bytes(buf)

    def recv(self) -> tuple[dict, bytes]:
        head = self._read_exact(_FRAME.size)
        magic, crc, hdr_len, body_len = _FRAME.unpack(head)
        if magic != MAGIC:
            raise TransportError(f"bad frame magic {magic!r}")
        if body_len > MAX_BODY_BYTES:
            raise TransportError(f"absurd body length {body_len}")
        hdr = self._read_exact(hdr_len)
        body = self._read_exact(body_len)
        if zlib.crc32(body, zlib.crc32(hdr)) != crc:
            raise TransportError("frame checksum mismatch")
        self.frames_recv += 1
        self.bytes_recv += _FRAME.size + hdr_len + body_len
        counter_add("transport.frames_recv")
        counter_add("transport.bytes_recv", _FRAME.size + hdr_len + body_len)
        return _loads(hdr), body

    def close(self) -> None:
        # shutdown first: close() alone does not reliably wake another
        # thread blocked in recv() on the same socket
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass

    def stats(self) -> dict:
        return {"frames_sent": self.frames_sent,
                "bytes_sent": self.bytes_sent,
                "frames_dropped": self.frames_dropped,
                "frames_duplicated": self.frames_duplicated,
                "frames_recv": self.frames_recv,
                "bytes_recv": self.bytes_recv}


# --------------------------------------------------------------------------
# chunk payload encoding: dtype/shape in the header, raw bytes in the body
# --------------------------------------------------------------------------
def encode_pairs(pairs: np.ndarray) -> tuple[dict, bytes]:
    a = np.ascontiguousarray(pairs)
    return {"dtype": a.dtype.str, "shape": list(a.shape)}, a.tobytes()


def decode_pairs(meta: dict, body: bytes) -> np.ndarray:
    a = np.frombuffer(body, dtype=np.dtype(meta["dtype"]))
    return a.reshape(meta["shape"])


class ChunkAssembler:
    """Exactly-once chunk→episode assembly.

    Every chunk carries the idempotence key ``(seed, epoch, episode,
    chunk)`` plus the episode's total chunk count. :meth:`add` returns
    ``(dup, assembled)``: ``dup`` is True when this exact chunk (or its
    whole episode) already landed — the caller acks and discards — and
    ``assembled`` is the episode's full pair array exactly once, on the
    call that completed it. Arrival order is irrelevant: assembly
    concatenates in chunk order, so duplicated/reordered/resent deliveries
    produce bitwise-identical episodes (property-tested under random
    interleavings).
    """

    def __init__(self):
        self._mu = threading.Lock()
        #: (seed, epoch, episode) -> {chunk: pairs}
        self._parts: dict[tuple, dict[int, np.ndarray]] = {}
        self._nchunks: dict[tuple, int] = {}
        self._complete: set[tuple] = set()
        self.dup_chunks = 0
        self.chunks_applied = 0

    def add(self, seed: int, epoch: int, episode: int, chunk: int,
            nchunks: int, pairs: np.ndarray):
        ek = (seed, epoch, episode)
        if not (0 <= chunk < nchunks):
            raise TransportError(
                f"chunk index {chunk} out of range for {nchunks} chunks "
                f"(episode {ek})")
        with self._mu:
            if ek in self._complete:
                self.dup_chunks += 1
                return True, None
            want = self._nchunks.setdefault(ek, nchunks)
            if want != nchunks:
                raise TransportError(
                    f"episode {ek}: chunk count changed {want} -> {nchunks}")
            parts = self._parts.setdefault(ek, {})
            if chunk in parts:
                self.dup_chunks += 1
                return True, None
            parts[chunk] = pairs
            self.chunks_applied += 1
            if len(parts) < nchunks:
                return False, None
            # complete: assemble in CHUNK order, free the parts
            orderd = [parts[c] for c in range(nchunks)]
            del self._parts[ek]
            self._complete.add(ek)
        assembled = (orderd[0] if len(orderd) == 1
                     else np.concatenate(orderd, axis=0))
        return False, assembled

    def complete(self, seed: int, epoch: int, episode: int) -> bool:
        with self._mu:
            return (seed, epoch, episode) in self._complete

    def forget_epoch(self, seed: int, epoch: int) -> None:
        """Release bookkeeping for a fully-consumed epoch."""
        with self._mu:
            for d in (self._parts, self._nchunks):
                for k in [k for k in d if k[0] == seed and k[1] == epoch]:
                    del d[k]
            self._complete = {k for k in self._complete
                              if not (k[0] == seed and k[1] == epoch)}


class HostHealth:
    """Heartbeat/lease registry for remote producer hosts.

    A host is ``alive`` while its last heartbeat is younger than
    ``lease_s``. :meth:`any_alive` is the store-watchdog probe (True while
    no host has registered yet — unknown is not dead); :meth:`expired`
    returns hosts whose lease has lapsed since the last call site marked
    them (the coordinator's reassignment trigger); :meth:`describe` renders
    the per-host state for ``StoreStalled`` diagnostics.
    """

    def __init__(self, lease_s: float = 5.0):
        self.lease_s = lease_s
        self._mu = threading.Lock()
        self._last: dict[str, float] = {}       # host -> last beat (monotonic)
        self._dead: set[str] = set()            # marked by mark_dead()

    def beat(self, host: str) -> None:
        with self._mu:
            self._last[host] = time.monotonic()
            self._dead.discard(host)            # a beating host is not dead

    def alive(self, host: str) -> bool:
        with self._mu:
            t = self._last.get(host)
            if t is None or host in self._dead:
                return False
            return time.monotonic() - t < self.lease_s

    def hosts(self) -> list[str]:
        with self._mu:
            return sorted(self._last)

    def any_alive(self) -> bool:
        with self._mu:
            if not self._last:
                return True                     # nobody registered yet
            now = time.monotonic()
            return any(h not in self._dead and now - t < self.lease_s
                       for h, t in self._last.items())

    def expired(self) -> list[str]:
        """Hosts whose lease has lapsed and that are not yet marked dead."""
        with self._mu:
            now = time.monotonic()
            return sorted(h for h, t in self._last.items()
                          if h not in self._dead and now - t >= self.lease_s)

    def mark_dead(self, host: str) -> None:
        with self._mu:
            self._dead.add(host)

    def describe(self) -> str:
        with self._mu:
            if not self._last:
                return "no producer hosts registered"
            now = time.monotonic()
            bits = []
            for h in sorted(self._last):
                age = now - self._last[h]
                if h in self._dead or age >= self.lease_s:
                    bits.append(f"{h}: DEAD (last heartbeat {age:.1f}s ago, "
                                f"lease {self.lease_s:.1f}s)")
                else:
                    bits.append(f"{h}: alive ({age:.1f}s ago)")
            return "; ".join(bits)

    def snapshot(self) -> dict:
        with self._mu:
            now = time.monotonic()
            return {h: {"last_beat_age_s": now - t,
                        "alive": h not in self._dead and now - t < self.lease_s}
                    for h, t in self._last.items()}
