"""Shared failure vocabulary for the fault-tolerant runtime.

Every layer raises (and catches) these instead of ad-hoc RuntimeErrors, so
recovery logic can be written once: a ``CorruptEpisodeError`` is retriable
by re-walking the episode, a ``StoreStalled`` names exactly what was
blocked and why, a ``DeadlineExceeded``/``Overloaded`` is a per-request
serving outcome rather than a process failure.
"""
from __future__ import annotations


class InjectedFault(RuntimeError):
    """Raised by a ``crash`` fault spec firing at a fault point.

    Deliberately a distinct type: tests and CI chaos legs assert that a
    failure was the injected one and not an incidental bug."""

    def __init__(self, site: str, key=None):
        self.site = site
        self.key = key
        super().__init__(f"injected fault at {site!r}"
                         + (f" key={key!r}" if key is not None else ""))


class StoreStalled(RuntimeError):
    """A sample-store wait loop gave up: the producer died or the stall
    deadline passed with no store progress.

    Carries the diagnostics the old silent ``_cv.wait(60.0)`` spin threw
    away: which key the waiter was blocked on, what was resident at the
    time, and whether the producer looked alive."""

    def __init__(self, op: str, key, *, resident, producer_alive,
                 waited_s: float, producer_info: str | None = None):
        self.op = op
        self.key = key
        self.resident = tuple(resident)
        self.producer_alive = producer_alive
        self.producer_info = producer_info
        self.waited_s = waited_s
        alive = ("unknown" if producer_alive is None
                 else "alive" if producer_alive else "DEAD")
        super().__init__(
            f"sample store stalled in {op} waiting on {key!r} "
            f"({waited_s:.1f}s without progress); resident episodes: "
            f"{sorted(self.resident)!r}; producer: {alive}"
            + (f" [{producer_info}]" if producer_info else ""))


class TransportError(RuntimeError):
    """A transport-level failure: torn/corrupt frame, injected disconnect,
    ack timeout, or a peer that vanished mid-conversation. Retriable by
    reconnect-and-resend — the idempotence keys on every episode chunk make
    redelivery exactly-once at the store."""


class CorruptEpisodeError(RuntimeError):
    """An episode payload failed its integrity check (short file, checksum
    mismatch). Retriable: the ``(seed, epoch, episode, chunk)`` RNG keying
    means the episode can be re-walked bitwise-identically."""

    def __init__(self, key, path: str, reason: str):
        self.key = key
        self.path = path
        self.reason = reason
        super().__init__(f"episode {key!r} corrupt at {path}: {reason}")


class DeadlineExceeded(RuntimeError):
    """A serving request's deadline passed before it was served."""


class Overloaded(RuntimeError):
    """A serving request was shed at admission because the queue was full."""
