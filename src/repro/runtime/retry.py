"""Bounded retry with exponential backoff.

The walk engine keys every chunk's RNG stream by
``(seed, epoch, episode, chunk)``, so replaying a failed unit of work
produces bitwise-identical output — retry is semantics-preserving by
construction (test-gated in ``tests/test_runtime.py``). This module is the
one retry-loop implementation, so attempt accounting and backoff behave
the same at every call site.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``attempts`` total tries (1 = no retry); backoff before try i is
    ``backoff_s * mult**(i-1)`` seconds."""

    attempts: int = 3
    backoff_s: float = 0.05
    mult: float = 2.0
    retry_on: tuple = (Exception,)

    def delays(self):
        d = self.backoff_s
        for _ in range(max(0, self.attempts - 1)):
            yield d
            d *= self.mult


def call_with_retry(fn, *args, policy: RetryPolicy = RetryPolicy(),
                    on_retry=None, **kwargs):
    """Run ``fn(*args, **kwargs)``, retrying per ``policy``.

    ``on_retry(attempt, exc)`` is called before each backoff sleep (attempt
    is the 1-based number of the try that just failed) — callers log there.
    The final failure re-raises the last exception unchanged, so callers
    see the real error, not a wrapper."""
    attempts = max(1, policy.attempts)
    delays = policy.delays()
    for attempt in range(1, attempts + 1):
        try:
            return fn(*args, **kwargs)
        except policy.retry_on as e:  # noqa: PERF203 — the retry loop
            if attempt >= attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(next(delays))
