"""Bounded retry with exponential backoff, jitter, and elapsed caps.

The walk engine keys every chunk's RNG stream by
``(seed, epoch, episode, chunk)``, so replaying a failed unit of work
produces bitwise-identical output — retry is semantics-preserving by
construction (test-gated in ``tests/test_runtime.py``). This module is the
one retry-loop implementation, so attempt accounting and backoff behave
the same at every call site.

Jitter exists for the failover path: when the episode server dies, every
remote producer notices within one ack timeout and, without jitter, they
all reconnect in lockstep — a thundering herd against the restarted
coordinator. ``jitter`` spreads each delay by a deterministic-seedable
fraction (seed it from the host name: replayable per host, decorrelated
across hosts). ``max_elapsed_s`` turns "retry N times" into "retry for a
grace window" — the producer's ``--server-grace-s`` outage budget — and
``attempts=None`` makes the window the only bound.
"""
from __future__ import annotations

import dataclasses
import random
import time


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``attempts`` total tries (1 = no retry; ``None`` = unbounded, cap
    with ``max_elapsed_s``); backoff before try i is
    ``backoff_s * mult**(i-1)`` seconds, clamped to ``max_backoff_s`` and
    spread by ``±jitter`` (a fraction of the delay, deterministic per
    ``delays(seed=...)``). ``max_elapsed_s`` stops retrying — the last
    error re-raises — once that many seconds have passed since the first
    try."""

    attempts: int | None = 3
    backoff_s: float = 0.05
    mult: float = 2.0
    max_backoff_s: float | None = None
    jitter: float = 0.0
    max_elapsed_s: float | None = None
    retry_on: tuple = (Exception,)

    def delays(self, seed: int | None = None):
        """Yield the backoff delay before each retry. With ``jitter`` the
        stream is randomized but fully determined by ``seed`` — two
        producers seeded differently desynchronize, one producer replays
        identically."""
        rng = random.Random(seed) if self.jitter else None
        d = self.backoff_s
        i = 0
        while self.attempts is None or i < max(0, self.attempts - 1):
            delay = d if self.max_backoff_s is None \
                else min(d, self.max_backoff_s)
            if rng is not None:
                delay *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
            yield max(0.0, delay)
            d *= self.mult
            i += 1


def call_with_retry(fn, *args, policy: RetryPolicy = RetryPolicy(),
                    on_retry=None, seed: int | None = None, **kwargs):
    """Run ``fn(*args, **kwargs)``, retrying per ``policy``.

    ``on_retry(attempt, exc)`` is called before each backoff sleep (attempt
    is the 1-based number of the try that just failed) — callers log there.
    ``seed`` feeds the jitter stream (see :meth:`RetryPolicy.delays`).
    The final failure re-raises the last exception unchanged, so callers
    see the real error, not a wrapper — whether attempts ran out or the
    ``max_elapsed_s`` window closed."""
    delays = policy.delays(seed=seed)
    t0 = time.monotonic()
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn(*args, **kwargs)
        except policy.retry_on as e:  # noqa: PERF203 — the retry loop
            if policy.attempts is not None and attempt >= max(1, policy.attempts):
                raise
            if (policy.max_elapsed_s is not None
                    and time.monotonic() - t0 >= policy.max_elapsed_s):
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(next(delays, policy.backoff_s))
