"""Deterministic fault-injection registry.

Stages of the runtime declare named **fault sites** by calling
:func:`fault_point` — e.g. ``walk.chunk`` before a chunk's walks are
generated, ``disk.write`` before an episode file is published,
``serve.shard`` inside a shard scan task. A :class:`FaultPlan` installed
via :func:`install_plan` (or the :func:`inject` context manager, or the
launchers' ``--inject`` flag) decides deterministically whether that
invocation crashes (:class:`~repro.runtime.errors.InjectedFault`), sleeps,
or asks the caller to corrupt its output.

Determinism: a spec fires on the N-th invocation of its site
(``at=N``, a per-site counter) and/or on an exact invocation key match
(``key=...`` — the same ``(epoch, episode, chunk)``-style tuples that key
the RNG streams), never on wall-clock or randomness, so a failure path
replays identically run after run.

Hot-path cost: with no plan installed ``fault_point`` is one module-level
``None`` check. Sites sit at episode/chunk/request granularity — never
per-sample — so the idle layer is free (gated by the ``faults_idle``
dataflow row in ``BENCH_episode.json``).

Spec string grammar (the CLI's ``--inject`` and ``FaultSpec.parse``)::

    site:kind[:opt=val]...
    kinds:  crash | delay | corrupt | fire
    opts:   at=N           fire on the N-th invocation of site (0-based)
            key=a/b/c      fire only when the invocation key == (a, b, c);
                           a trailing "/*" prefix-matches instead, e.g.
                           key=walker-0/* fires on that host's first
                           matching invocation whatever the rest of the key
                           (racy assignments stay killable deterministically)
            times=N|inf    firings before the spec is spent (default 1)
            delay=SECONDS  sleep length for kind=delay (default 0.05)

    walk.chunk:crash:at=5          crash the 6th chunk walked
    train.episode:crash:key=6/1    die right before training episode (6, 1)
    serve.shard:delay:key=1:delay=0.5:times=inf   shard 1 is always slow
    disk.write:corrupt:at=0        corrupt the first episode file written
    net.drop:fire:at=2             the 3rd frame sent vanishes on the wire
    net.disconnect:fire:at=5       the transport closes mid-conversation

``corrupt`` and ``fire`` are mechanically identical — the fault point
returns True and the CALLER implements the behaviour. ``corrupt`` names the
torn-output sites; ``fire`` is the generic signal used by sites whose
behaviour isn't a corruption (the ``net.*`` transport sites: the transport
drops / duplicates / reorders the frame or closes the socket when its site
fires — see ``repro.runtime.transport``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time

from repro.runtime.errors import InjectedFault

KINDS = ("crash", "delay", "corrupt", "fire")

#: canonical site names (informative, not enforced — new subsystems add
#: sites freely; tests use ad-hoc names). The ``net.*`` sites live inside
#: the episode transport's send path (keyed by the frame's message key);
#: ``producer.episode`` fires at the top of a remote producer's episode
#: loop, keyed by (host, epoch, episode) so a chaos plan can kill one
#: specific producer host.
SITES = ("walk.chunk", "store.put", "disk.write", "train.episode",
         "serve.shard", "net.drop", "net.delay", "net.duplicate",
         "net.reorder", "net.disconnect", "producer.episode")


def _key_str(key) -> str | None:
    if key is None:
        return None
    if isinstance(key, (tuple, list)):
        return "/".join(str(k) for k in key)
    return str(key)


@dataclasses.dataclass
class FaultSpec:
    """One deterministic fault: fire ``kind`` at ``site`` when the
    invocation ordinal and/or key match."""

    site: str
    kind: str
    at: int | None = None       # per-site invocation ordinal (0-based)
    key: str | None = None      # "/"-joined invocation key to match
    times: float = 1            # firings before spent (float("inf") = always)
    delay_s: float = 0.05

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.at is None and self.key is None:
            # neither ordinal nor key: fire on every invocation (bounded
            # by `times`, which defaults to 1 = first invocation only)
            self.at = 0 if self.times == 1 else None

    @classmethod
    def parse(cls, spec: str) -> "FaultSpec":
        parts = spec.split(":")
        if len(parts) < 2:
            raise ValueError(f"fault spec {spec!r}: want site:kind[:opt=val]")
        site, kind, kw = parts[0], parts[1], {}
        for opt in parts[2:]:
            if "=" not in opt:
                raise ValueError(f"fault spec option {opt!r}: want opt=val")
            name, val = opt.split("=", 1)
            if name == "at":
                kw["at"] = int(val)
            elif name == "key":
                kw["key"] = val
            elif name == "times":
                kw["times"] = float("inf") if val == "inf" else int(val)
            elif name == "delay":
                kw["delay_s"] = float(val)
            else:
                raise ValueError(f"fault spec {spec!r}: unknown option "
                                 f"{name!r} (at/key/times/delay)")
        return cls(site, kind, **kw)

    def matches(self, ordinal: int, key_s: str | None) -> bool:
        if self.times <= 0:
            return False
        if self.at is not None and ordinal != self.at:
            return False
        if self.key is not None:
            if self.key.endswith("/*"):
                if key_s is None or not key_s.startswith(self.key[:-1]):
                    return False
            elif key_s != self.key:
                return False
        return True


class FaultPlan:
    """A set of :class:`FaultSpec`\\ s plus per-site invocation counters.

    Thread-safe: fault points fire from walk workers, pipeline stages and
    serving threads concurrently; the counter handshake is locked so an
    ``at=N`` spec fires exactly once even under races."""

    def __init__(self, specs=()):
        self.specs = [s if isinstance(s, FaultSpec) else FaultSpec.parse(s)
                      for s in specs]
        self._counts: dict[str, int] = {}
        self._fired: list[tuple[str, str, object]] = []   # (site, kind, key)
        self._mu = threading.Lock()

    @property
    def fired(self) -> list:
        """(site, kind, key) log of every spec firing, in firing order."""
        with self._mu:
            return list(self._fired)

    def count(self, site: str) -> int:
        with self._mu:
            return self._counts.get(site, 0)

    def check(self, site: str, key=None) -> bool:
        """Advance ``site``'s counter; fire matching specs. Returns True if
        a ``corrupt`` or ``fire`` spec fired; raises/sleeps for
        crash/delay."""
        key_s = _key_str(key)
        with self._mu:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            todo = []
            for s in self.specs:
                if s.site == site and s.matches(n, key_s):
                    s.times -= 1
                    self._fired.append((site, s.kind, key))
                    todo.append(s)
        corrupt = False
        for s in todo:                     # outside the lock: may sleep/raise
            if s.kind == "delay":
                time.sleep(s.delay_s)
            elif s.kind in ("corrupt", "fire"):
                corrupt = True
            else:
                raise InjectedFault(site, key)
        return corrupt


# ------------------------------------------------------------------ registry
_PLAN: FaultPlan | None = None


def install_plan(plan: FaultPlan | None) -> None:
    """Install the process-wide plan (None = clear)."""
    global _PLAN
    _PLAN = plan


def clear_plan() -> None:
    install_plan(None)


def active_plan() -> FaultPlan | None:
    return _PLAN


def fault_point(site: str, key=None) -> bool:
    """Declare a fault site. No plan installed → immediate False (the
    no-op hot path). Returns True when a ``corrupt`` or ``fire`` spec
    fired; a ``crash`` spec raises :class:`InjectedFault`; ``delay``
    sleeps."""
    plan = _PLAN
    if plan is None:
        return False
    return plan.check(site, key)


@contextlib.contextmanager
def inject(*specs):
    """Scoped plan installation for tests::

        with inject("walk.chunk:crash:at=2") as plan:
            ...
        assert plan.fired
    """
    plan = FaultPlan(specs)
    prev = _PLAN
    install_plan(plan)
    try:
        yield plan
    finally:
        install_plan(prev)
