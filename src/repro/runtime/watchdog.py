"""Watchdog deadlines for producer/consumer wait loops.

The sample stores used to spin forever in ``_cv.wait(timeout=60.0)`` loops:
a walker thread dying without ``finish_epoch``/``abandon`` left the trainer
blocked silently, for good. :class:`Deadline` replaces those with loud
failure: a waiter periodically feeds it the store's progress version and a
producer-liveness probe, and it raises a diagnostics-carrying
:class:`~repro.runtime.errors.StoreStalled` when the producer is provably
dead or nothing has happened for ``timeout_s``.

The deadline is measured from the last **progress** event (any put / drop /
finish on the store), not from the start of the wait: a healthy-but-slow
pipeline never trips it, only a genuinely wedged one does.
"""
from __future__ import annotations

import time

from repro.runtime.errors import StoreStalled

#: wait-slice between liveness/deadline checks; condition notifies still
#: wake waiters immediately — this only bounds failure-detection latency
POLL_S = 0.25


class Deadline:
    """Stall watchdog for one wait loop.

    Parameters
    ----------
    timeout_s : seconds without store progress before ``StoreStalled``
        (None = no overall deadline; producer liveness still applies).
    op : description of the blocked operation ("get"/"put"/"episodes").
    key : the (epoch, episode) — or epoch — being waited on.
    producer : optional zero-arg liveness probe (e.g. ``WalkEngine.alive``
        or ``HostHealth.any_alive`` for remote producers); a False return
        while the waited-for work is still possible raises immediately — no
        point waiting out the deadline on a corpse.
    producer_info : optional zero-arg callable returning a human-readable
        producer description (e.g. ``HostHealth.describe``, naming which
        HOSTS are alive/dead and how stale their leases are) — attached to
        the ``StoreStalled`` so the diagnostic names the dead machine, not
        just "producer: DEAD".
    resident : zero-arg callable returning the store's resident keys, for
        the diagnostic.
    """

    def __init__(self, timeout_s: float | None, *, op: str, key,
                 producer=None, producer_info=None, resident=lambda: ()):
        self.timeout_s = timeout_s
        self.op = op
        self.key = key
        self.producer = producer
        self.producer_info = producer_info
        self.resident = resident
        self._t_progress = time.monotonic()
        self._version = None

    def wait_s(self) -> float:
        """The cv-wait / sleep slice to use before the next check."""
        if self.timeout_s is None:
            return POLL_S
        remaining = self.timeout_s - (time.monotonic() - self._t_progress)
        return max(0.001, min(POLL_S, remaining))

    def check(self, version=None, *, producer_done: bool = False) -> None:
        """Raise ``StoreStalled`` if stalled; otherwise note progress.

        version : the store's progress counter; any change resets the
            deadline clock.
        producer_done : True once the producer has legitimately finished
            (epoch done-marker seen) — suppresses the liveness raise so a
            normally-exited producer isn't mistaken for a crash.
        """
        now = time.monotonic()
        if version != self._version:
            self._version = version
            self._t_progress = now
            return
        alive = None
        if self.producer is not None and not producer_done:
            alive = bool(self.producer())
            if not alive:
                raise StoreStalled(self.op, self.key,
                                   resident=self.resident(),
                                   producer_alive=False,
                                   producer_info=self._info(),
                                   waited_s=now - self._t_progress)
        if (self.timeout_s is not None
                and now - self._t_progress >= self.timeout_s):
            raise StoreStalled(self.op, self.key, resident=self.resident(),
                               producer_alive=alive,
                               producer_info=self._info(),
                               waited_s=now - self._t_progress)

    def _info(self) -> str | None:
        if self.producer_info is None:
            return None
        try:
            return str(self.producer_info())
        except Exception as e:  # noqa: BLE001 — diagnostics must not mask
            return f"producer_info failed: {e!r}"
