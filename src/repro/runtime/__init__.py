"""Fault-tolerant runtime layer: deterministic fault injection, bounded
retry, and watchdog deadlines.

At the paper's deployment scale (3-minute epochs over ~300B edges on 40
GPUs, with decoupled CPU walk machines) worker death, partial episode
files and slow shards are routine events, not exceptions. This package is
the shared substrate every stage of the walk → store → partition → train →
serve path consults:

* :mod:`repro.runtime.faults` — a deterministic, seed-keyed fault-injection
  registry (``FaultPlan``). Stages call ``fault_point(site, key)`` at named
  sites (``walk.chunk``, ``store.put``, ``disk.write``, ``train.episode``,
  ``serve.shard``); an installed plan can crash, delay, or corrupt a
  specific invocation, so failure paths are unit-testable instead of
  theoretical. With no plan installed the check is a single module-level
  ``None`` test — free on the hot path.
* :mod:`repro.runtime.retry` — bounded retry with exponential backoff
  (``RetryPolicy`` / ``call_with_retry``). The walk engine's
  ``(seed, epoch, episode, chunk)`` RNG keying makes every retried unit of
  work bitwise-replayable, so retry is semantics-preserving by
  construction.
* :mod:`repro.runtime.watchdog` — ``Deadline`` helpers replacing silent
  infinite condition-variable waits with diagnostics-carrying
  ``StoreStalled`` failures.
* :mod:`repro.runtime.transport` — the process-boundary layer: length-
  prefixed checksummed message framing (``FramedSocket``) with the
  ``net.*`` fault sites in the send path, exactly-once chunk assembly
  keyed by ``(seed, epoch, episode, chunk)`` (``ChunkAssembler``), and the
  heartbeat/lease host registry (``HostHealth``) that lets stall
  diagnostics name the dead machine.
* :mod:`repro.runtime.errors` — the shared failure vocabulary
  (``InjectedFault``, ``StoreStalled``, ``TransportError``,
  ``CorruptEpisodeError``, ``DeadlineExceeded``, ``Overloaded``).
"""
from repro.runtime.errors import (CorruptEpisodeError, DeadlineExceeded,
                                  InjectedFault, Overloaded, StoreStalled,
                                  TransportError)
from repro.runtime.faults import (FaultPlan, FaultSpec, active_plan,
                                  clear_plan, fault_point, inject,
                                  install_plan)
from repro.runtime.retry import RetryPolicy, call_with_retry
from repro.runtime.transport import ChunkAssembler, FramedSocket, HostHealth
from repro.runtime.watchdog import Deadline

__all__ = [
    "ChunkAssembler", "CorruptEpisodeError", "Deadline", "DeadlineExceeded",
    "FaultPlan", "FaultSpec", "FramedSocket", "HostHealth", "InjectedFault",
    "Overloaded", "RetryPolicy", "StoreStalled", "TransportError",
    "active_plan", "call_with_retry", "clear_plan", "fault_point", "inject",
    "install_plan",
]
