"""Span tracer emitting Chrome trace-event JSON (Perfetto-loadable).

The tracer renders the paper's central overlap claim — walk machines,
the sample store, and the trainer saturated *simultaneously* — as an
actual timeline: one span per pipeline-stage unit of work, each on a
named track. Load the output of ``--trace FILE`` at https://ui.perfetto.dev
(or ``chrome://tracing``) and the stage overlap is directly visible.

Tracks are logical lanes mapped onto trace-event ``tid``s inside a single
synthetic process. The canonical pipeline lanes come first, in fixed
order (``walk``, ``build``, ``stage``, ``train``, ``store``, ``serve``);
dynamic lanes (one per walk-worker thread, one per remote producer host)
are appended as they first emit. ``thread_name``/``thread_sort_index``
metadata events pin names and order so every run renders the same way.

The module-level :func:`span` helper follows the same design rule as
``fault_point`` and the metrics helpers: with no tracer installed it is a
single ``None`` check returning a shared no-op context manager — zero
allocation on disabled hot paths.

Spans record wall-clock-anchored microseconds from a monotonic clock
(``perf_counter``) relative to tracer start. The event buffer is bounded
(``max_events``); past the cap events are counted in ``dropped`` rather
than grown without bound — a trace that silently eats the heap would be
a poor observability tool.
"""
from __future__ import annotations

import json
import threading
import time

# Canonical pipeline lanes, pre-registered in this order so every trace
# renders walk→build→stage→train top-to-bottom regardless of which stage
# emits first. Dynamic lanes (walk workers, producer hosts) follow.
PIPELINE_TRACKS = ("walk", "build", "stage", "train", "store", "serve")


class Tracer:
    """Thread-safe bounded recorder of complete ("X"), instant ("i") and
    counter ("C") trace events, serialized as Chrome trace-event JSON."""

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = max_events
        self.dropped = 0
        self._mu = threading.Lock()
        self._events: list[tuple] = []      # (ph, name, track, ts_us, dur_us, args)
        self._tracks: dict[str, int] = {}
        self._t0 = time.perf_counter()
        for t in PIPELINE_TRACKS:
            self._tracks[t] = len(self._tracks) + 1

    # ------------------------------------------------------------ plumbing
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks.setdefault(track, len(self._tracks) + 1)
        return tid

    def _push(self, ev: tuple) -> None:
        with self._mu:
            self._tid(ev[2])        # first emit on a dynamic lane names it
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    # ------------------------------------------------------------- emitters
    def add_span(self, name: str, track: str, t0_us: float, t1_us: float,
                 args: dict | None = None) -> None:
        """Record a complete span with explicit endpoints (in tracer
        microseconds, see :meth:`now_us`) — for spans whose start was
        observed before the duration was known (e.g. first-chunk to
        last-chunk arrival of a remote episode)."""
        self._push(("X", name, track, t0_us, max(0.0, t1_us - t0_us), args))

    def span(self, name: str, track: str = "train",
             args: dict | None = None) -> "_Span":
        return _Span(self, name, track, args)

    def instant(self, name: str, track: str = "train",
                args: dict | None = None) -> None:
        self._push(("i", name, track, self.now_us(), 0.0, args))

    def counter(self, name: str, value) -> None:
        """Counter-track sample: Perfetto renders these as a value-over-
        time graph (store residency, serve queue depth)."""
        self._push(("C", name, name, self.now_us(), 0.0, {"value": value}))

    # ---------------------------------------------------------------- output
    def event_count(self) -> int:
        with self._mu:
            return len(self._events)

    def to_json(self) -> dict:
        with self._mu:
            events = list(self._events)
            tracks = dict(self._tracks)
        out = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
                "args": {"name": "repro pipeline"}}]
        for track, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
                        "args": {"name": track}})
            out.append({"ph": "M", "pid": 1, "tid": tid,
                        "name": "thread_sort_index",
                        "args": {"sort_index": tid}})
        for ph, name, track, ts, dur, args in events:
            ev = {"ph": ph, "pid": 1, "tid": tracks.get(track, 0),
                  "name": name, "ts": ts}
            if ph == "X":
                ev["dur"] = dur
            elif ph == "i":
                ev["s"] = "t"            # instant scope: thread
            if args:
                ev["args"] = args
            out.append(ev)
        meta = {"dropped_events": self.dropped}
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": meta}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


class _Span:
    """Context manager recording one complete event on exit."""

    __slots__ = ("_tr", "_name", "_track", "_args", "_t0")

    def __init__(self, tracer, name, track, args):
        self._tr = tracer
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self):
        self._t0 = self._tr.now_us()
        return self

    def __exit__(self, *exc):
        self._tr.add_span(self._name, self._track, self._t0,
                          self._tr.now_us(), self._args)
        return False


class _NoopSpan:
    """Shared do-nothing context manager returned by the module-level
    helpers when no tracer is installed — one instance for the whole
    process, so a disabled ``with span(...)`` allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()

# --------------------------------------------------------------- module state
_TRACER: Tracer | None = None


def set_tracer(tracer: Tracer | None) -> None:
    global _TRACER
    _TRACER = tracer


def tracer() -> Tracer | None:
    return _TRACER


# ------------------------------------------------------- hot-path helpers
# Same rule as fault_point / metrics: disabled == one None check.
def span(name: str, track: str = "train", args: dict | None = None):
    tr = _TRACER
    if tr is None:
        return _NOOP
    return _Span(tr, name, track, args)


def instant(name: str, track: str = "train", args: dict | None = None) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.instant(name, track, args)


def trace_counter(name: str, value) -> None:
    tr = _TRACER
    if tr is None:
        return
    tr.counter(name, value)
