"""Process-wide telemetry registry: counters, gauges, bounded histograms.

One registry serves the whole walk → store → partition → train → serve
path. Components never hold a registry reference; they call the
module-level helpers (:func:`counter_add`, :func:`gauge_set`,
:func:`observe`) at named metrics, exactly the way fault sites call
``fault_point``. The design rule is the same one ``repro.runtime.faults``
established: with no registry installed every helper is a single
module-level ``None`` check — no allocation, no lock, no dict lookup — so
the idle cost of fully-instrumented hot paths is provably free (gated by
the ``obs_idle`` dataflow row in ``BENCH_episode.json`` and a
zero-allocation test).

Three metric kinds:

* :class:`Counter` — monotonically increasing, thread-safe ``add``.
* :class:`Gauge` — last-write-wins instantaneous value (queue depth,
  resident episodes).
* :class:`Histogram` — bounded-memory distribution with **exact**
  ``count``/``sum``/``min``/``max`` always, and exact p50/p95/p99 while
  the observation count is within the reservoir capacity; past the
  capacity the percentiles come from uniform reservoir sampling
  (Vitter's Algorithm R, deterministic per-histogram RNG so two runs of
  the same stream summarize identically).

Beyond owned metrics, a registry accepts **sources**: zero-arg callables
returning a dict, polled at :meth:`Registry.snapshot` time. This is how
pre-existing per-component counter surfaces (``MicroBatcher`` stats, the
transport's aggregated frame counters, ``HostHealth`` leases, the PS
baseline's structural counters) surface through the one registry without
duplicated bookkeeping: the component keeps its canonical counters and the
registry reads them when asked, so ``metrics.jsonl`` and
``diagnostics.json`` see every surface in one snapshot.
"""
from __future__ import annotations

import math
import random
import threading
import time


class Counter:
    """Monotonic counter. ``add`` is thread-safe (the GIL does not make
    ``+=`` on an attribute atomic — the read/add/store can interleave)."""

    __slots__ = ("_mu", "_value")

    def __init__(self):
        self._mu = threading.Lock()
        self._value = 0

    def add(self, n=1) -> None:
        with self._mu:
            self._value += n

    @property
    def value(self):
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v           # single store: atomic enough for a gauge


class Histogram:
    """Bounded-memory value distribution.

    ``count``/``sum``/``min``/``max`` are exact for the whole stream.
    Percentiles are computed over a reservoir of at most ``cap`` values:
    exact (nearest-rank over every observation) while ``count <= cap``,
    and a uniform sample of the stream after that (Algorithm R — each
    observation ends up in the reservoir with probability ``cap/count``).
    The replacement RNG is seeded per-histogram, so identical observation
    streams produce identical summaries run after run.
    """

    def __init__(self, cap: int = 4096, seed: int = 0):
        assert cap >= 1
        self.cap = cap
        self._mu = threading.Lock()
        self._values: list[float] = []
        self._rng = random.Random(seed)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v) -> None:
        v = float(v)
        with self._mu:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if len(self._values) < self.cap:
                self._values.append(v)
            else:
                j = self._rng.randrange(self.count)
                if j < self.cap:
                    self._values[j] = v

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (the inverted-CDF definition: the
        smallest reservoir value with at least ``q``% of values at or
        below it). NaN when nothing was observed."""
        with self._mu:
            vals = sorted(self._values)
        if not vals:
            return math.nan
        idx = max(0, math.ceil(q / 100.0 * len(vals)) - 1)
        return vals[min(idx, len(vals) - 1)]

    def summary(self) -> dict:
        with self._mu:
            vals = sorted(self._values)
            count, total = self.count, self.sum
            lo, hi = self.min, self.max
        out = {"count": count, "sum": total,
               "min": (None if count == 0 else lo),
               "max": (None if count == 0 else hi),
               "mean": (total / count if count else None),
               "exact": count <= len(vals) or count == 0}
        for q, name in ((50, "p50"), (95, "p95"), (99, "p99")):
            if not vals:
                out[name] = None
            else:
                idx = max(0, math.ceil(q / 100.0 * len(vals)) - 1)
                out[name] = vals[min(idx, len(vals) - 1)]
        return out


class Registry:
    """Thread-safe name → metric map plus snapshot-time sources.

    ``counter``/``gauge``/``histogram`` are get-or-create: the first call
    at a name fixes its kind (a name reused as a different kind raises).
    ``register_source(name, fn)`` attaches a zero-arg callable returning a
    dict, polled at snapshot time — the collector hook pre-existing
    counter surfaces use to read through the registry.
    """

    def __init__(self):
        self._mu = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}
        self._sources: dict[str, object] = {}
        self._t0 = time.monotonic()

    def _get_or_create(self, table, name, make, kind):
        m = table.get(name)          # lock-free fast path (dict read is safe)
        if m is not None:
            return m
        with self._mu:
            for other_kind, other in (("counter", self._counters),
                                      ("gauge", self._gauges),
                                      ("histogram", self._hists)):
                if other is not table and name in other:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{other_kind}, not {kind}")
            return table.setdefault(name, make())

    def counter(self, name: str) -> Counter:
        return self._get_or_create(self._counters, name, Counter, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(self._gauges, name, Gauge, "gauge")

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        return self._get_or_create(self._hists, name,
                                   lambda: Histogram(cap=cap), "histogram")

    # ------------------------------------------------------------- sources
    def register_source(self, name: str, fn) -> None:
        """Attach a snapshot-time collector (last registration at a name
        wins — a relaunched component simply replaces its predecessor)."""
        with self._mu:
            self._sources[name] = fn

    def unregister_source(self, name: str) -> None:
        with self._mu:
            self._sources.pop(name, None)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> dict:
        """One JSON-serializable view of everything: owned metrics plus
        every registered source, polled now. Sources run outside the
        registry lock (they may take their component's own locks)."""
        with self._mu:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
            sources = dict(self._sources)
        snap = {
            "ts": time.time(),
            "elapsed_s": time.monotonic() - self._t0,
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.summary() for k, h in sorted(hists.items())},
        }
        src = {}
        for name, fn in sorted(sources.items()):
            try:
                src[name] = fn()
            except Exception as e:   # noqa: BLE001 — a dying component must
                src[name] = {"error": f"{type(e).__name__}: {e}"}  # not kill
        snap["sources"] = src                                      # snapshots
        return snap


# ----------------------------------------------------------------- registry
_REG: Registry | None = None


def enable(registry: Registry | None = None) -> Registry:
    """Install the process-wide registry (creating one when not given)
    and return it. Until this is called every hot-path helper is a no-op."""
    global _REG
    _REG = registry if registry is not None else Registry()
    return _REG


def disable() -> None:
    global _REG
    _REG = None


def active() -> Registry | None:
    return _REG


def enabled() -> bool:
    return _REG is not None


# ------------------------------------------------------- hot-path helpers
# The fault_point design rule: disabled == one module-level None check.
def counter_add(name: str, n=1) -> None:
    reg = _REG
    if reg is None:
        return
    reg.counter(name).add(n)


def gauge_set(name: str, v) -> None:
    reg = _REG
    if reg is None:
        return
    reg.gauge(name).set(v)


def observe(name: str, v) -> None:
    reg = _REG
    if reg is None:
        return
    reg.histogram(name).observe(v)


def register_source(name: str, fn) -> None:
    reg = _REG
    if reg is None:
        return
    reg.register_source(name, fn)


def unregister_source(name: str) -> None:
    reg = _REG
    if reg is None:
        return
    reg.unregister_source(name)
