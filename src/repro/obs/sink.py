"""Metrics sink: periodic registry snapshots to ``metrics.jsonl``.

``--metrics-dir DIR`` on the launchers attaches a :class:`MetricsWriter`:
a daemon thread appending one JSON line per interval — the full registry
snapshot, sources included — to ``DIR/metrics.jsonl``, plus a final
``metrics_summary.json`` written at close. The jsonl is a time series
(each line carries ``ts``/``elapsed_s``); the summary is the last word.

The writer never touches hot paths — it only *reads* the registry on its
own thread — and it swallows write errors (a full disk must not kill a
training run; the error is kept and reported at close).
"""
from __future__ import annotations

import json
import os
import threading

from .metrics import Registry


class MetricsWriter:
    def __init__(self, registry: Registry, out_dir: str,
                 interval_s: float = 5.0):
        self.registry = registry
        self.out_dir = out_dir
        self.interval_s = max(0.05, float(interval_s))
        self.path = os.path.join(out_dir, "metrics.jsonl")
        self.summary_path = os.path.join(out_dir, "metrics_summary.json")
        self.lines_written = 0
        self.last_error: str | None = None
        os.makedirs(out_dir, exist_ok=True)
        open(self.path, "w").close()       # truncate: one run, one series
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, name="metrics-writer",
                                        daemon=True)
        self._thread.start()

    def _write_line(self) -> None:
        try:
            snap = self.registry.snapshot()
            with open(self.path, "a") as f:
                f.write(json.dumps(snap, default=str,
                                   separators=(",", ":")) + "\n")
            self.lines_written += 1
        except Exception as e:  # noqa: BLE001 — sink errors must not kill runs
            self.last_error = f"{type(e).__name__}: {e}"

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._write_line()

    def close(self) -> None:
        """Stop the thread, append one last line, write the summary."""
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._write_line()
        try:
            snap = self.registry.snapshot()
            snap["lines_written"] = self.lines_written
            if self.last_error:
                snap["sink_error"] = self.last_error
            with open(self.summary_path, "w") as f:
                json.dump(snap, f, indent=2, default=str)
        except Exception as e:  # noqa: BLE001
            self.last_error = f"{type(e).__name__}: {e}"
