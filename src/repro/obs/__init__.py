"""Unified telemetry: metrics registry + span tracer + sinks.

Disabled by default. ``enable()`` installs the process-wide registry;
``set_tracer(Tracer())`` installs the span recorder. Every hot-path
helper (``counter_add``/``gauge_set``/``observe``/``span``/``instant``/
``trace_counter``) is a single module-level ``None`` check while
disabled — the ``fault_point`` design rule — so instrumented code pays
nothing until a launcher opts in via ``--metrics-dir`` / ``--trace``.
"""
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    Registry,
    active,
    counter_add,
    disable,
    enable,
    enabled,
    gauge_set,
    observe,
    register_source,
    unregister_source,
)
from .sink import MetricsWriter  # noqa: F401
from .trace import (  # noqa: F401
    PIPELINE_TRACKS,
    Tracer,
    instant,
    set_tracer,
    span,
    trace_counter,
    tracer,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "MetricsWriter", "Tracer",
    "PIPELINE_TRACKS",
    "enable", "disable", "active", "enabled",
    "counter_add", "gauge_set", "observe",
    "register_source", "unregister_source",
    "set_tracer", "tracer", "span", "instant", "trace_counter",
]
