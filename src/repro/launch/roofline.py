"""Roofline terms from a compiled dry-run artifact (no real hardware).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (DCN for the "pod" axis is slower; collectives whose
replica groups span pods are reported separately when detectable).

Methodology:
  * compute term   = per-device HLO FLOPs / peak  (cost_analysis runs on the
    post-SPMD per-device module, so no extra /chips)
  * memory term    = per-device HLO bytes accessed / HBM bw
  * collective term = Σ (result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute in the per-device
    module) / link bw. Result-shape bytes are a lower bound on the bytes a
    device moves for that op (ring all-reduce moves ~2x); we report the raw
    sum and note the factor.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
VMEM_BYTES = 16 * 2**20      # on-chip vector memory per core (~16 MB); the
                             # budget kernels.ops sizes fused-kernel scratch
                             # against (compiled reality to be tightened on
                             # real TPU — see ROADMAP)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[2,4096,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(_COLLECTIVES)
    + r")[ (]")
# tuple-result collectives:  = (bf16[..], bf16[..]) all-to-all(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")[ (]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, bucketed by op kind."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dt, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dt, dims)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> dict:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    return terms


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference).

    Embedding arch: the "model" touched per sample is two d-vectors per
    (pair x (1 + negatives)) — 6*2d per trained pair, not 6*N_total."""
    if getattr(cfg, "arch_type", "") == "embedding":
        # samples per episode: filled in by the caller via shape.global_batch?
        # use block geometry: P^2 * k * block_cap samples
        samples = 256 * 256 * cfg.subparts * cfg.block_cap
        return 6.0 * 2 * cfg.dim * (1 + cfg.negatives) * samples
    n_active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Forward-active parameter count (MoE: top_k + shared experts only)."""
    if getattr(cfg, "arch_type", "") == "embedding":
        return 2.0 * cfg.num_nodes * cfg.dim
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd = cfg.resolved_head_dim
    total = 2 * V * d  # embed + head
    types = cfg.layer_types()
    for i in range(L):
        if types[i] == "A":
            if cfg.mla:
                qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
                total += (d * cfg.q_lora_rank
                          + cfg.q_lora_rank * cfg.num_heads * qk
                          + d * cfg.kv_lora_rank + d * cfg.qk_rope_head_dim
                          + cfg.kv_lora_rank * cfg.num_heads
                          * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                          + cfg.num_heads * cfg.v_head_dim * d)
            else:
                total += d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) \
                    + cfg.num_heads * hd * d
        else:
            di = cfg.d_inner_ssm
            total += d * (2 * di + 2 * cfg.ssm_state + cfg.ssm_heads) + di * d
        if cfg.is_moe_layer(i):
            active_e = cfg.moe_top_k + cfg.moe_num_shared
            total += 3 * d * cfg.moe_d_ff * active_e + d * cfg.moe_num_experts
        elif cfg.d_ff:
            total += 3 * d * cfg.d_ff
    if cfg.is_encdec:
        total += cfg.encoder_layers * (
            d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
            + cfg.num_heads * hd * d + 3 * d * cfg.d_ff)
        total += L * (d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
                      + cfg.num_heads * hd * d)  # cross-attention
    return float(total)
