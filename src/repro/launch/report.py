"""Render §Dry-run and §Roofline markdown tables from the dry-run JSONs.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir experiments]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import json
import os

GB = 1 << 30


def _fmt_bytes(b):
    return f"{b / GB:.2f}"


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | compile s | args GiB/dev | temp GiB/dev | "
        "flops/dev | bytes/dev | coll B/dev | top collective |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | "
                         f"{'x'.join(map(str, r['mesh']))} | FAIL |||||| "
                         f"{r['error'][:40]} |")
            continue
        coll = r["collectives"]
        top = max((k for k in coll if k != "total"), key=lambda k: coll[k])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {'x'.join(map(str, r['mesh']))} "
            f"| {r['compile_s']:.0f} | {_fmt_bytes(r['memory']['argument_bytes'])} "
            f"| {_fmt_bytes(r['memory']['temp_bytes'])} "
            f"| {r['cost']['flops_per_device']:.2e} "
            f"| {r['cost']['bytes_per_device']:.2e} "
            f"| {coll['total']:.2e} | {top} ({coll[top]:.1e}) |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant "
        "| MODEL_FLOPS | useful ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if "error" in r:
            continue
        t = r["roofline"]
        hint = _hint(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} "
            f"| {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} "
            f"| **{t['dominant']}** | {r['model_flops']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} | {hint} |")
    return "\n".join(lines)


def _hint(r) -> str:
    dom = r["roofline"]["dominant"]
    shape = r["shape"]
    if dom == "memory":
        if shape in ("train_4k", "prefill_32k"):
            return ("avoid materialized f32 masks/activations; bf16 "
                    "end-to-end; fuse softmax path")
        return "shard the KV cache wider; reduce f32 staging"
    if dom == "collective":
        return ("resharding between layers — tighten param/activation "
                "specs; overlap a2a with expert compute")
    return "MXU-align tile shapes; raise arithmetic intensity"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    for fname, tag in (("dryrun_single.json", "single-pod 16x16 (256 chips)"),
                       ("dryrun_multipod.json", "multi-pod 2x16x16 (512 chips)")):
        path = os.path.join(args.dir, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            recs = json.load(f)
        ok = sum("error" not in r for r in recs)
        if args.section in ("all", "dryrun"):
            print(f"\n### Dry-run — {tag}: {ok}/{len(recs)} pass\n")
            print(dryrun_table(recs))
        if args.section in ("all", "roofline") and "single" in fname:
            print(f"\n### Roofline — {tag}\n")
            print(roofline_table(recs))


if __name__ == "__main__":
    main()
