"""Step builders: for an (arch, shape, mesh) triple produce the jit-able
step function, abstract inputs (ShapeDtypeStructs only — nothing allocated),
and input shardings. Used by the dry-run, the roofline report, and tests.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfgs
from repro.configs.shapes import InputShape
from repro.launch.mesh import data_axes_of
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.sharding.specs import batch_spec, param_shardings
from repro.train.optimizer import make_optimizer
from repro.train.serve_step import (cache_len_for, cache_shardings,
                                    cache_specs, make_decode_step,
                                    make_prefill_step)
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class BuiltStep:
    fn: object                 # callable to jit
    args: tuple                # abstract ShapeDtypeStructs
    in_shardings: tuple
    donate: tuple = ()
    meta: dict | None = None   # params for MODEL_FLOPS etc.


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))


def _batch_struct(cfg: ModelConfig, shape: InputShape):
    return cfgs.input_specs(cfg, shape)


def _batch_shardings(batch_struct, shape: InputShape, mesh):
    bs = batch_spec(shape.global_batch, mesh)

    def one(leaf):
        return NamedSharding(mesh, P(*bs, *((None,) * (len(leaf.shape) - 1))))
    return jax.tree.map(one, batch_struct)


def build_lm_step(cfg: ModelConfig, shape: InputShape, mesh) -> BuiltStep:
    data_axes = data_axes_of(mesh)
    cfg = cfgs.for_shape(cfg, shape)
    cfg = dataclasses.replace(cfg, tp_size=int(mesh.shape.get("model", 1)))
    params = _abstract_params(cfg)
    p_sh = param_shardings(params, mesh)

    if shape.kind == "train":
        step_fn, opt = make_train_step(cfg, mesh=mesh, data_axes=data_axes)
        opt_state = jax.eval_shape(opt.init, params)
        o_sh = param_shardings(opt_state, mesh)
        batch = _batch_struct(cfg, shape)
        b_sh = _batch_shardings(batch, shape, mesh)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return BuiltStep(
            fn=step_fn,
            args=(params, opt_state, step, batch),
            in_shardings=(p_sh, o_sh, NamedSharding(mesh, P()), b_sh),
            donate=(0, 1),
            meta={"cfg": cfg})

    if shape.kind == "prefill":
        clen = cache_len_for(cfg, shape.seq_len)
        fn = make_prefill_step(cfg, clen, mesh=mesh, data_axes=data_axes)
        batch = _batch_struct(cfg, shape)
        b_sh = _batch_shardings(batch, shape, mesh)
        return BuiltStep(fn=fn, args=(params, batch),
                         in_shardings=(p_sh, b_sh), meta={"cfg": cfg})

    # decode: one token against a seq_len cache
    clen = cache_len_for(cfg, shape.seq_len)
    fn = make_decode_step(cfg, mesh=mesh, data_axes=data_axes)
    caches = cache_specs(cfg, shape.global_batch, clen, params)
    c_sh = cache_shardings(caches, shape.global_batch, mesh)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_sh = NamedSharding(mesh, P(*batch_spec(shape.global_batch, mesh), None))
    return BuiltStep(fn=fn, args=(params, token, caches),
                     in_shardings=(p_sh, t_sh, c_sh), donate=(2,),
                     meta={"cfg": cfg})


def build_embedding_step(arch_cfg, shape: InputShape, mesh) -> BuiltStep:
    """The paper's own arch: one hybrid-parallel training episode.

    Shape mapping: `seq_len` has no direct analogue; the episode trains
    `block_cap` samples per (round x sub-part) cell. Decode/prefill kinds map
    to inference-style *embedding lookup serving* (gather + dot scoring)."""
    from repro.core.hybrid import HybridConfig, build_episode_fn
    from repro.core.partition import NodePartition

    dims = tuple(mesh.devices.shape)
    P_dev = int(np.prod(dims))
    hcfg = HybridConfig(dim=arch_cfg.dim, negatives=arch_cfg.negatives,
                        minibatch=arch_cfg.minibatch,
                        subparts=arch_cfg.subparts,
                        neg_pool=arch_cfg.neg_pool, lr=arch_cfg.lr,
                        dtype=getattr(arch_cfg, "dtype", "float32"))
    part = NodePartition(arch_cfg.num_nodes, dims=dims,
                         subparts=arch_cfg.subparts)
    fn, sh = build_episode_fn(mesh, part, hcfg)
    # abstract inputs
    d = arch_cfg.dim
    N = part.padded_num_nodes
    bcap = arch_cfg.block_cap
    f32 = jnp.float32
    tdt = jnp.dtype(hcfg.dtype)
    args = (
        jax.ShapeDtypeStruct((N, d), tdt),                       # vert
        jax.ShapeDtypeStruct((N, d), tdt),                       # ctx
        jax.ShapeDtypeStruct((P_dev, *dims, hcfg.subparts, bcap, 2),
                             jnp.int32),                         # blocks
        jax.ShapeDtypeStruct((P_dev, *dims, hcfg.subparts), jnp.int32),
        jax.ShapeDtypeStruct((P_dev, hcfg.neg_pool), jnp.int32),  # pool
        jax.ShapeDtypeStruct((1,), jnp.int32),                   # seed
        jax.ShapeDtypeStruct((), f32),                           # lr
    )
    in_sh = (sh["table"], sh["table"], sh["blocks"], sh["blocks"],
             sh["blocks"], sh["replicated"], sh["replicated"])
    # the episode fn is already shard_map+jit; expose the underlying callable
    return BuiltStep(fn=fn, args=args, in_shardings=in_sh, donate=(0, 1),
                     meta={"embedding": True, "samples":
                           P_dev * P_dev * hcfg.subparts * bcap})


def build_step(arch: str, shape_name: str, mesh) -> BuiltStep:
    shape = cfgs.SHAPES[shape_name]
    cfg = cfgs.get_config(arch)
    if getattr(cfg, "arch_type", None) == "embedding":
        return build_embedding_step(cfg, shape, mesh)
    return build_lm_step(cfg, shape, mesh)
