"""Production training launcher.

Two modes, selected by --arch:

* ``tencent-embedding`` — the paper's system: decoupled walk engine (async,
  one epoch ahead), episode pipeline, hybrid model-data parallel episode
  step, periodic checkpoints, link-prediction eval.
* any LM arch id — config-system LM training on the synthetic token
  pipeline with the same sharding rules as the production dry-run.

    PYTHONPATH=src python -m repro.launch.train --arch tencent-embedding \
        --epochs 10 --nodes 20000
    PYTHONPATH=src python -m repro.launch.train --arch phi3.5-moe-42b-a6.6b \
        --reduced --steps 100

Scale note: full (non-``--reduced``) LM configs need the real pod — on this
container they are exercised via ``repro.launch.dryrun``.

Fault tolerance (embedding mode): ``--ckpt-every N`` writes an atomic,
checksummed resume checkpoint (tables + mid-epoch cursor) every N episodes;
``--resume`` continues from it, bitwise-identical to an uninterrupted run.
``--inject SPEC`` installs a deterministic fault plan (crash/delay/corrupt
at named sites — see ``repro.runtime.faults``) for chaos testing;
``--stall-timeout-s`` bounds how long any stage may block without store
progress before failing with diagnostics instead of hanging.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np


def train_embedding(args):
    import jax
    from repro import obs
    from repro.configs.tencent_embedding import SMALL
    from repro.core import (EpisodePipeline, HybridConfig,
                            HybridEmbeddingTrainer, TieredEmbeddingTrainer)
    from repro.core import eval as ev
    from repro.graph.csr import build_csr
    from repro.graph.generators import powerlaw_graph
    from repro.runtime import (FaultPlan, clear_plan, install_plan)
    from repro.train.checkpoint import load_arrays
    from repro.walk import (DiskSampleStore, MemorySampleStore,
                            RemoteWalkCoordinator, WalkConfig, WalkEngine)

    # flag validation first: fail before any graph/trainer work happens
    if args.coordinator_resume and args.remote_walkers <= 0:
        raise SystemExit("--coordinator-resume requires --remote-walkers")
    if args.coordinator_resume and not args.resume:
        raise SystemExit("--coordinator-resume requires --resume (the "
                         "trainer cursor tells the server which epochs to "
                         "re-submit)")

    # telemetry is opt-in (disabled-by-default hot paths are single None
    # checks); enable BEFORE building the dataflow so components register
    # their snapshot sources with the live registry
    writer = obs_tracer = None
    if args.metrics_dir or args.trace:
        reg = obs.enable()
        if args.trace:
            obs_tracer = obs.Tracer()
            obs.set_tracer(obs_tracer)
        if args.metrics_dir:
            writer = obs.MetricsWriter(reg, args.metrics_dir,
                                       interval_s=args.metrics_interval_s)
            print(f"metrics -> {writer.path} "
                  f"(every {writer.interval_s:g}s)")

    if args.graph:
        from repro.graph.io import load_edge_list
        g_full = load_edge_list(args.graph)
    elif args.graph_kind == "sbm":
        from repro.graph.generators import sbm_graph
        # candidate-pair budget must scale with n or large graphs come out
        # mostly degree-0 (expected edges ~ rounds * batch * 0.0075)
        g_full = sbm_graph(args.nodes, rounds=max(30, args.nodes // 40),
                           seed=args.seed)
    else:
        g_full = powerlaw_graph(args.nodes, 5, seed=args.seed)
    train_e, test_e = ev.split_edges(g_full, 0.03, seed=args.seed)
    g = build_csr(train_e, g_full.num_nodes, symmetrize=False, dedup=False)
    neg_e = ev.sample_negative_pairs(g_full, len(test_e), seed=args.seed + 1)
    print(f"graph: {g.num_nodes} nodes / {g.num_edges} train edges; "
          f"{len(test_e)} held out")

    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    cfg_kw = {}
    if args.dtype is not None:          # None -> HybridConfig default (bf16)
        cfg_kw["dtype"] = args.dtype
    cfg = HybridConfig(dim=args.dim,
                       minibatch=args.minibatch or SMALL.minibatch,
                       negatives=args.negatives or SMALL.negatives,
                       subparts=args.subparts,
                       neg_pool=args.neg_pool or SMALL.neg_pool,
                       lr=args.lr, seed=args.seed,
                       impl=args.impl, block_b=args.block_b, **cfg_kw)
    if args.hbm_rows is not None:
        # tiered tables: host-RAM master + HBM cache of --hbm-rows hot rows;
        # bitwise identical to the resident trainer at any budget, so the
        # artifacts (and --resume) are interchangeable between the two
        trainer = TieredEmbeddingTrainer(
            g.num_nodes, mesh, cfg, degrees=g.degrees(),
            hbm_rows=args.hbm_rows, policy=args.cache_policy,
            spill_dir=(os.path.join(args.out_dir, "master_spill")
                       if args.cache_spill else None))
        print(f"tiered tables: hbm_rows={args.hbm_rows} "
              f"policy={args.cache_policy}"
              + (" (disk-backed master)" if args.cache_spill else ""))
    else:
        trainer = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                         degrees=g.degrees())

    # crash-resume: restore tables + (epoch, episode) cursor from the last
    # resume checkpoint; the remaining episodes replay bitwise-identically
    # (per-episode RNG streams are keyed by the config, never by history)
    start_epoch, start_episode = 0, 0
    resume_path = os.path.join(args.out_dir, "resume.npz")
    if args.resume:
        data, _ = load_arrays(resume_path)   # verifies the crc manifest
        start_epoch, start_episode = (int(v) for v in data["__cursor__"])
        trainer.set_embeddings(data["vertex"], data["context"])
        print(f"resume <- {resume_path} @ epoch {start_epoch} "
              f"episode {start_episode}")
        if start_epoch >= args.epochs:
            print("resume cursor is past the final epoch; nothing to do")
            return
    else:
        trainer.init_embeddings()

    # bounded store: the walker can run at most store_depth episodes ahead of
    # the pipeline's drops, so peak sample memory is O(depth · episode)
    store_depth = args.store_depth or args.pipeline_depth + 1
    store_kw = {}
    if args.stall_timeout_s is not None:
        store_kw["stall_timeout_s"] = (args.stall_timeout_s
                                       if args.stall_timeout_s > 0 else None)
    if args.store == "disk":
        # fresh: this run produces NEW walks — stale episode files or .done
        # markers from a previous run in the same dir would race it. With
        # --keep-samples the files are the artifact the user asked to keep,
        # so never delete them — warn instead if any are present.
        sample_dir = args.store_dir or os.path.join(args.out_dir, "samples")
        if args.keep_samples and os.path.isdir(sample_dir) and any(
                f.startswith("epoch") and f.endswith((".npy", ".done"))
                for f in os.listdir(sample_dir)):
            print(f"WARNING: {sample_dir} already holds episode files from a "
                  f"previous run; this run's epochs will overwrite same-"
                  f"numbered files and may race stale .done markers — use a "
                  f"fresh --store-dir to keep both artifacts")
        # --coordinator-resume reconstructs the episode server's state FROM
        # the store, so a resuming run must never wipe the surviving files
        keep_files = args.keep_samples or args.coordinator_resume
        store = DiskSampleStore(sample_dir, depth=store_depth,
                                keep=args.keep_samples,
                                fresh=not keep_files, **store_kw)
    else:
        store = MemorySampleStore(depth=store_depth, **store_kw)
    wcfg = WalkConfig(walk_length=10, window=5, episodes=args.episodes,
                      seed=args.seed, workers=args.walk_workers)
    # rewalk: a never-started engine whose episode_pairs regenerates any
    # episode bitwise — the corrupt-episode-file recovery path
    pipe = EpisodePipeline(store, trainer.part, pad_multiple=cfg.minibatch,
                           block_cap=args.block_cap,
                           depth=args.pipeline_depth,
                           stage_fn=trainer.stage_blocks, drop_consumed=True,
                           rewalk=WalkEngine(g, wcfg, store).episode_pairs)
    os.makedirs(args.out_dir, exist_ok=True)

    plan = None
    if args.inject:
        plan = FaultPlan(args.inject)
        install_plan(plan)
        print(f"fault plan: {args.inject}")

    # walker factory: in-process threaded engine, or — with
    # --remote-walkers N — subprocess producers shipping episode chunks over
    # the checksummed socket transport (same RNG keys, bitwise-identical
    # sample stream, and real parallelism outside the GIL)
    coord = None
    if args.remote_walkers > 0:
        coord = RemoteWalkCoordinator(
            g, wcfg, store, num_producers=args.remote_walkers,
            heartbeat_s=args.heartbeat_s, lease_s=args.lease_s,
            inject_specs=args.inject, port=args.coordinator_port,
            recover=args.coordinator_resume,
            server_grace_s=args.server_grace_s)
        coord.start()
        mk_walker = coord.epoch_walker
        print(f"remote walkers: {args.remote_walkers} subprocess "
              f"producer(s) @ {coord.server.address[0]}:"
              f"{coord.server.address[1]} (heartbeat {args.heartbeat_s}s, "
              f"lease {args.lease_s}s, grace {args.server_grace_s}s)")
        if args.coordinator_resume:
            print(f"coordinator takeover: recovering server on port "
                  f"{coord.server.address[1]} reconstructs epoch state "
                  f"from the {args.store} store")
    else:
        def mk_walker():
            return WalkEngine(g, wcfg, store)

    engine = mk_walker()
    engine.start_async(start_epoch)
    try:
        _train_embedding_epochs(args, cfg, trainer, engine, store,
                                pipe, test_e, neg_e, mk_walker=mk_walker,
                                start_epoch=start_epoch,
                                start_episode=start_episode)
        if args.hbm_rows is not None:
            st = trainer.cache_stats()
            print(f"cache: hit_rate {st['hit_rate']:.3f} "
                  f"hbm_bytes {st['hbm_bytes_moved']} "
                  f"host_bytes {st['host_bytes_moved']} "
                  f"promotions {st['vertex']['promotions']}"
                  f"+{st['context']['promotions']} "
                  f"evictions {st['vertex']['evictions']}"
                  f"+{st['context']['evictions']}")
        if coord is not None:
            st = coord.transport_stats()
            print(f"transport: {st['frames_recv']} frames / "
                  f"{st['bytes_recv']} bytes received, "
                  f"{st['dup_chunks']} duplicate chunk(s) discarded")
            fo = coord.failover_stats()
            if fo["takeovers"] or fo["recovered_episodes"]:
                print(f"failover: {fo['takeovers']} takeover(s), "
                      f"{fo['recovered_episodes']} episode(s) recovered "
                      f"from the store without re-production")
    except BaseException as e:
        # leave a machine-readable dump for CI artifact upload on ANY fatal
        # exit — not just StoreStalled/TransportError, so a chaos leg that
        # dies on an unexpected error still produces an artifact: what
        # failed, what was resident, which hosts were (not) beating, and
        # the live metrics snapshot when telemetry is on
        _dump_diagnostics(args.out_dir, e, coord)
        raise
    finally:
        # always drain the prefetch workers: an in-flight build racing
        # interpreter teardown (e.g. after a KeyboardInterrupt) can crash
        # inside numpy after module unload
        pipe.close()
        if coord is not None:
            coord.close()
        if plan is not None:
            clear_plan()
        if writer is not None:
            writer.close()
            print(f"metrics summary -> {writer.summary_path}")
        if obs_tracer is not None:
            obs.set_tracer(None)
            obs_tracer.save(args.trace)
            print(f"trace -> {args.trace} "
                  f"({obs_tracer.event_count()} events, "
                  f"{obs_tracer.dropped} dropped)")
        if writer is not None or obs_tracer is not None:
            obs.disable()


def _dump_diagnostics(out_dir, err, coord):
    """OUT_DIR/diagnostics.json: the stall/transport failure in machine-
    readable form (CI uploads it as an artifact on chaos-leg failure)."""
    import json
    from repro import obs
    from repro.runtime import StoreStalled

    diag = {"error": type(err).__name__, "message": str(err)}
    if isinstance(err, StoreStalled):
        diag.update({"op": err.op, "key": err.key,
                     "resident": sorted(err.resident),
                     "producer_alive": err.producer_alive,
                     "producer_info": err.producer_info,
                     "waited_s": err.waited_s})
    if coord is not None:
        diag["host_health"] = coord.server.health.snapshot()
        diag["transport"] = coord.transport_stats()
        diag["failover"] = coord.failover_stats()
    reg = obs.active()
    if reg is not None:          # fold the live registry into the dump
        diag["metrics"] = reg.snapshot()
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "diagnostics.json")
    with open(path, "w") as f:
        json.dump(diag, f, indent=2, default=str)
    print(f"diagnostics -> {path}")


def _write_resume(args, trainer, epoch, next_ep):
    """Atomic resume checkpoint: tables + checksummed (epoch, episode)
    cursor. ``next_ep`` is the NEXT episode to train; a full epoch
    normalizes to (epoch+1, 0) so resume never re-enters a finished epoch."""
    from repro.train.checkpoint import save_checkpoint

    cur = (epoch + 1, 0) if next_ep >= args.episodes else (epoch, next_ep)
    path = os.path.join(args.out_dir, "resume.npz")
    save_checkpoint(path,
                    {"vertex": trainer.embeddings(),
                     "context": trainer.context_embeddings()},
                    step=epoch * args.episodes + next_ep,
                    extra={"__cursor__": np.asarray(cur, np.int64)})
    return path


def _train_embedding_epochs(args, cfg, trainer, engine, store, pipe,
                            test_e, neg_e, *, mk_walker,
                            start_epoch=0, start_episode=0):
    from repro.core import eval as ev
    from repro.obs import counter_add, observe, span
    from repro.obs import trace as _trace
    from repro.runtime import fault_point
    from repro.train.checkpoint import save_checkpoint

    auc = 0.0
    ckpt_every = max(0, args.ckpt_every)
    for epoch in range(start_epoch, args.epochs):
        # streamed: do NOT join — training starts as soon as episode 0 lands
        # in the bounded store; the walker streams the rest concurrently
        tr = _trace.tracer()
        t_epoch_us = tr.now_us() if tr is not None else 0.0
        t0 = time.perf_counter()
        nxt = None
        losses = []
        # resuming mid-epoch: episodes before the cursor were already trained
        # into the restored tables — drain them from the walker's stream
        # without training so the bounded store keeps flowing
        skip_until = start_episode if epoch == start_epoch else 0
        try:
            for ep in range(args.episodes):
                fault_point("train.episode", (epoch, ep))
                if ep < skip_until:
                    store.get(epoch, ep)
                    store.drop(epoch, ep)
                    continue
                pipe.prefetch_window(epoch, ep, args.episodes)
                eb = pipe.get(epoch, ep)
                t_ep = time.perf_counter()
                with span("train_episode", "train",
                          {"epoch": epoch, "episode": ep}):
                    losses.append(trainer.train_episode(
                        eb, lr=cfg.lr * max(1 - epoch / args.epochs, 0.05)))
                observe("train.episode_s", time.perf_counter() - t_ep)
                counter_add("train.episodes")
                # paper: walks for e+1 overlap training e — launch them the
                # moment this epoch's walker finishes (backpressure-paced)
                if nxt is None and epoch + 1 < args.epochs and engine.finished():
                    engine.join()        # surfaces walker errors
                    nxt = mk_walker()
                    nxt.start_async(epoch + 1)
                if ckpt_every and (epoch * args.episodes + ep + 1) % ckpt_every == 0:
                    path = _write_resume(args, trainer, epoch, ep + 1)
                    print(f"  resume checkpoint -> {path} "
                          f"@ ({epoch}, {ep + 1})")
        except Exception:
            # a dead walker finishes the epoch with episodes missing, which
            # surfaces here as a KeyError — join to re-raise its real error.
            # abandon() first: with nobody left to drain the bounded store, a
            # HEALTHY walker could be blocked in put() and join would hang
            store.abandon()
            engine.join()
            raise
        engine.join()
        if nxt is None and epoch + 1 < args.epochs:
            nxt = mk_walker()
            nxt.start_async(epoch + 1)
        store.drop_epoch(epoch)
        with span("eval", "train", {"epoch": epoch}):
            V = trainer.embeddings()
            Vn = V / (np.linalg.norm(V, axis=1, keepdims=True) + 1e-9)
            auc = ev.auc_score(
                np.einsum("ij,ij->i", Vn[test_e[:, 0]], Vn[test_e[:, 1]]),
                np.einsum("ij,ij->i", Vn[neg_e[:, 0]], Vn[neg_e[:, 1]]))
        if tr is not None:
            tr.add_span("epoch", "train", t_epoch_us, tr.now_us(),
                        {"epoch": epoch, "auc": round(float(auc), 4)})
        loss_s = f"{np.mean(losses):.4f}" if losses else "--"
        print(f"epoch {epoch:3d} loss {loss_s} AUC {auc:.4f} "
              f"({time.perf_counter()-t0:.1f}s)"
              + (f" [{len(pipe.recovered)} episode(s) re-walked]"
                 if pipe.recovered else ""))
        if epoch + 1 < args.epochs:
            engine = nxt
        if epoch + 1 == args.epochs:
            path = os.path.join(args.out_dir, f"embeddings_{epoch+1}.npz")
            save_checkpoint(path, {"vertex": V,
                                   "context": trainer.context_embeddings()},
                            step=epoch + 1)
            print(f"  checkpoint -> {path}")
    if args.min_auc is not None and auc < args.min_auc:
        raise SystemExit(
            f"final AUC {auc:.4f} below --min-auc {args.min_auc}")


def train_lm(args):
    import jax
    import jax.numpy as jnp
    from repro import configs as cfgs
    from repro.data.tokens import TokenPipeline
    from repro.launch.mesh import data_axes_of, make_host_mesh
    from repro.models import transformer as tfm
    from repro.models.common import count_params
    from repro.sharding.specs import param_shardings
    from repro.train.checkpoint import save_checkpoint
    from repro.train.train_step import make_train_step

    cfg = cfgs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(layers=args.layers, d_model=args.d_model, experts=4)
        cfg = dataclasses.replace(
            cfg, vocab_size=min(cfg.vocab_size, 8192), train_microbatches=1)
    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    print(f"{args.arch}: {count_params(params)/1e6:.1f}M params")

    mesh = make_host_mesh()
    data_axes = data_axes_of(mesh)
    params = jax.device_put(params, param_shardings(params, mesh))
    step_fn, opt = make_train_step(cfg, mesh=mesh, data_axes=data_axes,
                                   lr=args.lr)
    opt_state = jax.device_put(
        opt.init(params),
        param_shardings(jax.eval_shape(opt.init, params), mesh))
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    os.makedirs(args.out_dir, exist_ok=True)
    with mesh:
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            batch.setdefault("positions", jnp.broadcast_to(
                jnp.arange(args.seq, dtype=jnp.int32),
                batch["tokens"].shape))
            params, opt_state, m = jit_step(params, opt_state,
                                            jnp.int32(step), batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(m['loss']):.4f} "
                      f"grad_norm {float(m['grad_norm']):.2f}")
        if args.save:
            save_checkpoint(os.path.join(args.out_dir, "lm_final.npz"),
                            params, step=args.steps)
    pipe.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tencent-embedding")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=None)
    # embedding mode
    ap.add_argument("--graph", default=None, help="edge-list file (.npy/.txt)")
    ap.add_argument("--graph-kind", default="powerlaw",
                    choices=["powerlaw", "sbm"],
                    help="synthetic graph when no --graph file: powerlaw "
                         "(paper's social-network topology) or sbm (planted "
                         "communities — use when gating on --min-auc)")
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=96)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--subparts", type=int, default=4)
    ap.add_argument("--minibatch", type=int, default=None,
                    help="shared-negative group rows (default: SMALL config)")
    ap.add_argument("--negatives", type=int, default=None,
                    help="shared negatives per minibatch (default: SMALL)")
    ap.add_argument("--neg-pool", type=int, default=None,
                    help="per-device negative pool size (default: SMALL)")
    # literal copy of kernels.ops.STEP_IMPLS: importing ops here would pull
    # jax into --help / arg-error paths (this module defers jax on purpose);
    # a stale copy fails loudly anyway (ops validates impl at trace time)
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "pallas", "pallas_fused",
                             "pallas_fused2"],
                    help="kernels.ops execution path for the episode step")
    ap.add_argument("--dtype", default=None,
                    choices=["float32", "bfloat16"],
                    help="embedding-table dtype (default: the HybridConfig "
                         "default, bfloat16; pass float32 for the "
                         "paper-faithful tables)")
    ap.add_argument("--block-b", type=int, default=None,
                    help="pin the fused-kernel tile size (default: "
                         "VMEM-aware autotune in kernels.ops)")
    ap.add_argument("--hbm-rows", type=int, default=None,
                    help="train through tiered tables: host-RAM master + an "
                         "HBM cache of this many hot rows per table "
                         "(core.tiered; bitwise identical to the resident "
                         "trainer at any budget). Default: fully resident "
                         "shards")
    ap.add_argument("--cache-policy", default="freq",
                    choices=["freq", "lru"],
                    help="hot-row promotion policy for --hbm-rows: freq "
                         "(cumulative access count) or lru (most recent "
                         "episode touch); ties break to the smaller row id")
    ap.add_argument("--cache-spill", action="store_true",
                    help="with --hbm-rows: back the master tables with "
                         "np.memmap files under OUT_DIR/master_spill "
                         "(tables beyond host RAM)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="episodes between atomic resume checkpoints "
                         "(OUT_DIR/resume.npz: tables + cursor, crc-"
                         "manifested; 0 = final artifact only)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from OUT_DIR/resume.npz — restores tables "
                         "+ (epoch, episode) cursor and replays the rest of "
                         "the run bitwise-identically to an uninterrupted "
                         "one (per-episode RNG streams are config-keyed)")
    ap.add_argument("--inject", action="append", default=[], metavar="SPEC",
                    help="deterministic fault spec, repeatable: "
                         "site:kind[:opt=val]... e.g. walk.chunk:crash:at=5, "
                         "train.episode:crash:key=6/1, "
                         "disk.write:corrupt:at=0 (see repro.runtime.faults)")
    ap.add_argument("--stall-timeout-s", type=float, default=None,
                    help="seconds without sample-store progress before a "
                         "blocked stage fails with StoreStalled diagnostics "
                         "(default 600; <=0 disables the deadline — producer "
                         "liveness detection still applies)")
    # streaming dataflow knobs
    ap.add_argument("--walk-workers", type=int, default=2,
                    help="walk-engine chunk worker threads (1 = inline; the "
                         "sample stream is identical for any value)")
    ap.add_argument("--remote-walkers", type=int, default=0,
                    help="run N walk producers as subprocesses shipping "
                         "episode chunks over the checksummed socket "
                         "transport (0 = in-process threads). The sample "
                         "stream is bitwise-identical either way; "
                         "subprocesses walk outside the GIL and survive "
                         "producer crashes via lease-based reassignment")
    ap.add_argument("--coordinator-resume", action="store_true",
                    help="with --resume and --remote-walkers: build the "
                         "episode server in recovery mode — it reconstructs "
                         "the work queue from the sample store (complete "
                         "episodes skipped, partial ones replayed via the "
                         "RNG keys) instead of starting the epoch from 0")
    ap.add_argument("--coordinator-port", type=int, default=0,
                    help="fixed listen port for the episode server (default "
                         "0 = ephemeral); a restarted coordinator must "
                         "reuse its predecessor's port so producers in "
                         "their reconnect-backoff loop can reattach")
    ap.add_argument("--server-grace-s", type=float, default=30.0,
                    help="producer-side outage budget: how long a producer "
                         "keeps retrying (jittered capped backoff) against "
                         "an unreachable episode server before giving up")
    ap.add_argument("--heartbeat-s", type=float, default=1.0,
                    help="remote producer heartbeat interval")
    ap.add_argument("--lease-s", type=float, default=10.0,
                    help="seconds without a heartbeat before a remote "
                         "producer is declared dead and its unfinished "
                         "episodes are reassigned to survivors")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="episodes in flight through the fetch/build/stage "
                         "pipeline")
    ap.add_argument("--store", default="memory", choices=["memory", "disk"],
                    help="sample store backend (disk = the paper's "
                         "offline/slow-cluster mode: episode .npy files)")
    ap.add_argument("--store-dir", default=None,
                    help="disk-store directory (default: OUT_DIR/samples)")
    ap.add_argument("--store-depth", type=int, default=None,
                    help="bounded-store capacity in undrained episodes "
                         "(default: pipeline depth + 1)")
    ap.add_argument("--keep-samples", action="store_true",
                    help="disk store: keep episode files after consumption "
                         "(the offline artifact) instead of deleting them")
    ap.add_argument("--min-auc", type=float, default=None,
                    help="exit non-zero if the final epoch's link-prediction "
                         "AUC is below this (CI sanity gate)")
    # telemetry (repro.obs; disabled unless one of these is given)
    ap.add_argument("--metrics-dir", default=None,
                    help="enable the telemetry registry and append periodic "
                         "snapshots to DIR/metrics.jsonl (+ final "
                         "metrics_summary.json at exit)")
    ap.add_argument("--metrics-interval-s", type=float, default=5.0,
                    help="seconds between metrics.jsonl snapshots")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record a span timeline of the walk/build/stage/"
                         "train pipeline and write Chrome trace-event JSON "
                         "to FILE (load in ui.perfetto.dev)")
    ap.add_argument("--block-cap", type=int, default=None,
                    help="pin every episode's per-cell block capacity (rounds "
                         "up to the minibatch pad): episodes then share one "
                         "compiled step instead of re-lowering per bmax — "
                         "set it above the expected max cell count or "
                         "overflow samples are dropped (default: per-episode "
                         "bmax, recompiles when it changes)")
    # lm mode
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--save", action="store_true")
    args = ap.parse_args(argv)
    if args.arch == "tencent-embedding":
        args.lr = args.lr if args.lr is not None else 0.025
        train_embedding(args)
    else:
        args.lr = args.lr if args.lr is not None else 3e-4
        train_lm(args)


if __name__ == "__main__":
    main()
