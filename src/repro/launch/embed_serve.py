"""Embedding retrieval serving launcher (the paper's downstream consumer).

Loads a table from a ``launch/train.py`` checkpoint into the device-sharded
``ShardedEmbeddingStore``, stands up the ``MicroBatcher`` frontend, drives a
seeded open-loop query stream at ``--qps``, and reports achieved QPS,
request-latency percentiles, and recall@k against the numpy oracle.
(Distinct from ``launch/serve.py``, the LM token-decode demo.)

    PYTHONPATH=src python -m repro.launch.train --arch tencent-embedding \
        --nodes 400 --epochs 2 --episodes 2 --dim 32 --ckpt-every 2 \
        --out-dir /tmp/embed_ckpt
    PYTHONPATH=src python -m repro.launch.embed_serve \
        --ckpt /tmp/embed_ckpt/embeddings_2.npz --k 10 --queries 100 \
        --qps 1000 --batch-window-ms 2 --check-recall 1.0

``--check-recall`` turns the run into a gate (exit 1 below the threshold) —
that is the CI smoke: trained checkpoint → serve → recall@k == oracle.
``--quant int8`` builds the int8 tier at load and (with ``--impl auto``)
serves through the two-tier scan — the same gate then certifies that the
``--overfetch`` margin loses nothing vs the exact oracle.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    from repro.embed_serve import (MicroBatcher, ShardedEmbeddingStore,
                                   drive_open_loop, recall_at_k)

    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True,
                    help="launch/train.py embedding checkpoint (.npz)")
    ap.add_argument("--table", default="vertex", choices=["vertex", "context"])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256,
                    help="number of requests in the seeded stream")
    ap.add_argument("--qps", type=float, default=1000.0,
                    help="open-loop request rate (0 = submit all at once)")
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=256,
                    help="backend batch rows; every call is padded to this "
                         "(fixed shape: one compile, warmed before the "
                         "clock)")
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "pallas", "rowwise", "xla", "quant",
                             "quant_pallas", "quant_xla"],
                    help="shard top-k path (auto: pallas on TPU, xla "
                         "elsewhere; pass pallas to force the kernel — "
                         "interpret mode off-TPU; quant* need --quant int8)")
    ap.add_argument("--quant", default="none", choices=["none", "int8"],
                    help="build the int8 tier at load; with --impl auto "
                         "this also routes queries through the two-tier "
                         "scan (int8 first pass + exact rescore)")
    ap.add_argument("--overfetch", type=float, default=None,
                    help="tier-one candidate margin m = ceil(k * overfetch) "
                         "for the quant path (default quant.DEFAULT_OVERFETCH)")
    ap.add_argument("--metric", default="dot", choices=["dot", "cosine"],
                    help="cosine normalizes table rows at load and query "
                         "vectors at submit; same MIPS kernel either way")
    ap.add_argument("--noise", type=float, default=0.0,
                    help="N(0, noise) perturbation of the sampled query rows")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-recall", type=float, default=None,
                    help="exit 1 if recall@k vs the oracle is below this")
    args = ap.parse_args(argv)

    from repro.embed_serve import quant as qz

    quant = None if args.quant == "none" else args.quant
    impl = args.impl
    if quant and impl == "auto":
        impl = "quant"            # the tier was built to be used
    if impl.startswith("quant") and not quant:
        ap.error(f"--impl {impl} requires --quant int8")
    if args.overfetch is not None and not quant:
        # silently serving the exact path would let a recall-gate run
        # "validate" an overfetch margin that was never exercised
        ap.error("--overfetch requires --quant int8")
    store = ShardedEmbeddingStore.load(
        args.ckpt, table=args.table, normalize=args.metric == "cosine",
        quant=quant,
        overfetch=(qz.DEFAULT_OVERFETCH if args.overfetch is None
                   else args.overfetch))
    tier = f", int8 tier (overfetch {store.overfetch:g})" if quant else ""
    print(f"loaded {args.table} table: {store.num_nodes} x {store.dim} "
          f"{store.host_table.dtype} over {len(store.shards)} shard(s) "
          f"(step {store.step}){tier}")

    rng = np.random.default_rng(args.seed)
    rows = rng.integers(0, store.num_nodes, size=args.queries)
    queries = store.host_table[rows].astype(np.float32)
    if args.noise:
        queries = queries + rng.normal(0, args.noise, queries.shape)
    if args.metric == "cosine":
        queries /= np.linalg.norm(queries, axis=1, keepdims=True) + 1e-12

    def serve_fn(q):
        return store.topk(q, args.k, impl=impl)

    # fixed_batch: every backend call is padded to max_batch rows, so the
    # shape-specialized (jitted) path compiles exactly once — here, before
    # the clock starts, not inside a request's latency
    serve_fn(np.zeros((args.max_batch, store.dim), np.float32))
    batcher = MicroBatcher(serve_fn, store.dim, max_batch=args.max_batch,
                           window_ms=args.batch_window_ms, fixed_batch=True)
    results, lat, wall = drive_open_loop(batcher, queries, qps=args.qps,
                                         timeout=120)
    batcher.close()

    got_ids = np.stack([ids for _, ids in results])
    oracle_vals, oracle_ids = store.oracle_topk(queries, args.k)
    # tie tolerance uses ground-truth rescoring of the returned ids, never
    # the kernel's own reported values
    recall = recall_at_k(got_ids, oracle_ids,
                         got_vals=store.score_ids(queries, got_ids),
                         oracle_vals=oracle_vals)
    lat_ms = np.sort(np.asarray(lat)) * 1e3
    p50 = float(np.percentile(lat_ms, 50))
    p99 = float(np.percentile(lat_ms, 99))
    st = batcher.stats
    print(f"served {args.queries} requests in {wall:.3f}s "
          f"({args.queries / wall:.1f} QPS achieved, target "
          f"{args.qps or 'inf'}) | latency p50 {p50:.2f}ms p99 {p99:.2f}ms "
          f"| {st.batches} batches, mean {st.mean_batch:.1f} req/batch "
          f"| recall@{args.k} {recall:.4f}")
    if args.check_recall is not None and recall < args.check_recall:
        print(f"FAIL: recall {recall:.4f} < required {args.check_recall}")
        sys.exit(1)


if __name__ == "__main__":
    main()
