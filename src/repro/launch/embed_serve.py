"""Embedding retrieval serving launcher (the paper's downstream consumer).

Loads a table from a ``launch/train.py`` checkpoint into the device-sharded
``ShardedEmbeddingStore``, stands up the ``MicroBatcher`` frontend, drives a
seeded open-loop query stream at ``--qps``, and reports achieved QPS,
request-latency percentiles, and recall@k against the numpy oracle.
(Distinct from ``launch/serve.py``, the LM token-decode demo.)

    PYTHONPATH=src python -m repro.launch.train --arch tencent-embedding \
        --nodes 400 --epochs 2 --episodes 2 --dim 32 --ckpt-every 2 \
        --out-dir /tmp/embed_ckpt
    PYTHONPATH=src python -m repro.launch.embed_serve \
        --ckpt /tmp/embed_ckpt/embeddings_2.npz --k 10 --queries 100 \
        --qps 1000 --batch-window-ms 2 --check-recall 1.0

``--check-recall`` turns the run into a gate (exit 1 below the threshold) —
that is the CI smoke: trained checkpoint → serve → recall@k == oracle.
``--quant int8`` builds the int8 tier at load and (with ``--impl auto``)
serves through the two-tier scan — the same gate then certifies that the
``--overfetch`` margin loses nothing vs the exact oracle. ``--hot-rows N``
additionally splits every shard into an exact hot tier (the N hottest rows
of the request stream's query log) in front of a compacted int8 cold
remainder and serves ``impl="tiered"`` — hot hits skip quantization, and
the same recall gate certifies the tier merge.

Degraded mode: ``--shards N`` forces an N-shard layout (repeating devices
when there are fewer), ``--shard-timeout-ms`` bounds each shard's scan, and
``--inject "serve.shard:delay:key=1:..."`` makes a shard miss it — the
recall gate then scores against the SURVIVING-shards oracle (exactness of
what was answerable, not of what was lost), ``--expect-degraded`` asserts
the degradation actually happened, and ``--deadline-ms`` gives every
request an admission deadline so nothing hangs past it.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None):
    from repro.embed_serve import (MicroBatcher, ShardedEmbeddingStore,
                                   drive_open_loop, recall_at_k)

    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True,
                    help="launch/train.py embedding checkpoint (.npz)")
    ap.add_argument("--table", default="vertex", choices=["vertex", "context"])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256,
                    help="number of requests in the seeded stream")
    ap.add_argument("--qps", type=float, default=1000.0,
                    help="open-loop request rate (0 = submit all at once)")
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=256,
                    help="backend batch rows; every call is padded to this "
                         "(fixed shape: one compile, warmed before the "
                         "clock)")
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "pallas", "rowwise", "xla", "quant",
                             "quant_pallas", "quant_xla", "tiered"],
                    help="shard top-k path (auto: pallas on TPU, xla "
                         "elsewhere; pass pallas to force the kernel — "
                         "interpret mode off-TPU; quant* need --quant int8; "
                         "tiered needs --hot-rows)")
    ap.add_argument("--hot-rows", type=int, default=None,
                    help="exact hot-tier budget per store (rows); ranks the "
                         "request stream's query log, requires --quant int8 "
                         "and routes --impl auto to the tiered scan")
    ap.add_argument("--quant", default="none", choices=["none", "int8"],
                    help="build the int8 tier at load; with --impl auto "
                         "this also routes queries through the two-tier "
                         "scan (int8 first pass + exact rescore)")
    ap.add_argument("--overfetch", type=float, default=None,
                    help="tier-one candidate margin m = ceil(k * overfetch) "
                         "for the quant path (default quant.DEFAULT_OVERFETCH)")
    ap.add_argument("--metric", default="dot", choices=["dot", "cosine"],
                    help="cosine normalizes table rows at load and query "
                         "vectors at submit; same MIPS kernel either way")
    ap.add_argument("--noise", type=float, default=0.0,
                    help="N(0, noise) perturbation of the sampled query rows")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-recall", type=float, default=None,
                    help="exit 1 if recall@k vs the oracle is below this "
                         "(the surviving-shards oracle when shards failed)")
    ap.add_argument("--shards", type=int, default=None,
                    help="force an N-shard layout, repeating devices when "
                         "fewer exist (degraded-mode testing on one host)")
    ap.add_argument("--shard-timeout-ms", type=float, default=None,
                    help="per-shard scan deadline; shards that miss it are "
                         "dropped from the merge and the response is tagged "
                         "degraded (default: wait forever)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request admission deadline in the batcher; an "
                         "expired request fails with DeadlineExceeded "
                         "instead of being served late")
    ap.add_argument("--inject", action="append", default=[], metavar="SPEC",
                    help="deterministic fault spec, repeatable, e.g. "
                         "serve.shard:delay:key=1:delay=1.0:times=inf "
                         "(see repro.runtime.faults)")
    ap.add_argument("--expect-degraded", action="store_true",
                    help="exit 1 unless at least one response was actually "
                         "degraded (guards the chaos leg against a fault "
                         "plan that silently never fired)")
    # telemetry (repro.obs; disabled unless one of these is given)
    ap.add_argument("--metrics-dir", default=None,
                    help="enable the telemetry registry and append periodic "
                         "snapshots to DIR/metrics.jsonl (+ final "
                         "metrics_summary.json at exit)")
    ap.add_argument("--metrics-interval-s", type=float, default=5.0,
                    help="seconds between metrics.jsonl snapshots")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record serve_batch spans + queue-depth counter "
                         "track as Chrome trace-event JSON (ui.perfetto.dev)")
    args = ap.parse_args(argv)

    from repro import obs
    from repro.embed_serve import quant as qz
    from repro.runtime import FaultPlan, clear_plan, install_plan

    writer = obs_tracer = None
    if args.metrics_dir or args.trace:
        reg = obs.enable()
        if args.trace:
            obs_tracer = obs.Tracer()
            obs.set_tracer(obs_tracer)
        if args.metrics_dir:
            writer = obs.MetricsWriter(reg, args.metrics_dir,
                                       interval_s=args.metrics_interval_s)
            print(f"metrics -> {writer.path}")

    quant = None if args.quant == "none" else args.quant
    impl = args.impl
    if quant and impl == "auto":
        impl = "quant"            # the tier was built to be used
    if args.hot_rows is not None:
        if not quant:
            ap.error("--hot-rows requires --quant int8 (the cold tier)")
        if impl in ("auto", "quant"):
            impl = "tiered"       # ditto for the hot tier
    if impl == "tiered" and args.hot_rows is None:
        ap.error("--impl tiered requires --hot-rows")
    if impl.startswith("quant") and not quant:
        ap.error(f"--impl {impl} requires --quant int8")
    if args.overfetch is not None and not quant:
        # silently serving the exact path would let a recall-gate run
        # "validate" an overfetch margin that was never exercised
        ap.error("--overfetch requires --quant int8")
    load_kw = {}
    if args.shards is not None:
        import jax
        devs = jax.devices()
        load_kw["devices"] = [devs[i % len(devs)] for i in range(args.shards)]
    if args.shard_timeout_ms is not None:
        load_kw["shard_timeout_s"] = args.shard_timeout_ms / 1e3
    store = ShardedEmbeddingStore.load(
        args.ckpt, table=args.table, normalize=args.metric == "cosine",
        quant=quant,
        overfetch=(qz.DEFAULT_OVERFETCH if args.overfetch is None
                   else args.overfetch), **load_kw)
    tier = f", int8 tier (overfetch {store.overfetch:g})" if quant else ""
    print(f"loaded {args.table} table: {store.num_nodes} x {store.dim} "
          f"{store.host_table.dtype} over {len(store.shards)} shard(s) "
          f"(step {store.step}){tier}")

    plan = None
    if args.inject:
        plan = FaultPlan(args.inject)
        install_plan(plan)
        print(f"fault plan: {args.inject}")

    rng = np.random.default_rng(args.seed)
    rows = rng.integers(0, store.num_nodes, size=args.queries)
    if args.hot_rows is not None:
        # the request stream IS the query log: rank the hot set by it
        n_hot = store.enable_hot_tier(
            args.hot_rows,
            counts=np.bincount(rows, minlength=store.num_nodes)
                     .astype(np.float64))
        print(f"hot tier: {n_hot} exact rows + compacted int8 cold "
              f"remainder per shard")
    queries = store.host_table[rows].astype(np.float32)
    if args.noise:
        queries = queries + rng.normal(0, args.noise, queries.shape)
    if args.metric == "cosine":
        queries /= np.linalg.norm(queries, axis=1, keepdims=True) + 1e-12

    degraded_meta = args.shard_timeout_ms is not None

    def serve_fn(q):
        # with a shard deadline, request the TopKMeta so the batcher can tag
        # every response of a degraded batch
        return store.topk(q, args.k, impl=impl, return_meta=degraded_meta)

    # fixed_batch: every backend call is padded to max_batch rows, so the
    # shape-specialized (jitted) path compiles exactly once — here, before
    # the clock starts, not inside a request's latency. Warm up with the
    # fault layer suppressed (a times-bounded spec must not be spent on it)
    # and the shard deadline disabled (the compile takes longer than any
    # sane timeout; a healthy store must not warm up degraded).
    if plan is not None:
        clear_plan()
    store.topk(np.zeros((args.max_batch, store.dim), np.float32), args.k,
               impl=impl, shard_timeout_s=None, return_meta=degraded_meta)
    if plan is not None:
        install_plan(plan)
    batcher = MicroBatcher(serve_fn, store.dim, max_batch=args.max_batch,
                           window_ms=args.batch_window_ms, fixed_batch=True,
                           deadline_ms=args.deadline_ms)
    results, lat, wall = drive_open_loop(batcher, queries, qps=args.qps,
                                         timeout=120)
    batcher.close()
    if plan is not None:
        clear_plan()
    if writer is not None:
        writer.close()
        print(f"metrics summary -> {writer.summary_path}")
    if obs_tracer is not None:
        obs.set_tracer(None)
        obs_tracer.save(args.trace)
        print(f"trace -> {args.trace} ({obs_tracer.event_count()} events)")
    if writer is not None or obs_tracer is not None:
        obs.disable()

    # results are (vals, ids) or (vals, ids, meta); union the failed shards
    # so the gate scores against what was actually answerable
    got_ids = np.stack([r[1] for r in results])
    failed = sorted({s for r in results if len(r) == 3
                     for s in r[2].failed_shards})
    n_degraded = sum(1 for r in results
                     if len(r) == 3 and r[2].degraded)
    oracle_vals, oracle_ids = store.oracle_topk(queries, args.k,
                                                exclude_shards=failed)
    # tie tolerance uses ground-truth rescoring of the returned ids, never
    # the kernel's own reported values
    recall = recall_at_k(got_ids, oracle_ids,
                         got_vals=store.score_ids(queries, got_ids),
                         oracle_vals=oracle_vals)
    lat_ms = np.sort(np.asarray(lat)) * 1e3
    p50 = float(np.percentile(lat_ms, 50))
    p99 = float(np.percentile(lat_ms, 99))
    st = batcher.stats_snapshot()
    deg = (f" | DEGRADED {n_degraded}/{args.queries} req "
           f"(shards {failed} failed)" if failed else "")
    print(f"served {args.queries} requests in {wall:.3f}s "
          f"({args.queries / wall:.1f} QPS achieved, target "
          f"{args.qps or 'inf'}) | latency p50 {p50:.2f}ms p99 {p99:.2f}ms "
          f"| {st.batches} batches, mean {st.mean_batch:.1f} req/batch "
          f"| recall@{args.k} {recall:.4f}{deg}")
    if args.hot_rows is not None:
        ht = store.hot_tier_stats()
        print(f"hot tier: {ht['hot_rows']} rows, "
              f"{ht['returned_hot_frac']*100:.1f}% of returned ids exact-hot, "
              f"scan bytes {ht['scan_bytes_tiered']} tiered vs "
              f"{ht['scan_bytes_quant']} full-quant")
    if args.expect_degraded and not n_degraded:
        print("FAIL: --expect-degraded but every response was full-fidelity "
              "(did the fault plan fire?)")
        sys.exit(1)
    if args.check_recall is not None and recall < args.check_recall:
        which = f"surviving-shards ({failed} excluded)" if failed else "full"
        print(f"FAIL: recall {recall:.4f} < required {args.check_recall} "
              f"vs the {which} oracle")
        sys.exit(1)


if __name__ == "__main__":
    main()
