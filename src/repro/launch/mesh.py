"""Production meshes (spec: 16x16 single pod, 2x16x16 multi-pod).

`make_production_mesh` is a FUNCTION so importing this module never touches
jax device state; the dry-run sets XLA_FLAGS before calling it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / CPU benchmarks)."""
    n = jax.device_count()
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")
