"""HLO cost analyzer that handles while loops (scans) correctly.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which makes
scan-over-layers models look ~L-times cheaper than they are. This module
re-derives the three roofline inputs from ``compiled.as_text()``:

  * **flops** — 2 x prod(result dims) x prod(contracting dims) per `dot`
    (recursing into fusion/call subcomputations), x trip count per while.
  * **bytes** — per top-level op: result + operand bytes ("write once, read
    once" HBM model), with slicing ops counted at their *slice* size, not the
    full operand (a scan reading one layer's weights per iteration must not
    be billed G full reads of the stack).
  * **collective bytes** — result-shape bytes per collective op kind, x trip
    counts. (Ring all-reduce moves ~2x its payload across links; reported
    raw, the factor is applied in the roofline table.)

Trip counts come from the loop-condition computation (largest integer
`constant(N)` feeding its compare — jax scans count 0..N). Every number is
derived from the compiled per-device SPMD module, so terms are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIPS_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|to_apply|condition|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id",
               "iota", "broadcast", "reshape"}
_SLICE_RESULT_ONLY = {"dynamic-slice", "gather", "slice"}


def _shape_list(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, shape in _shape_list(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict      # op name -> type_str (includes parameters)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(name=m.group(2), ops=[], symbols={})
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        line_nc = _COMMENT_RE.sub("", line)
        m = _ASSIGN_RE.match(line_nc)
        if m:
            name, rhs = m.groups()
            mm = _OPCODE_RE.search(rhs)
            if not mm:
                continue
            type_str = rhs[: mm.start()]
            opcode = mm.group(1)
            rest = rhs[mm.end():]
            cur.symbols[name] = type_str
            cur.ops.append(Op(name, type_str, opcode, rest, line_nc.strip()))
    if cur is not None:
        comps[cur.name] = cur
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict | None = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVE_KINDS}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVE_KINDS:
            self.coll[k] += other.coll[k] * mult


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = None
        for raw in text.splitlines():
            s = raw.strip()
            if s.startswith("ENTRY"):
                m = _COMP_HDR.match(s)
                if m:
                    self.entry = m.group(2)
                    break
        if self.entry is None:  # fall back: jit_ main computation
            cands = [n for n in self.comps if n.startswith("main")]
            self.entry = cands[0] if cands else next(iter(self.comps))
        self._cache: dict[str, Cost] = {}

    # ---------------------------------------------------------------- trips
    def trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        best = 1
        for op in comp.ops:
            for c in _CONST_RE.findall(op.line):
                v = int(c)
                if v > best and v < 10_000_000:
                    best = v
        return best

    def _fusion_is_inplace_update(self, op: "Op") -> bool:
        """True when a fusion's called computation roots in a scatter /
        dynamic-update-slice and one operand has the result's shape (the
        aliasable table)."""
        for sub in _CALLS_RE.findall(op.line):
            comp = self.comps.get(sub)
            if comp and any(o.opcode in ("scatter", "dynamic-update-slice")
                            for o in comp.ops):
                return True
        return False

    # ---------------------------------------------------------------- flops
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        result = _shape_list(op.type_str)
        out_elems = 1
        for _, shape in result:
            for d in shape:
                out_elems *= d
        m = _LHS_C_RE.search(op.line)
        contracting = 1
        if m:
            dims = [int(x) for x in m.group(1).split(",") if x]
            operands = _OPERAND_RE.findall(op.rest)
            if operands:
                lhs_type = comp.symbols.get(operands[0])
                if lhs_type:
                    shapes = _shape_list(lhs_type)
                    if shapes:
                        lhs_shape = shapes[0][1]
                        for d in dims:
                            if d < len(lhs_shape):
                                contracting *= lhs_shape[d]
        return 2.0 * out_elems * contracting

    # ----------------------------------------------------------- cost recurse
    def cost_of(self, comp_name: str, *, top_bytes: bool = True) -> Cost:
        key = (comp_name, top_bytes)
        if key in self._cache:
            return self._cache[key]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        self._cache[key] = total  # guard vs cycles
        for op in comp.ops:
            if op.opcode == "while":
                mt = _TRIPS_RE.search(op.line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    cond = _COND_RE.search(op.line)
                    trips = self.trip_count(cond.group(1)) if cond else 1
                body = _BODY_RE.search(op.line)
                if body:
                    total.add(self.cost_of(body.group(1)), trips)
                continue
            if op.opcode == "dot":
                total.flops += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                # rough: 2 * out_elems * kernel elems (kernel = operand 1)
                out = _bytes_of(op.type_str)
                total.flops += 2.0 * out
            elif op.opcode in ("fusion", "call", "reduce", "map", "sort",
                               "scatter", "select-and-scatter",
                               "conditional"):
                for sub in set(_CALLS_RE.findall(op.line)):
                    # flops only inside subcomputations; their memory traffic
                    # is represented by this op's operands/result below
                    sub_cost = self.cost_of(sub, top_bytes=False)
                    total.flops += sub_cost.flops
                    for k in _COLLECTIVE_KINDS:
                        total.coll[k] += sub_cost.coll[k]
            kind = op.opcode.removesuffix("-start")
            if kind in _COLLECTIVE_KINDS and not op.opcode.endswith("-done"):
                total.coll[kind] += _bytes_of(op.type_str)
            # ---- bytes ----
            if not top_bytes:
                continue
            if op.opcode in _SKIP_BYTES or op.opcode.endswith("-done"):
                continue
            res_bytes = _bytes_of(op.type_str)
            if op.opcode in _SLICE_RESULT_ONLY:
                total.bytes += 2.0 * res_bytes
            elif op.opcode in ("dynamic-update-slice", "scatter"):
                operands = _OPERAND_RE.findall(op.rest)
                upd = comp.symbols.get(operands[1]) if len(operands) > 1 else None
                ub = _bytes_of(upd) if upd else res_bytes
                total.bytes += 2.0 * min(ub, res_bytes)
            elif op.opcode == "fusion" and self._fusion_is_inplace_update(op):
                # fusion wrapping a scatter / dynamic-update-slice whose
                # result aliases a same-shaped operand: traffic is the
                # read-modify-write of the updated rows, i.e. ~2x the small
                # operands (updates + indices), not the whole table.
                for on in _OPERAND_RE.findall(op.rest.split("metadata=")[0]):
                    t = comp.symbols.get(on)
                    if t:
                        b = _bytes_of(t)
                        if b < res_bytes:
                            total.bytes += 2.0 * b
            else:
                total.bytes += res_bytes
                # fusion operands are streamed, and gather-style fusions
                # touch only result-sized slices of their big operands: cap
                # each operand's contribution at 4x the result size.
                cap = 4 * res_bytes if op.opcode == "fusion" else None
                for on in _OPERAND_RE.findall(op.rest.split("metadata=")[0]):
                    t = comp.symbols.get(on)
                    if t:
                        b = _bytes_of(t)
                        total.bytes += min(b, cap) if cap is not None else b
        self._cache[key] = total
        return total

    def analyze(self) -> dict:
        c = self.cost_of(self.entry)
        coll_total = sum(c.coll.values())
        return {"flops": c.flops, "bytes": c.bytes,
                "collectives": dict(c.coll, total=coll_total)}


def analyze_hlo(text: str) -> dict:
    return HloCostModel(text).analyze()
