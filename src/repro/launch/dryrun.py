import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract memory / cost / collective statistics (deliverable e).

MUST be run as its own process (`python -m repro.launch.dryrun ...`): the
XLA_FLAGS line above executes before any jax import so 512 host placeholder
devices exist for `jax.make_mesh`.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] --out experiments/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax

from repro import configs as cfgs
from repro.configs.shapes import SHAPES
from repro.launch import hlo_cost
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    built = build_step(arch, shape_name, mesh)
    with mesh:
        if hasattr(built.fn, "lower"):          # pre-jitted (embedding arch)
            lowered = built.fn.lower(*built.args)
        else:
            jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                             donate_argnums=built.donate)
            lowered = jitted.lower(*built.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # xla's cost_analysis counts while (scan) bodies once; use our HLO cost
    # model, which multiplies by trip counts (launch/hlo_cost.py)
    analysis = hlo_cost.analyze_hlo(hlo)
    flops = float(analysis["flops"])
    bytes_acc = float(analysis["bytes"])
    coll = analysis["collectives"]
    terms = rl.roofline_terms(flops, bytes_acc, coll["total"])

    shape = SHAPES[shape_name]
    cfg = cfgs.get_config(arch)
    mflops = rl.model_flops(cfg, shape)
    useful = mflops / (flops * n_chips) if flops else 0.0

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes),
        },
        "cost": {"flops_per_device": flops,
                 "bytes_per_device": bytes_acc,
                 "xla_flops_scan_once": float(xla_cost.get("flops", 0.0))},
        "collectives": coll,
        "roofline": terms,
        "model_flops": mflops,
        "useful_flops_ratio": round(useful, 4),
    }
    if verbose:
        gb = 1 << 30
        print(f"[{arch} x {shape_name} @ {'x'.join(map(str, rec['mesh']))}] "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"args {rec['memory']['argument_bytes']/gb:.2f}GiB "
              f"temp {rec['memory']['temp_bytes']/gb:.2f}GiB | "
              f"flops/dev {flops:.3e} bytes/dev {bytes_acc:.3e} "
              f"coll/dev {coll['total']:.3e} | dominant {terms['dominant']} "
              f"(c={terms['compute_s']*1e3:.2f}ms m={terms['memory_s']*1e3:.2f}ms "
              f"x={terms['collective_s']*1e3:.2f}ms) useful={useful:.2%}")
    return rec


LM_ARCHS = [a for a in cfgs.list_archs() if a != "tencent-embedding"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", default=None,
                    help="json file with records to skip")
    args = ap.parse_args()

    pairs: list[tuple[str, str]] = []
    if args.all:
        pairs = [(a, s) for a in LM_ARCHS for s in SHAPES]
        pairs.append(("tencent-embedding", "train_4k"))
    else:
        pairs = [(args.arch, args.shape)]

    done = set()
    records = []
    if args.skip_existing and os.path.exists(args.skip_existing):
        with open(args.skip_existing) as f:
            records = json.load(f)
        done = {(r["arch"], r["shape"], tuple(r["mesh"])) for r in records}

    for arch, shape in pairs:
        mesh_shape = (2, 16, 16) if args.multi_pod else (16, 16)
        if (arch, shape, mesh_shape) in done:
            continue
        try:
            records.append(dryrun_one(arch, shape, multi_pod=args.multi_pod))
        except Exception:
            print(f"FAILED: {arch} x {shape}")
            traceback.print_exc()
            records.append({"arch": arch, "shape": shape,
                            "mesh": list(mesh_shape), "error":
                            traceback.format_exc().splitlines()[-1]})
        if args.out:
            with open(args.out, "w") as f:
                json.dump(records, f, indent=1)

    failures = [r for r in records if "error" in r]
    print(f"\n{len(records) - len(failures)}/{len(records)} combinations "
          f"lowered+compiled successfully")
    if failures:
        for r in failures:
            print("  FAIL:", r["arch"], r["shape"], r["error"])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
