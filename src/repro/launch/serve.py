"""LM serving demo launcher: batched-request token-decode loop for the
config-system LM archs (NOT the paper's embedding workload — embedding
retrieval serving, i.e. loading a trained node-embedding checkpoint and
answering top-k nearest-neighbor queries, lives in
``repro.launch.embed_serve`` on top of the ``repro.embed_serve`` package).

Chunked prefill builds the ring-buffer caches, then the decode loop serves
one token per step for the whole batch (the decode_32k / long_500k
production path). ``--window`` selects the sub-quadratic sliding-window
variant used by dense archs for long contexts.

    PYTHONPATH=src python -m repro.launch.serve --arch jamba-v0.1-52b \
        --reduced --batch 4 --prompt-len 128 --tokens 64
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro import configs as cfgs
    from repro.models import transformer as tfm
    from repro.train.train_step import synthetic_batch

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    choices=cfgs.list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(layers=2, d_model=256, experts=4)
    if args.window:
        cfg = dataclasses.replace(cfg, sliding_window=args.window)

    params = tfm.init_params(jax.random.PRNGKey(args.seed), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, args.batch, args.prompt_len,
                             seed=args.seed).items()}
    cache_len = args.prompt_len + args.tokens + 8
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)

    prefill = jax.jit(lambda p, b: tfm.prefill(p, b, cfg, cache_len))
    decode = jax.jit(lambda p, t, c: tfm.decode_step(p, t, c, cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(args.seed + 1)

    def sample(logits, key):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            key, logits[:, 0] / args.temperature, -1).astype(jnp.int32)[:, None]

    tok = sample(logits, key)
    outs = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, caches = decode(params, tok, caches)
        key = jax.random.fold_in(key, i)
        tok = sample(logits, key)
        outs.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate(outs, 1)
    thr = (args.tokens - 1) * args.batch / max(t_decode, 1e-9)
    print(f"{args.arch}: prefill {args.batch}x{args.prompt_len} "
          f"{t_prefill*1e3:.1f}ms (incl. compile) | decode {thr:.1f} tok/s")
    print("request 0:", gen[0][:24].tolist())


if __name__ == "__main__":
    main()
