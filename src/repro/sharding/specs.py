"""Sharding rules: hierarchical tensor/expert parallel + best-effort FSDP.

The paper's hierarchical partitioning idea (fast axis shards the hot dim,
slow axes shard the bulk) is applied to the LLM pool as a *rule engine*:

  * each param name has a preferred TP dim → sharded over ``"model"`` (ICI)
    when divisible (attention heads, FFN hidden, experts, vocab);
  * large params additionally shard one remaining dim over the slow
    ("pod","data") axes — FSDP-style, GSPMD inserts the all-gathers;
  * anything non-divisible degrades gracefully to fewer axes (e.g. qwen1.5's
    20 heads on a 16-wide model axis falls back to d_model/FSDP sharding).

This makes every (arch x mesh) combination lower without per-arch tables,
while keeping the intended 2-level hierarchy.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# preferred (model-axis dim, data-axes dim) per param leaf name; dims are
# tried in order, first divisible wins.
_PREFERRED: dict[str, tuple[tuple[int, ...], tuple[int, ...]]] = {
    "embed":    ((0,), (1,)),     # vocab over model, d over data
    "lm_head":  ((1,), (0,)),
    "wq":       ((1,), (0,)),     # heads over model, d over data
    "wk":       ((1,), (0,)),
    "wv":       ((1,), (0,)),
    "wo":       ((0,), (2,)),
    "bq":       ((0,), ()),
    "bk":       ((0,), ()),
    "bv":       ((0,), ()),
    "w_gate":   ((1,), (0,)),     # ff over model (also experts: dim0 handled
    "w_up":     ((1,), (0,)),     #   by the 3-D case below)
    "w_down":   ((0,), (1,)),
    "router":   ((), ()),
    "wuq":      ((1,), (0,)),     # MLA: heads over model, rank over data
    "wuk":      ((1,), (0,)),
    "wuv":      ((1,), (0,)),
    "wdq":      ((), (0,)),
    "wdkv":     ((), (0,)),
    "wkr":      ((), ()),
    "in_proj":  ((), (0,)),       # mamba: keep concat dim whole
    "out_proj": ((0,), (1,)),
    "conv_w":   ((), ()),
    "mtp_proj": ((), (0,)),
}
# 3-D expert tensors (E, d, ff): experts over model, d over data
_PREFERRED_EXPERT = ((0,), (1,))
_BIG = 1 << 20


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def spec_for(shape: tuple[int, ...], name: str, mesh: Mesh,
             offset: int = 0) -> P:
    """offset=1 for scan-stacked layer params (leading group dim, which must
    never be sharded — each scan iteration slices one group)."""
    model_n = mesh.shape.get("model", 1)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    data_n = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    numel = int(np.prod(shape)) if shape else 0
    if numel < (1 << 16):   # norms/biases/small vectors: replicate
        return P()

    pref = _PREFERRED.get(name, ((0,), (1,)))
    expert_case = (name in ("w_gate", "w_up", "w_down")
                   and len(shape) - offset == 3)
    if expert_case:
        pref = _PREFERRED_EXPERT
    pref_m = tuple(d + offset for d in pref[0])
    pref_d = tuple(d + offset for d in pref[1])

    assignment: list = [None] * len(shape)

    # 2-D expert parallelism: expert dim over (data x model) jointly when it
    # divides the whole mesh (matches mlp.moe_forward's EP choice; keeps
    # expert weights fully resident — §Perf B.2)
    if expert_case and data_axes and             shape[offset] % (model_n * data_n) == 0 and model_n * data_n > 1:
        assignment[offset] = (*data_axes, "model")
        return P(*assignment)

    def try_assign(dims: tuple[int, ...], axes, size: int) -> bool:
        for dim in dims:
            if offset <= dim < len(shape) and assignment[dim] is None \
                    and shape[dim] % size == 0 and size > 1:
                assignment[dim] = axes
                return True
        return False

    # 1) model axis on the preferred TP dim, falling back to any divisible dim
    if not try_assign(pref_m, "model", model_n):
        try_assign(tuple(i for i in range(offset, len(shape))
                         if i not in pref_d), "model", model_n)
    # 2) FSDP over the (pod, data) axes for big tensors
    if numel >= _BIG and data_axes:
        if not try_assign(pref_d, data_axes, data_n):
            ok = False
            if len(data_axes) > 1:  # try the trailing 'data' axis alone
                sub = data_axes[-1:]
                ok = try_assign(pref_d, sub, mesh.shape[sub[0]])
            if not ok:  # any other shardable dim
                try_assign(tuple(range(offset, len(shape))), data_axes, data_n)
    return P(*assignment)


def param_shardings(params, mesh: Mesh):
    """NamedShardings for a param/optimizer pytree via the rule engine."""
    def one(path, leaf):
        stacked = any(getattr(e, "key", None) in ("segments", "enc_segments")
                      for e in path if hasattr(e, "key"))
        return NamedSharding(mesh, spec_for(tuple(leaf.shape),
                                            _leaf_name(path), mesh,
                                            offset=1 if stacked else 0))
    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(global_batch: int, mesh: Mesh) -> P:
    """Shard the batch dim over as many slow axes as divide it."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    for k in range(len(data_axes), 0, -1):
        axes = data_axes[:k]
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if global_batch % n == 0 and n > 1:
            return P(axes)
    return P()


def batch_shardings(batch_specs: dict, global_batch: int, mesh: Mesh):
    bs = batch_spec(global_batch, mesh)

    def one(leaf):
        extra = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*bs, *extra))
    return jax.tree_util.tree_map(one, batch_specs)
