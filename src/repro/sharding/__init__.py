from repro.sharding.specs import (param_shardings, batch_spec, batch_shardings,
                                  spec_for)

__all__ = ["param_shardings", "batch_spec", "batch_shardings", "spec_for"]
