"""Version-compat shims for jax APIs that moved between releases.

The container pins an older jax than some call sites were written against;
everything here resolves to the modern API when it exists and falls back to
the equivalent older spelling otherwise, so the same source runs on both.
"""
from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs):
    """``jax.shard_map`` appeared in jax 0.6; older releases expose the same
    transform as ``jax.experimental.shard_map.shard_map`` with the replication
    check named ``check_rep`` instead of ``check_vma`` (we disable it either
    way: the episode step's tuple-of-subparts carry defeats the checker)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_flat_index(axis_names, sizes):
    """Row-major flat index of this device across ``axis_names``, for use
    inside shard_map. Mesh extents are passed statically: ``jax.lax
    .axis_size`` is missing on older jax, and they must be python ints
    anyway."""
    idx = jax.lax.axis_index(axis_names[0])
    for name, n in zip(axis_names[1:], sizes[1:]):
        idx = idx * n + jax.lax.axis_index(name)
    return idx
