"""Pure-jnp oracles for the Pallas kernels.

These are the numerical ground truth: every Pallas kernel in this package has
a matching function here, and tests assert allclose between the two across a
shape/dtype sweep. They are also the CPU execution path for real training
runs in this container (Pallas interpret mode is Python-slow).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sgns_grads_ref(v: jax.Array, c: jax.Array, n: jax.Array, mask: jax.Array):
    """Shared-negative SGNS loss + grads for one minibatch.

    Args:
      v:    (B, d) gathered vertex rows (centers).
      c:    (B, d) gathered context rows (positives).
      n:    (S, d) gathered shared negative context rows.
      mask: (B,) float {0,1} — padding mask.

    Returns:
      (loss, dv, dc, dn): scalar summed loss, (B,d), (B,d), (S,d) grads of
      the summed loss w.r.t. v, c, n.

    Math: loss = Σ_b m_b [ softplus(-⟨v_b,c_b⟩) + Σ_s softplus(⟨v_b,n_s⟩) ].
    """
    f32 = jnp.float32
    v32, c32, n32 = v.astype(f32), c.astype(f32), n.astype(f32)
    m = mask.astype(f32)
    pos = jnp.sum(v32 * c32, axis=-1)                 # (B,)
    neg = v32 @ n32.T                                 # (B, S)
    g_pos = (jax.nn.sigmoid(pos) - 1.0) * m           # dL/dpos
    g_neg = jax.nn.sigmoid(neg) * m[:, None]          # dL/dneg
    dv = g_pos[:, None] * c32 + g_neg @ n32           # (B, d)
    dc = g_pos[:, None] * v32                         # (B, d)
    dn = g_neg.T @ v32                                # (S, d)
    loss = jnp.sum(m * jax.nn.softplus(-pos)) + jnp.sum(
        m[:, None] * jax.nn.softplus(neg)
    )
    return loss, dv.astype(v.dtype), dc.astype(c.dtype), dn.astype(n.dtype)


def gather_rows_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """(N, d) table, (B,) int32 -> (B, d)."""
    return jnp.take(table, idx, axis=0)


def scatter_add_rows_ref(table: jax.Array, idx: jax.Array, upd: jax.Array) -> jax.Array:
    """(N, d) table += updates at rows idx (duplicates accumulate)."""
    return table.at[idx].add(upd.astype(table.dtype))


def sgns_step_ref(vert: jax.Array, ctx: jax.Array, idx_v: jax.Array,
                  idx_c: jax.Array, idx_n: jax.Array, mask: jax.Array,
                  lr: jax.Array):
    """One full SGNS SGD minibatch against local shards (oracle for the fused op).

    vert: (Nv, d) local vertex sub-shard;  ctx: (Nc, d) local context shard.
    Returns (vert', ctx', loss).
    """
    v = gather_rows_ref(vert, idx_v)
    c = gather_rows_ref(ctx, idx_c)
    n = gather_rows_ref(ctx, idx_n)
    loss, dv, dc, dn = sgns_grads_ref(v, c, n, mask)
    vert = scatter_add_rows_ref(vert, idx_v, -lr * dv)
    # ONE combined scatter for both context updates (exactly equivalent:
    # scatter-add commutes). Two chained scatters defeat XLA's while-carry
    # in-place aliasing and force full-table copies every minibatch —
    # EXPERIMENTS.md §Perf hillclimb A.
    idx_cn = jnp.concatenate([idx_c, idx_n])
    upd_cn = jnp.concatenate([-lr * dc, -lr * dn])
    ctx = scatter_add_rows_ref(ctx, idx_cn, upd_cn)
    return vert, ctx, loss


def topk_mips_ref(table, queries, k: int):
    """Numpy oracle for exact-MIPS top-k retrieval (embed_serve.topk).

    table: (N, d); queries: (Q, d). Scores are the f32 inner products
    queries @ table.T (matching the kernels, which cast to f32 before the
    MXU dot); ties break toward the smaller row index — `kind="stable"` on
    the negated scores is exactly that rule.

    Returns (vals (Q, k) f32, idx (Q, k) int32). Numpy (not jnp) on
    purpose: this is the serving subsystem's ground truth, so it must not
    share an execution path with anything it validates.
    """
    t = np.asarray(table).astype(np.float32)
    q = np.asarray(queries).astype(np.float32)
    scores = q @ t.T                                  # (Q, N) f32
    order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    vals = np.take_along_axis(scores, order, axis=1)
    return vals, order.astype(np.int32)
