"""Public jit'd ops over the SGNS kernels.

``impl`` selects the execution path:
  * ``"ref"``           — pure jnp (XLA). Default on CPU: fast and exact.
  * ``"pallas"``        — separate Pallas kernels: blocked gather → grads
                          (MXU tile kernel) → blocked scatter-add.
  * ``"pallas_fused"``  — one kernel for DMA-gather + grads; SGD apply still
                          runs as standalone scatter-add passes.
  * ``"pallas_fused2"`` — the pipelined fully-fused update kernel: gather,
                          grads, and SGD apply in a single pallas_call with
                          the tables aliased in-place (one HBM round-trip per
                          row; no separate scatters, no (idx_c ++ idx_n)
                          concatenate). This is the production pallas path.

Pallas kernels run in interpret mode on CPU, compiled on TPU.

`sgns_step` is the fused edge-minibatch update the hybrid trainer calls in
its inner loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import sgns as _k

_ON_TPU = jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _ON_TPU


def _pad_to(x: jax.Array, mult: int, axis: int = 0, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def sgns_grads(v, c, n, mask, *, impl: str = "ref", block_b: int = 256):
    """loss + (dv, dc, dn) for a shared-negative SGNS minibatch."""
    _check_impl(impl, ("ref", "pallas"))
    if impl == "ref":
        return _ref.sgns_grads_ref(v, c, n, mask)
    B = v.shape[0]
    bb = min(block_b, B) if B % min(block_b, B) == 0 else B
    vp, cp, mp = (_pad_to(v, bb), _pad_to(c, bb), _pad_to(mask, bb))
    loss, dv, dc, dn = _k.sgns_grads(vp, cp, n, mp, block_b=bb,
                                     interpret=_interpret())
    return loss, dv[:B], dc[:B], dn


STEP_IMPLS = ("ref", "pallas", "pallas_fused", "pallas_fused2")


def _check_impl(impl: str, allowed=STEP_IMPLS):
    if impl not in allowed:
        raise ValueError(f"unknown impl {impl!r}; expected one of {allowed}")


def gather_rows(table, idx, *, impl: str = "ref", rows_per_block: int = 8):
    _check_impl(impl, ("ref", "pallas"))
    if impl == "ref":
        return _ref.gather_rows_ref(table, idx)
    return _k.gather_rows(table, idx, rows_per_block=rows_per_block,
                          interpret=_interpret())


def scatter_add_rows(table, idx, upd, *, impl: str = "ref",
                     rows_per_block: int = 8):
    _check_impl(impl, ("ref", "pallas"))
    if impl == "ref":
        return _ref.scatter_add_rows_ref(table, idx, upd)
    return _k.scatter_add_rows(table, idx, upd,
                               rows_per_block=rows_per_block,
                               interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("impl", "reduction", "block_b"))
def sgns_step(vert, ctx, idx_v, idx_c, idx_n, mask, lr, *, impl: str = "ref",
              reduction: str = "sum", block_b: int = 256):
    """One SGNS SGD minibatch against local (vert, ctx) shards.

    vert: (Nv, d), ctx: (Nc, d); idx_v/idx_c: (B,), idx_n: (S,), mask: (B,).
    Returns (vert', ctx', summed loss).

    ``reduction="sum"`` is word2vec-faithful: every pair's gradient is applied
    at full lr, and a shared-negative row accumulates up to B aligned
    contributions per step. This matches Ji et al. [19] / BlazingText [20]
    shared-negative batching and is stable for small-to-moderate B (the
    trainer's minibatch config). ``"mean"`` divides by B — stable at any B but
    under-weights positives relative to the shared negatives (degenerates; see
    EXPERIMENTS.md §Perf ablation). Default: sum.
    """
    _check_impl(impl)
    lr_eff = lr / mask.shape[0] if reduction == "mean" else lr
    if impl == "ref":
        return _ref.sgns_step_ref(vert, ctx, idx_v, idx_c, idx_n, mask, lr_eff)
    if impl in ("pallas_fused", "pallas_fused2"):
        # both fused branches tile B by bb and pad with (index 0, mask 0)
        # rows, which produce zero grads
        B = idx_v.shape[0]
        bb = min(block_b, B)
        iv_p, ic_p, m_p = (_pad_to(idx_v, bb), _pad_to(idx_c, bb),
                           _pad_to(mask, bb))
        if impl == "pallas_fused2":
            # fully-fused pipelined update: the kernel applies -lr*grad
            # straight to the aliased tables — no standalone scatter passes,
            # no (idx_c ++ idx_n) concatenate round-trip through HBM. The
            # kernel's duplicate-combine write-back makes padded positions
            # write row 0's correct final value.
            return _k.sgns_fused_update(
                vert, ctx, iv_p, ic_p, idx_n, m_p, lr_eff, block_b=bb,
                interpret=_interpret())
        # pallas_fused: one kernel for DMA-gather + grads (rows never
        # round-trip HBM), then standalone scatters. Scatter the REAL rows
        # only: padded zero-grad rows would be wasted DMAs, and their
        # repeated index 0 would trip scatter_add_rows' duplicate check
        # into the serialized slow path.
        loss, dv, dc, dn = _k.sgns_fused_grads(
            vert, ctx, iv_p, ic_p, idx_n, m_p, block_b=bb,
            interpret=_interpret())
        vert = scatter_add_rows(vert, idx_v, -lr_eff * dv[:B], impl="pallas")
        idx_cn = jnp.concatenate([idx_c, idx_n])
        upd_cn = jnp.concatenate([-lr_eff * dc[:B], -lr_eff * dn])
        ctx = scatter_add_rows(ctx, idx_cn, upd_cn, impl="pallas")
        return vert, ctx, loss
    v = gather_rows(vert, idx_v, impl=impl)
    c = gather_rows(ctx, idx_c, impl=impl)
    n = gather_rows(ctx, idx_n, impl=impl)
    loss, dv, dc, dn = sgns_grads(v, c, n, mask, impl=impl, block_b=block_b)
    vert = scatter_add_rows(vert, idx_v, -lr_eff * dv, impl=impl)
    # combined ctx scatter (see ref.sgns_step_ref: keeps ctx aliasable)
    idx_cn = jnp.concatenate([idx_c, idx_n])
    upd_cn = jnp.concatenate([-lr_eff * dc, -lr_eff * dn])
    ctx = scatter_add_rows(ctx, idx_cn, upd_cn, impl=impl)
    return vert, ctx, loss
