"""Public jit'd ops over the SGNS kernels.

``impl`` selects the execution path:
  * ``"ref"``           — pure jnp (XLA). Default on CPU: fast and exact.
  * ``"pallas"``        — separate Pallas kernels: blocked gather → grads
                          (MXU tile kernel) → blocked scatter-add.
  * ``"pallas_fused"``  — one kernel for DMA-gather + grads; SGD apply still
                          runs as standalone scatter-add passes.
  * ``"pallas_fused2"`` — the pipelined fully-fused update kernel: gather,
                          grads, and SGD apply in a single pallas_call with
                          the tables aliased in-place (one HBM round-trip per
                          row; no separate scatters, no (idx_c ++ idx_n)
                          concatenate). This is the production pallas path.

Pallas kernels run in interpret mode on CPU, compiled on TPU.

`sgns_step` is the fused edge-minibatch update the hybrid trainer calls in
its inner loop. Its kernel launch geometry — tile size ``block_b``, the
duplicate-combine strategy, and how many minibatch rows fit one launch —
is picked at trace time by :func:`plan_fused_update` from
(B, d, S, dtype, VMEM budget); callers no longer guess a static knob
(pass ``block_b=`` only to override the autotuner).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import sgns as _k
from repro.launch import roofline

_ON_TPU = jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _ON_TPU


# --------------------------------------------------------------------------
# VMEM-aware launch-geometry autotuner. All decisions are made from static
# shape/dtype info at trace time, so they cost nothing at run time and the
# jit cache keys stay the same per shape.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FusedPlan:
    """Trace-time launch geometry for the fused SGNS update.

    block_b:    pipeline tile rows per grid step.
    combine:    duplicate-combine strategy ("eq" | "segsum").
    chunk_rows: max minibatch rows per kernel launch; sgns_step splits
                larger batches into sequential launches (each chunk's SGD
                apply lands before the next chunk's gathers — plain
                sequential minibatch SGD at a coarser grain).
    """

    block_b: int
    combine: str
    chunk_rows: int


def fused_update_vmem_bytes(B: int, d: int, S: int, dtype,
                            combine: str, staging_rows: int = 0) -> int:
    """Modeled VMEM scratch for one sgns_fused_update launch of B rows.

    Mirrors the scratch_shapes in kernels/sgns.py: gathered tables
    (v/c/n, table dtype), f32 grads (dv/dc/dn), plus the combine's own
    footprint — eq: the (B,B)/(B,S)/(S,S) equality matrices; segsum: the
    sorted finals (table dtype) and f32 segment-prefix buffers.

    staging_rows models a co-resident cache-tier miss-staging block (the
    tiered trainer streams a (staging_rows, d) cold-row block alongside
    the update); 0 — the default — is the resident path, byte-identical
    to the pre-tiering model.
    """
    item = jnp.dtype(dtype).itemsize
    L = B + S
    total = (2 * B + S) * d * item          # v_s, c_s, n_s
    total += (2 * B + S) * d * 4            # dv_s, dc_s, dn_s
    if combine == "eq":
        total += (B * B + B * S + S * S) * 4
    else:
        total += (B + L) * d * item + L * d * 4   # fv_s, fc_s, ps_s
    total += staging_rows * d * item        # cache miss-staging block
    return total


def choose_block_b(B: int, d: int, S: int, dtype,
                   vmem_budget: int = roofline.VMEM_BYTES,
                   staging_rows: int = 0) -> int:
    """Pipeline tile rows from (B, d, S, dtype, VMEM budget).

    The tile only drives the per-step working set (two f32 (bb, d) row
    tiles, the (bb, S) logits/grads, the f32 grad tiles) and the pipeline
    depth, so the rule is: big enough to feed the MXU (cap 256), small
    enough that a tile's compute working set stays well under the budget.
    Batches past the cap get >= 2 grid steps automatically, which is where
    the double-buffered gather actually has a compute phase to hide behind;
    small batches run a single tile (forcing 2 tiles at B <= 256 measurably
    hurts on the interpret-mode container and saves nothing on TPU — the
    whole gather is tiny).
    """
    # per-tile active rows: the gathered v/c tile slices (table dtype) plus
    # the f32 compute temporaries (v/c casts, dv/dc, the (bb, S) logits);
    # a cache-tier staging block shrinks the budget the tile can claim
    per_row = 2 * d * jnp.dtype(dtype).itemsize + 4 * (4 * d + 2 * S)
    budget = max(per_row * 8,
                 vmem_budget - staging_rows * d * jnp.dtype(dtype).itemsize)
    cap = max(8, budget // 8 // per_row)
    bb = min(256, B, cap)
    if bb >= 8:
        bb -= bb % 8                    # f32 sublane alignment
    return max(1, bb)


def plan_fused_update(B: int, d: int, S: int, dtype, *,
                      block_b: int | None = None,
                      combine: str | None = None,
                      vmem_budget: int = roofline.VMEM_BYTES,
                      staging_rows: int = 0) -> FusedPlan:
    """Pick (block_b, combine, chunk_rows) for a B-row fused update.

    combine: equality-matrix reference while its O(B²) matrices fit the
    budget, segment-sum beyond. chunk_rows: the largest block_b multiple
    whose modeled scratch fits the budget (>= one tile even if nothing
    "fits" — interpret mode has no real VMEM and TPU will simply spill).

    Deliberate tradeoff when chunking kicks in: combine is decided from
    the WHOLE padded batch, so a batch too big for eq runs segsum chunks
    sized by segsum's (smaller) footprint — the fewest launches. The
    alternative — eq-sized chunks, each running the MXU-friendly combine —
    means ~3x more launches, each re-DMAing the shared negatives and doing
    B'² multiplies where segsum does B'·d adds; which side wins is a real-
    TPU measurement (ROADMAP "VMEM model calibration"). Pass combine="eq"
    with a pinned block_b to force eq-sized chunks for that experiment.

    staging_rows reserves VMEM headroom for a co-resident cache-tier
    miss-staging block (tiered trainer); 0 keeps the plan identical to
    the pre-tiering model. NOTE: passing staging_rows to a call whose
    result feeds sgns_step can change block_b and thus the f32 gradient
    accumulation tiling — the tiered trainer therefore plans with the
    SAME (block_b=None, staging_rows=0) geometry as the resident path and
    uses this extended model only to validate that the geometry still
    fits with the staging block co-resident.
    """
    bb = block_b if block_b is not None else choose_block_b(
        B, d, S, dtype, vmem_budget, staging_rows)
    bb = min(bb, B)
    Bp = -(-B // bb) * bb               # rows after sgns_step's tile padding
    if combine is None:
        combine = ("eq"
                   if fused_update_vmem_bytes(Bp, d, S, dtype, "eq",
                                              staging_rows) <= vmem_budget
                   else "segsum")
    if fused_update_vmem_bytes(Bp, d, S, dtype, combine,
                               staging_rows) <= vmem_budget:
        chunk = Bp                      # whole batch in one launch
    else:
        chunk = bb
        while (chunk + bb < Bp
               and fused_update_vmem_bytes(chunk + bb, d, S, dtype, combine,
                                           staging_rows) <= vmem_budget):
            chunk += bb
    return FusedPlan(block_b=bb, combine=combine, chunk_rows=chunk)


def _pad_to(x: jax.Array, mult: int, axis: int = 0, fill=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def sgns_grads(v, c, n, mask, *, impl: str = "ref",
               block_b: int | None = None):
    """loss + (dv, dc, dn) for a shared-negative SGNS minibatch.

    block_b=None autotunes the tile size (choose_block_b)."""
    _check_impl(impl, ("ref", "pallas"))
    if impl == "ref":
        return _ref.sgns_grads_ref(v, c, n, mask)
    B, d = v.shape
    S = n.shape[0]
    if block_b is None:
        block_b = choose_block_b(B, d, S, v.dtype)
    bb = min(block_b, B)
    vp, cp, mp = (_pad_to(v, bb), _pad_to(c, bb), _pad_to(mask, bb))
    loss, dv, dc, dn = _k.sgns_grads(vp, cp, n, mp, block_b=bb,
                                     interpret=_interpret())
    return loss, dv[:B], dc[:B], dn


STEP_IMPLS = ("ref", "pallas", "pallas_fused", "pallas_fused2")


def _check_impl(impl: str, allowed=STEP_IMPLS):
    if impl not in allowed:
        raise ValueError(f"unknown impl {impl!r}; expected one of {allowed}")


def gather_rows(table, idx, *, impl: str = "ref", rows_per_block: int = 8):
    _check_impl(impl, ("ref", "pallas"))
    if impl == "ref":
        return _ref.gather_rows_ref(table, idx)
    return _k.gather_rows(table, idx, rows_per_block=rows_per_block,
                          interpret=_interpret())


def scatter_add_rows(table, idx, upd, *, impl: str = "ref",
                     rows_per_block: int = 8):
    _check_impl(impl, ("ref", "pallas"))
    if impl == "ref":
        return _ref.scatter_add_rows_ref(table, idx, upd)
    return _k.scatter_add_rows(table, idx, upd,
                               rows_per_block=rows_per_block,
                               interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("impl", "reduction", "block_b"))
def sgns_step(vert, ctx, idx_v, idx_c, idx_n, mask, lr, *, impl: str = "ref",
              reduction: str = "sum", block_b: int | None = None):
    """One SGNS SGD minibatch against local (vert, ctx) shards.

    vert: (Nv, d), ctx: (Nc, d); idx_v/idx_c: (B,), idx_n: (S,), mask: (B,).
    Returns (vert', ctx', summed loss).

    ``block_b=None`` (the default) autotunes the whole launch geometry via
    :func:`plan_fused_update`; pass an int to pin the tile size. Batches
    larger than the plan's VMEM-sized ``chunk_rows`` run as sequential
    fused launches (each chunk's SGD apply lands before the next chunk
    gathers — coarser-grained sequential SGD, loss is the sum over chunks).

    ``reduction="sum"`` is word2vec-faithful: every pair's gradient is applied
    at full lr, and a shared-negative row accumulates up to B aligned
    contributions per step. This matches Ji et al. [19] / BlazingText [20]
    shared-negative batching and is stable for small-to-moderate B (the
    trainer's minibatch config). ``"mean"`` divides by B — stable at any B but
    under-weights positives relative to the shared negatives (degenerates; see
    EXPERIMENTS.md §Perf ablation). Default: sum.
    """
    _check_impl(impl)
    lr_eff = lr / mask.shape[0] if reduction == "mean" else lr
    if impl == "ref":
        return _ref.sgns_step_ref(vert, ctx, idx_v, idx_c, idx_n, mask, lr_eff)
    if impl in ("pallas_fused", "pallas_fused2"):
        # both fused branches tile B by bb and pad with (index 0, mask 0)
        # rows, which produce zero grads
        B = idx_v.shape[0]
        d = vert.shape[1]
        S = idx_n.shape[0]
        plan = plan_fused_update(B, d, S, vert.dtype, block_b=block_b)
        bb = plan.block_b
        if impl == "pallas_fused2":
            # fully-fused pipelined update: the kernel applies -lr*grad
            # straight to the aliased tables — no standalone scatter passes,
            # no (idx_c ++ idx_n) concatenate round-trip through HBM. The
            # kernel's duplicate-combine write-back makes padded positions
            # write row 0's correct final value.
            if B <= plan.chunk_rows:
                iv_p, ic_p, m_p = (_pad_to(idx_v, bb), _pad_to(idx_c, bb),
                                   _pad_to(mask, bb))
                return _k.sgns_fused_update(
                    vert, ctx, iv_p, ic_p, idx_n, m_p, lr_eff, block_b=bb,
                    combine=plan.combine, interpret=_interpret())
            # chunked launches: B rows don't fit one launch's VMEM —
            # sequential fused updates over chunk_rows-row slices
            loss = jnp.float32(0.0)
            for s in range(0, B, plan.chunk_rows):
                e = min(s + plan.chunk_rows, B)
                iv_c, ic_c, m_c = (_pad_to(idx_v[s:e], bb),
                                   _pad_to(idx_c[s:e], bb),
                                   _pad_to(mask[s:e], bb))
                vert, ctx, lc = _k.sgns_fused_update(
                    vert, ctx, iv_c, ic_c, idx_n, m_c, lr_eff,
                    block_b=bb, combine=plan.combine,
                    interpret=_interpret())
                loss = loss + lc
            return vert, ctx, loss
        # pallas_fused: one kernel for DMA-gather + grads (rows never
        # round-trip HBM), then standalone scatters. Scatter the REAL rows
        # only: padded zero-grad rows would be wasted DMAs, and their
        # repeated index 0 would serialize the blocks they land in.
        iv_p, ic_p, m_p = (_pad_to(idx_v, bb), _pad_to(idx_c, bb),
                           _pad_to(mask, bb))
        loss, dv, dc, dn = _k.sgns_fused_grads(
            vert, ctx, iv_p, ic_p, idx_n, m_p, block_b=bb,
            interpret=_interpret())
        vert = scatter_add_rows(vert, idx_v, -lr_eff * dv[:B], impl="pallas")
        idx_cn = jnp.concatenate([idx_c, idx_n])
        upd_cn = jnp.concatenate([-lr_eff * dc[:B], -lr_eff * dn])
        ctx = scatter_add_rows(ctx, idx_cn, upd_cn, impl="pallas")
        return vert, ctx, loss
    v = gather_rows(vert, idx_v, impl=impl)
    c = gather_rows(ctx, idx_c, impl=impl)
    n = gather_rows(ctx, idx_n, impl=impl)
    loss, dv, dc, dn = sgns_grads(v, c, n, mask, impl=impl, block_b=block_b)
    vert = scatter_add_rows(vert, idx_v, -lr_eff * dv, impl=impl)
    # combined ctx scatter (see ref.sgns_step_ref: keeps ctx aliasable)
    idx_cn = jnp.concatenate([idx_c, idx_n])
    upd_cn = jnp.concatenate([-lr_eff * dc, -lr_eff * dn])
    ctx = scatter_add_rows(ctx, idx_cn, upd_cn, impl=impl)
    return vert, ctx, loss
