"""Blocked online-softmax (flash) attention Pallas kernel for TPU.

The transformer pool's perf-critical hot spot: q tiles stay resident in
VMEM while k/v tiles stream past; the running (max, denominator,
accumulator) update means the (Sq, Skv) score matrix is never materialized
in HBM — the memory term that dominates every dense train/prefill row in
EXPERIMENTS.md §Roofline.

Layout: q (B, H, Sq, hd), k/v (B, Hkv, Skv, hd) — GQA is handled in the
BlockSpec index maps (kv head = h // (H // Hkv)), no broadcast
materialization. Causal masking and sliding windows are applied from global
tile offsets. Validated against :func:`repro.kernels.ref_attention.mha_ref`
in interpret mode; TPU is the target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# renamed TPUCompilerParams -> CompilerParams across jax releases
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))
if _COMPILER_PARAMS is None:  # fail at import, not deep inside pallas_call
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; extend this shim for the installed jax")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  scale: float, causal: bool, window: int,
                  tile_q: int, tile_k: int, num_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (Tq, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (Tk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * tile_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * tile_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]                                # (Tq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                           # (Tq, Tk)
    alpha = jnp.exp(m_prev - m_new)                  # (Tq, 1)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ik == num_k - 1)
    def _finish():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc_s[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "tile_q",
                                             "tile_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    tile_q: int = 128, tile_k: int = 128,
                    interpret: bool = False):
    """q: (B,H,Sq,hd), k/v: (B,Hkv,Skv,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    G = H // Hkv
    tq, tk = min(tile_q, Sq), min(tile_k, Skv)
    assert Sq % tq == 0 and Skv % tk == 0, (Sq, tq, Skv, tk)
    nq, nk = Sq // tq, Skv // tk
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        tile_q=tq, tile_k=tk, num_k=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, tk, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, tk, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),    # running max
            pltpu.VMEM((tq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((tq, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def mha_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Pure-jnp oracle. Same layout as :func:`flash_attention`."""
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
