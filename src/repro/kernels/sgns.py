"""Pallas TPU kernels for the SGNS hot loop (the paper's CUDA kernel, §II-C).

The paper's performance model says node-embedding training is O(1) arithmetic
intensity and therefore memory-bound: the hot loop is gather rows → tiny
dot-products → scatter rows. The TPU-native rethink (DESIGN.md §6):

* **Shared-negative batching** (Ji et al. [19], adopted by the paper's lineage)
  turns the per-edge level-1 dot products into level-3 ``(B,d) @ (d,S)``
  matmuls — exactly the shape the 128×128 MXU wants.
* The whole fwd+bwd for a (Bt, d) tile lives in **VMEM**: one HBM round-trip
  per row, honoring the memory-bound analysis.
* Row gathers use **scalar-prefetched indices** so the index-dependent DMA
  address is known before the block runs (TPU has no hardware gather from
  HBM; scalar prefetch + per-row BlockSpec index_map is the idiom).

Kernels:
  * :func:`sgns_grads`      — dense tile kernel: loss + dv/dc/dn grads (MXU).
  * :func:`gather_rows`     — (N,d) table × (B,) idx → (B,d), scalar prefetch.
  * :func:`scatter_add_rows`— (N,d) table += upd at idx, aliased output.

All are validated against ``ref.py`` in interpret mode (CPU container); TPU is
the compilation target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# dense SGNS grads tile kernel
# --------------------------------------------------------------------------
def _sgns_grads_kernel(v_ref, c_ref, n_ref, mask_ref,
                       dv_ref, dc_ref, dn_ref, loss_ref):
    i = pl.program_id(0)
    v = v_ref[...].astype(jnp.float32)          # (Bt, d)
    c = c_ref[...].astype(jnp.float32)          # (Bt, d)
    n = n_ref[...].astype(jnp.float32)          # (S, d)
    m = mask_ref[...].astype(jnp.float32)       # (Bt, 1)

    pos = jnp.sum(v * c, axis=-1, keepdims=True)               # (Bt, 1)
    neg = jax.lax.dot_general(v, n, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Bt, S) MXU
    g_pos = (jax.nn.sigmoid(pos) - 1.0) * m                    # (Bt, 1)
    g_neg = jax.nn.sigmoid(neg) * m                            # (Bt, S)

    dv_ref[...] = (g_pos * c + jax.lax.dot_general(
        g_neg, n, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(dv_ref.dtype)
    dc_ref[...] = (g_pos * v).astype(dc_ref.dtype)

    dn_tile = jax.lax.dot_general(g_neg, v, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (S, d)
    loss_tile = (jnp.sum(m * jax.nn.softplus(-pos))
                 + jnp.sum(m * jax.nn.softplus(neg)))

    # dn and loss accumulate across the B grid (sequential on TPU).
    @pl.when(i == 0)
    def _init():
        dn_ref[...] = jnp.zeros_like(dn_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    dn_ref[...] += dn_tile.astype(dn_ref.dtype)
    loss_ref[...] += loss_tile.astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_grads(v, c, n, mask, *, block_b: int = 256, interpret: bool = False):
    """Pallas version of :func:`repro.kernels.ref.sgns_grads_ref`.

    v, c: (B, d); n: (S, d); mask: (B,). B must be a multiple of block_b
    (ops.py pads). d, S should be multiples of 128 / 8 for MXU alignment on
    real hardware; interpret mode accepts anything.
    """
    B, d = v.shape
    S = n.shape[0]
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    mask2 = mask.reshape(B, 1)
    out_shape = (
        jax.ShapeDtypeStruct((B, d), v.dtype),      # dv
        jax.ShapeDtypeStruct((B, d), c.dtype),      # dc
        jax.ShapeDtypeStruct((S, d), jnp.float32),  # dn (accumulated)
        jax.ShapeDtypeStruct((1, 1), jnp.float32),  # loss
    )
    loss_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    dv, dc, dn, loss = pl.pallas_call(
        _sgns_grads_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),   # v
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),   # c
            pl.BlockSpec((S, d), lambda i: (0, 0)),         # n (resident)
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),   # mask
        ],
        out_specs=(
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((S, d), lambda i: (0, 0)),
            loss_spec,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(v, c, n, mask2)
    return loss[0, 0], dv, dc, dn.astype(n.dtype)


# --------------------------------------------------------------------------
# FUSED kernel: DMA-gather + grads in one pallas_call (the paper's fused
# CUDA hot loop, TPU-native: per-row HBM->VMEM async copies from scalar-
# prefetched indices feed the same MXU tile math as `_sgns_grads_kernel`,
# so gathered rows never round-trip through HBM between gather and compute).
# --------------------------------------------------------------------------
def _sgns_fused_kernel(iv_ref, ic_ref, in_ref, vert_ref, ctx_ref, mask_ref,
                       dv_ref, dc_ref, dn_ref, loss_ref,
                       v_s, c_s, n_s, sem):
    i = pl.program_id(0)
    Bt = v_s.shape[0]
    S = n_s.shape[0]

    @pl.when(i == 0)
    def _load_negatives():           # shared negatives persist across tiles
        for s in range(S):
            cp = pltpu.make_async_copy(ctx_ref.at[in_ref[s]], n_s.at[s], sem)
            cp.start()
            cp.wait()

    for j in range(Bt):              # gather this tile's rows into VMEM
        cp = pltpu.make_async_copy(vert_ref.at[iv_ref[i * Bt + j]],
                                   v_s.at[j], sem)
        cp.start()
        cp.wait()
        cp = pltpu.make_async_copy(ctx_ref.at[ic_ref[i * Bt + j]],
                                   c_s.at[j], sem)
        cp.start()
        cp.wait()

    v = v_s[...].astype(jnp.float32)
    c = c_s[...].astype(jnp.float32)
    n = n_s[...].astype(jnp.float32)
    m = mask_ref[...].astype(jnp.float32)

    pos = jnp.sum(v * c, axis=-1, keepdims=True)
    neg = jax.lax.dot_general(v, n, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    g_pos = (jax.nn.sigmoid(pos) - 1.0) * m
    g_neg = jax.nn.sigmoid(neg) * m

    dv_ref[...] = (g_pos * c + jax.lax.dot_general(
        g_neg, n, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(dv_ref.dtype)
    dc_ref[...] = (g_pos * v).astype(dc_ref.dtype)
    dn_tile = jax.lax.dot_general(g_neg, v, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    loss_tile = (jnp.sum(m * jax.nn.softplus(-pos))
                 + jnp.sum(m * jax.nn.softplus(neg)))

    @pl.when(i == 0)
    def _init():
        dn_ref[...] = jnp.zeros_like(dn_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    dn_ref[...] += dn_tile.astype(dn_ref.dtype)
    loss_ref[...] += loss_tile.astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_fused_grads(vert, ctx, idx_v, idx_c, idx_n, mask, *,
                     block_b: int = 256, interpret: bool = False):
    """Fused gather+grads: rows are DMA'd from the (HBM-resident) tables by
    index inside the kernel. Returns (loss, dv, dc, dn) like sgns_grads.

    vert: (Nv, d); ctx: (Nc, d); idx_v/idx_c: (B,); idx_n: (S,); mask: (B,).
    """
    B = idx_v.shape[0]
    d = vert.shape[1]
    S = idx_n.shape[0]
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    mask2 = mask.reshape(B, 1)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),              # vert (HBM)
            pl.BlockSpec(memory_space=pl.ANY),              # ctx (HBM)
            pl.BlockSpec((bb, 1), lambda i, *_: (i, 0)),    # mask tile
        ],
        out_specs=(
            pl.BlockSpec((bb, d), lambda i, *_: (i, 0)),    # dv
            pl.BlockSpec((bb, d), lambda i, *_: (i, 0)),    # dc
            pl.BlockSpec((S, d), lambda i, *_: (0, 0)),     # dn (accum)
            pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),     # loss
        ),
        scratch_shapes=[
            pltpu.VMEM((bb, d), vert.dtype),
            pltpu.VMEM((bb, d), ctx.dtype),
            pltpu.VMEM((S, d), ctx.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    dv, dc, dn, loss = pl.pallas_call(
        _sgns_fused_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, d), vert.dtype),
            jax.ShapeDtypeStruct((B, d), ctx.dtype),
            jax.ShapeDtypeStruct((S, d), f32),
            jax.ShapeDtypeStruct((1, 1), f32),
        ),
        interpret=interpret,
    )(idx_v.astype(jnp.int32), idx_c.astype(jnp.int32),
      idx_n.astype(jnp.int32), vert, ctx, mask2)
    return loss[0, 0], dv, dc, dn.astype(ctx.dtype)


# --------------------------------------------------------------------------
# row gather via scalar-prefetched indices
# --------------------------------------------------------------------------
def _gather_kernel(idx_ref, table_ref, out_ref):
    del idx_ref  # consumed by the index_map
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows(table, idx, *, interpret: bool = False):
    """(N, d) table, (B,) int32 → (B, d). One grid step per row; the row
    address comes from the scalar-prefetched index vector (HBM→VMEM DMA)."""
    B = idx.shape[0]
    N, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


# --------------------------------------------------------------------------
# row scatter-add (aliased in/out, sequential grid ⇒ duplicates accumulate)
# --------------------------------------------------------------------------
def _scatter_add_kernel(idx_ref, table_ref, upd_ref, out_ref):
    del idx_ref, table_ref  # table is aliased to out; its rows arrive in out_ref
    out_ref[...] += upd_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_add_rows(table, idx, upd, *, interpret: bool = False):
    """table[idx[i]] += upd[i]. The table is aliased input→output; the TPU
    grid is sequential, so revisiting a row reads the previously written
    block (read-modify-write semantics)."""
    B = idx.shape[0]
    N, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),           # table: alias only
            pl.BlockSpec((1, d), lambda i, idx: (i, 0)),    # upd row
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0)),
    )
    return pl.pallas_call(
        _scatter_add_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, d), table.dtype),
        # operand 0 is the scalar-prefetch idx; operand 1 is `table`.
        input_output_aliases={1: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), table, upd)
