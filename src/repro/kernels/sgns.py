"""Pallas TPU kernels for the SGNS hot loop (the paper's CUDA kernel, §II-C).

The paper's performance model says node-embedding training is O(1) arithmetic
intensity and therefore memory-bound: the hot loop is gather rows → tiny
dot-products → scatter rows. The TPU-native rethink (DESIGN.md §6):

* **Shared-negative batching** (Ji et al. [19], adopted by the paper's lineage)
  turns the per-edge level-1 dot products into level-3 ``(B,d) @ (d,S)``
  matmuls — exactly the shape the 128×128 MXU wants.
* The whole fwd+bwd for a (Bt, d) tile lives in **VMEM**: one HBM round-trip
  per row, honoring the memory-bound analysis.
* Row gathers use **scalar-prefetched indices** so the index-dependent DMA
  address is known before the block runs (TPU has no hardware gather from
  HBM; scalar prefetch + per-row BlockSpec index_map is the idiom).

Kernels:
  * :func:`sgns_grads`        — dense tile kernel: loss + dv/dc/dn grads (MXU).
  * :func:`sgns_fused_grads`  — DMA-gather + grads in one launch (no apply).
  * :func:`sgns_fused_update` — the paper's full fused hot loop: pipelined
    double-buffered gather → grads → **in-kernel SGD apply** straight back to
    the HBM-resident tables (aliased outputs). One HBM round-trip per row.
    Duplicate scatter targets combine via an O(B²) equality-matrix matmul
    (small B, the reference) or an O(B·d) sort-based segment sum (large B).
  * :func:`gather_rows`       — multi-row blocks, overlapped async row copies.
  * :func:`scatter_add_rows`  — multi-row blocks; per-block duplicate flags —
    only a block with an internal collision serializes its RMW.
  * ``*_rowwise``             — the original one-row-per-grid-step layouts,
    kept as the interpret-mode reference implementations.

All are validated against ``ref.py`` in interpret mode (CPU container); TPU is
the compilation target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# shared tile math: the SGNS fwd+bwd every kernel in this file runs on the
# MXU. One definition so a formula fix can't silently diverge the kernels.
# --------------------------------------------------------------------------
def _tile_grads(v, c, n, m):
    """v, c: (Bt, d); n: (S, d); m: (Bt, 1) — all f32.
    Returns (dv, dc, dn_tile, loss_tile) in f32."""
    f32 = jnp.float32
    pos = jnp.sum(v * c, axis=-1, keepdims=True)               # (Bt, 1)
    neg = jax.lax.dot_general(v, n, (((1,), (1,)), ((), ())),
                              preferred_element_type=f32)      # (Bt, S) MXU
    g_pos = (jax.nn.sigmoid(pos) - 1.0) * m                    # (Bt, 1)
    g_neg = jax.nn.sigmoid(neg) * m                            # (Bt, S)
    dv = g_pos * c + jax.lax.dot_general(
        g_neg, n, (((1,), (0,)), ((), ())), preferred_element_type=f32)
    dc = g_pos * v
    dn_tile = jax.lax.dot_general(g_neg, v, (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32)  # (S, d)
    loss_tile = (jnp.sum(m * jax.nn.softplus(-pos))
                 + jnp.sum(m * jax.nn.softplus(neg)))
    return dv, dc, dn_tile, loss_tile


# --------------------------------------------------------------------------
# dense SGNS grads tile kernel
# --------------------------------------------------------------------------
def _sgns_grads_kernel(v_ref, c_ref, n_ref, mask_ref,
                       dv_ref, dc_ref, dn_ref, loss_ref):
    i = pl.program_id(0)
    f32 = jnp.float32
    dv, dc, dn_tile, loss_tile = _tile_grads(
        v_ref[...].astype(f32), c_ref[...].astype(f32),
        n_ref[...].astype(f32), mask_ref[...].astype(f32))
    dv_ref[...] = dv.astype(dv_ref.dtype)
    dc_ref[...] = dc.astype(dc_ref.dtype)

    # dn and loss accumulate across the B grid (sequential on TPU).
    @pl.when(i == 0)
    def _init():
        dn_ref[...] = jnp.zeros_like(dn_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    dn_ref[...] += dn_tile.astype(dn_ref.dtype)
    loss_ref[...] += loss_tile.astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_grads(v, c, n, mask, *, block_b: int = 256, interpret: bool = False):
    """Pallas version of :func:`repro.kernels.ref.sgns_grads_ref`.

    v, c: (B, d); n: (S, d); mask: (B,). B must be a multiple of block_b
    (ops.py pads). d, S should be multiples of 128 / 8 for MXU alignment on
    real hardware; interpret mode accepts anything.
    """
    B, d = v.shape
    S = n.shape[0]
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    mask2 = mask.reshape(B, 1)
    out_shape = (
        jax.ShapeDtypeStruct((B, d), v.dtype),      # dv
        jax.ShapeDtypeStruct((B, d), c.dtype),      # dc
        jax.ShapeDtypeStruct((S, d), jnp.float32),  # dn (accumulated)
        jax.ShapeDtypeStruct((1, 1), jnp.float32),  # loss
    )
    loss_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    dv, dc, dn, loss = pl.pallas_call(
        _sgns_grads_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),   # v
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),   # c
            pl.BlockSpec((S, d), lambda i: (0, 0)),         # n (resident)
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),   # mask
        ],
        out_specs=(
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((S, d), lambda i: (0, 0)),
            loss_spec,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(v, c, n, mask2)
    return loss[0, 0], dv, dc, dn.astype(n.dtype)


# --------------------------------------------------------------------------
# FUSED kernel: DMA-gather + grads in one pallas_call (the paper's fused
# CUDA hot loop, TPU-native: per-row HBM->VMEM async copies from scalar-
# prefetched indices feed the same MXU tile math as `_sgns_grads_kernel`,
# so gathered rows never round-trip through HBM between gather and compute).
# --------------------------------------------------------------------------
def _sgns_fused_kernel(iv_ref, ic_ref, in_ref, vert_ref, ctx_ref, mask_ref,
                       dv_ref, dc_ref, dn_ref, loss_ref,
                       v_s, c_s, n_s, sem):
    i = pl.program_id(0)
    Bt = v_s.shape[0]
    S = n_s.shape[0]

    @pl.when(i == 0)
    def _load_negatives():           # shared negatives persist across tiles
        for s in range(S):
            cp = pltpu.make_async_copy(ctx_ref.at[in_ref[s]], n_s.at[s], sem)
            cp.start()
            cp.wait()

    for j in range(Bt):              # gather this tile's rows into VMEM
        cp = pltpu.make_async_copy(vert_ref.at[iv_ref[i * Bt + j]],
                                   v_s.at[j], sem)
        cp.start()
        cp.wait()
        cp = pltpu.make_async_copy(ctx_ref.at[ic_ref[i * Bt + j]],
                                   c_s.at[j], sem)
        cp.start()
        cp.wait()

    f32 = jnp.float32
    dv, dc, dn_tile, loss_tile = _tile_grads(
        v_s[...].astype(f32), c_s[...].astype(f32), n_s[...].astype(f32),
        mask_ref[...].astype(f32))
    dv_ref[...] = dv.astype(dv_ref.dtype)
    dc_ref[...] = dc.astype(dc_ref.dtype)

    @pl.when(i == 0)
    def _init():
        dn_ref[...] = jnp.zeros_like(dn_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    dn_ref[...] += dn_tile.astype(dn_ref.dtype)
    loss_ref[...] += loss_tile.astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_fused_grads(vert, ctx, idx_v, idx_c, idx_n, mask, *,
                     block_b: int = 256, interpret: bool = False):
    """Fused gather+grads: rows are DMA'd from the (HBM-resident) tables by
    index inside the kernel. Returns (loss, dv, dc, dn) like sgns_grads.

    vert: (Nv, d); ctx: (Nc, d); idx_v/idx_c: (B,); idx_n: (S,); mask: (B,).
    """
    B = idx_v.shape[0]
    d = vert.shape[1]
    S = idx_n.shape[0]
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    mask2 = mask.reshape(B, 1)
    f32 = jnp.float32
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),              # vert (HBM)
            pl.BlockSpec(memory_space=pl.ANY),              # ctx (HBM)
            pl.BlockSpec((bb, 1), lambda i, *_: (i, 0)),    # mask tile
        ],
        out_specs=(
            pl.BlockSpec((bb, d), lambda i, *_: (i, 0)),    # dv
            pl.BlockSpec((bb, d), lambda i, *_: (i, 0)),    # dc
            pl.BlockSpec((S, d), lambda i, *_: (0, 0)),     # dn (accum)
            pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),     # loss
        ),
        scratch_shapes=[
            pltpu.VMEM((bb, d), vert.dtype),
            pltpu.VMEM((bb, d), ctx.dtype),
            pltpu.VMEM((S, d), ctx.dtype),
            pltpu.SemaphoreType.DMA,
        ],
    )
    dv, dc, dn, loss = pl.pallas_call(
        _sgns_fused_kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, d), vert.dtype),
            jax.ShapeDtypeStruct((B, d), ctx.dtype),
            jax.ShapeDtypeStruct((S, d), f32),
            jax.ShapeDtypeStruct((1, 1), f32),
        ),
        interpret=interpret,
    )(idx_v.astype(jnp.int32), idx_c.astype(jnp.int32),
      idx_n.astype(jnp.int32), vert, ctx, mask2)
    return loss[0, 0], dv, dc, dn.astype(ctx.dtype)


# --------------------------------------------------------------------------
# FULLY-FUSED pipelined update kernel (the tentpole): double-buffered DMA
# gather → MXU tile grads → in-kernel SGD apply to the aliased HBM tables.
#
# Pipeline (grid step = one (bb, d) tile, sequential on TPU):
#   step i:  start tile i+1's row gathers   (rotating sem slot (i+1) % 2)
#            wait  tile i's   row gathers   (sem slot i % 2 — started at i-1)
#            tile math on the MXU           (overlaps tile i+1's copies)
#   last step: duplicate-combine + write-back (see below).
#
# Scatter-accumulate semantics without read-modify-write: all B rows were
# gathered *pre-update*, so the final value of table row r is
#   orig[r] - lr * Σ_{positions p with idx[p]==r} grad[p].
# Every position then writes the SAME final value for its row, so the
# write-back is pure pipelined DMA with no RAW hazards — duplicate writes
# race benignly (identical bytes). ctx duplicates may span idx_c and idx_n;
# the combine runs over the concatenated (idx_c ++ idx_n) index space, which
# is also what lets ops.sgns_step drop its concatenate round-trip through
# HBM. Padded rows (mask 0, index 0) fold in for free: their grads are zero,
# and the combine makes them write row 0's correct final value.
#
# Two duplicate-combine strategies (`combine=`):
#   * "eq"     — (B, B) equality-matrix matmuls (MXU-friendly). O(B²) VMEM:
#                the reference path, caps B per launch at ~2k rows (f32).
#   * "segsum" — sort-based segment-sum: the host argsorts the index vectors
#                once (XLA), the kernel runs a forward segment-prefix pass
#                and a backward run-total broadcast over the sorted runs —
#                O(B·d) memory and work, so B ≫ 2k fits in one launch. The
#                sorted order also means the write-back touches each table
#                row's duplicates consecutively.
# --------------------------------------------------------------------------
_NWRITE = 4   # write-back semaphore ring depth (max outstanding row writes)

# largest B for which a direct sgns_fused_update call auto-selects the
# equality-matrix combine ((B, B) f32 = 4 MB here); ops.plan_fused_update
# makes the production decision from the full VMEM model instead
_EQ_COMBINE_MAX_B = 1024


def _fused_main_body(i, iv_ref, ic_ref, in_ref, vert_hbm, ctx_hbm, mask_ref,
                     loss_ref, v_s, c_s, n_s, dv_s, dc_s, dn_s, gsem, nsem):
    """Shared per-grid-step body of both fused-update kernels: the double-
    buffered row-gather pipeline + MXU tile grads + loss/dn accumulation."""
    T = pl.num_programs(0)
    bb = mask_ref.shape[0]
    S = n_s.shape[0]
    f32 = jnp.float32

    def tile_copies(t, op):
        """start/wait the 2*bb row DMAs of tile t on sem slot t % 2."""
        def body(j, _):
            r = t * bb + j
            getattr(pltpu.make_async_copy(
                vert_hbm.at[iv_ref[r]], v_s.at[r], gsem.at[t % 2]), op)()
            getattr(pltpu.make_async_copy(
                ctx_hbm.at[ic_ref[r]], c_s.at[r], gsem.at[t % 2]), op)()
            return 0
        jax.lax.fori_loop(0, bb, body, 0)

    @pl.when(i == 0)
    def _prologue():
        # shared negatives: start first so they overlap tile 0's gathers
        def nstart(s, _):
            pltpu.make_async_copy(ctx_hbm.at[in_ref[s]], n_s.at[s],
                                  nsem).start()
            return 0
        jax.lax.fori_loop(0, S, nstart, 0)
        tile_copies(0, "start")
        def nwait(s, _):
            pltpu.make_async_copy(ctx_hbm.at[in_ref[s]], n_s.at[s],
                                  nsem).wait()
            return 0
        jax.lax.fori_loop(0, S, nwait, 0)

    @pl.when(i + 1 < T)
    def _prefetch_next():          # double buffering: next tile's DMAs fly
        tile_copies(i + 1, "start")   # while this tile computes

    tile_copies(i, "wait")

    dv, dc, dn_tile, loss_tile = _tile_grads(
        v_s[pl.ds(i * bb, bb), :].astype(f32),
        c_s[pl.ds(i * bb, bb), :].astype(f32),
        n_s[...].astype(f32), mask_ref[...].astype(f32))
    dv_s[pl.ds(i * bb, bb), :] = dv
    dc_s[pl.ds(i * bb, bb), :] = dc

    @pl.when(i == 0)
    def _init():
        dn_s[...] = jnp.zeros_like(dn_s)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    dn_s[...] += dn_tile
    loss_ref[...] += loss_tile


def _write_rows(src, idx_sref, tbl_out, count, wsem):
    """Pipelined row write-back: semaphore ring, _NWRITE in flight."""
    def body(p, _):
        @pl.when(p >= _NWRITE)
        def _retire():
            q = p - _NWRITE
            pltpu.make_async_copy(
                src.at[q], tbl_out.at[idx_sref[q]],
                wsem.at[q % _NWRITE]).wait()
        pltpu.make_async_copy(src.at[p], tbl_out.at[idx_sref[p]],
                              wsem.at[p % _NWRITE]).start()
        return 0
    jax.lax.fori_loop(0, count, body, 0)
    for p in range(max(0, count - _NWRITE), count):   # drain
        pltpu.make_async_copy(src.at[p], tbl_out.at[idx_sref[p]],
                              wsem.at[p % _NWRITE]).wait()


def _write_rows_unique(src, upos_ref, idx_sref, tbl_out, count, wsem):
    """Deduplicated pipelined write-back: one row DMA per *unique* scatter
    target instead of one per position.

    upos_ref[j] is the sorted position holding the j-th run's final bytes
    (host plan: _unique_write_plan); count — the number of runs — is a
    traced SMEM scalar, so both loops are dynamic-bound fori_loops (the
    static-drain idiom of _write_rows needs a python range). On skewed
    batches hub rows collapse many positions into one DMA; the written
    bytes are identical because every position of a run emits the same
    final row, so this also retires the old benign write race.
    """
    def body(p, _):
        @pl.when(p >= _NWRITE)
        def _retire():
            q = p - _NWRITE
            pltpu.make_async_copy(
                src.at[upos_ref[q]], tbl_out.at[idx_sref[upos_ref[q]]],
                wsem.at[q % _NWRITE]).wait()
        pltpu.make_async_copy(
            src.at[upos_ref[p]], tbl_out.at[idx_sref[upos_ref[p]]],
            wsem.at[p % _NWRITE]).start()
        return 0
    jax.lax.fori_loop(0, count, body, 0)

    def drain(p, _):
        pltpu.make_async_copy(
            src.at[upos_ref[p]], tbl_out.at[idx_sref[upos_ref[p]]],
            wsem.at[p % _NWRITE]).wait()
        return 0
    jax.lax.fori_loop(jnp.maximum(count - _NWRITE, 0), count, drain, 0)


def _sgns_update_kernel(iv_ref, ic_ref, in_ref,               # scalar prefetch
                        vert_hbm, ctx_hbm, ivv_ref, icv_ref, inv_ref,
                        mask_ref, lr_ref,
                        vert_out, ctx_out, loss_ref,
                        v_s, c_s, n_s, dv_s, dc_s, dn_s,
                        gsem, nsem, wsem):
    i = pl.program_id(0)
    T = pl.num_programs(0)
    B, d = v_s.shape
    S = n_s.shape[0]
    f32 = jnp.float32
    _fused_main_body(i, iv_ref, ic_ref, in_ref, vert_hbm, ctx_hbm, mask_ref,
                     loss_ref, v_s, c_s, n_s, dv_s, dc_s, dn_s, gsem, nsem)

    @pl.when(i == T - 1)
    def _apply():
        lr = lr_ref[0, 0]
        iv = ivv_ref[...]                                    # (B, 1) i32
        ic = icv_ref[...]
        inn = inv_ref[...]                                   # (S, 1) i32
        dot = functools.partial(jax.lax.dot_general,
                                preferred_element_type=f32)
        # duplicate-combine: position-level grad sums per table row
        eq_vv = (iv == iv.reshape(1, B)).astype(f32)         # (B, B)
        dvsum = dot(eq_vv, dv_s[...], (((1,), (0,)), ((), ())))
        eq_cc = (ic == ic.reshape(1, B)).astype(f32)         # (B, B)
        eq_cn = (ic == inn.reshape(1, S)).astype(f32)        # (B, S)
        eq_nn = (inn == inn.reshape(1, S)).astype(f32)       # (S, S)
        dcsum = (dot(eq_cc, dc_s[...], (((1,), (0,)), ((), ())))
                 + dot(eq_cn, dn_s[...], (((1,), (0,)), ((), ()))))
        dnsum = (dot(eq_cn, dc_s[...], (((0,), (0,)), ((), ())))
                 + dot(eq_nn, dn_s[...], (((1,), (0,)), ((), ()))))
        # in-place SGD (update cast to table dtype first, like the ref's
        # scatter-add of a cast update)
        v_s[...] = v_s[...] + (-lr * dvsum).astype(v_s.dtype)
        c_s[...] = c_s[...] + (-lr * dcsum).astype(c_s.dtype)
        n_s[...] = n_s[...] + (-lr * dnsum).astype(n_s.dtype)

        _write_rows(v_s, iv_ref, vert_out, B, wsem)
        _write_rows(c_s, ic_ref, ctx_out, B, wsem)
        _write_rows(n_s, in_ref, ctx_out, S, wsem)


def _sgns_update_kernel_segsum(iv_ref, ic_ref, in_ref,        # scalar prefetch
                               pv_ref, ivs_ref, vflag_ref,
                               pc_ref, icns_ref, cflag_ref,
                               uv_ref, nv_ref, uc_ref, nc_ref,
                               vert_hbm, ctx_hbm, mask_ref, lr_ref,
                               vert_out, ctx_out, loss_ref,
                               v_s, c_s, n_s, dv_s, dc_s, dn_s,
                               fv_s, fc_s, ps_s,
                               gsem, nsem, wsem):
    """Fused update with the sort-based segment-sum duplicate-combine.

    The host argsorted the index vectors: pv/pc map sorted position → batch
    position (ctx positions p ≥ B address idx_n's grads dn_s[p - B]); ivs/
    icns are the sorted indices (the write-back targets); vflag/cflag pack
    run boundaries (bit 0 = first of its run, bit 1 = last). The combine is
    two O(B) passes per side instead of an O(B²) equality matmul:

      forward:  acc resets at each run start; ps[j] = prefix sum of the
                run's grads up to sorted position j.
      backward: the run total (ps at the run's last position) propagates
                back over the run; every position emits its row's final
                value orig - lr·total into fv/fc.

    All positions of a run emit identical bytes; the write-back issues ONE
    DMA per run (uv/uc list each run's last sorted position, nv/nc count
    the runs) instead of one per position — on skewed batches the hub rows
    that dominate collapse to single writes.
    """
    i = pl.program_id(0)
    T = pl.num_programs(0)
    B, d = v_s.shape
    S = n_s.shape[0]
    L = B + S
    f32 = jnp.float32
    _fused_main_body(i, iv_ref, ic_ref, in_ref, vert_hbm, ctx_hbm, mask_ref,
                     loss_ref, v_s, c_s, n_s, dv_s, dc_s, dn_s, gsem, nsem)

    @pl.when(i == T - 1)
    def _apply():
        lr = lr_ref[0, 0]

        def combine(count, perm_ref, flag_ref, grad_row, orig_row, out_buf):
            zero = jnp.zeros((1, d), f32)

            def fwd(j, acc):
                g = grad_row(perm_ref[j])
                acc = jnp.where((flag_ref[j] & 1) == 1, g, acc + g)
                ps_s[pl.ds(j, 1), :] = acc
                return acc
            jax.lax.fori_loop(0, count, fwd, zero)

            def bwd(t, tot):
                j = count - 1 - t
                tot = jnp.where((flag_ref[j] & 2) == 2,
                                ps_s[pl.ds(j, 1), :], tot)
                # same op structure as the eq path's in-place SGD: the
                # combined update is cast to the table dtype, the add runs
                # in the table dtype
                out_buf[pl.ds(j, 1), :] = (
                    orig_row(perm_ref[j]) + (-lr * tot).astype(out_buf.dtype))
                return tot
            jax.lax.fori_loop(0, count, bwd, zero)

        combine(B, pv_ref, vflag_ref,
                lambda p: dv_s[pl.ds(p, 1), :],
                lambda p: v_s[pl.ds(p, 1), :], fv_s)

        # ctx side runs over the concatenated (idx_c ++ idx_n) position
        # space: p < B is a positive-context grad, p >= B a shared-negative
        # grad — this is exactly the cross-coupling the eq path's eq_cn
        # blocks provided
        def c_grad(p):
            pc = jnp.minimum(p, B - 1)
            pn = jnp.maximum(p - B, 0)
            return jnp.where(p < B, dc_s[pl.ds(pc, 1), :],
                             dn_s[pl.ds(pn, 1), :])

        def c_orig(p):
            pc = jnp.minimum(p, B - 1)
            pn = jnp.maximum(p - B, 0)
            return jnp.where(p < B, c_s[pl.ds(pc, 1), :],
                             n_s[pl.ds(pn, 1), :])

        combine(L, pc_ref, cflag_ref, c_grad, c_orig, fc_s)

        _write_rows_unique(fv_s, uv_ref, ivs_ref, vert_out, nv_ref[0], wsem)
        _write_rows_unique(fc_s, uc_ref, icns_ref, ctx_out, nc_ref[0], wsem)


def _run_flags(sorted_idx):
    """Bit 0: first position of its equal-index run; bit 1: last."""
    brk = sorted_idx[1:] != sorted_idx[:-1]
    one = jnp.ones((1,), bool)
    start = jnp.concatenate([one, brk])
    end = jnp.concatenate([brk, one])
    return start.astype(jnp.int32) | (end.astype(jnp.int32) << 1)


def _unique_write_plan(sorted_idx):
    """Write-back dedup plan for a sorted scatter-index vector.

    Returns (upos, n): upos[j] is the sorted position whose buffer row
    holds run j's final bytes (the run's last position — every position of
    a run emits identical bytes, see the segsum kernel), n (shape (1,)) is
    the run count. upos entries past n are zero padding the kernel's
    dynamic-bound write loop never reads.
    """
    L = sorted_idx.shape[0]
    ar = jnp.arange(L, dtype=jnp.int32)
    start = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_idx[1:] != sorted_idx[:-1]])
    rank = jnp.cumsum(start.astype(jnp.int32)) - 1
    upos = jnp.zeros((L,), jnp.int32).at[rank].max(ar)
    return upos, (rank[-1] + 1).reshape(1)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "combine", "interpret"))
def sgns_fused_update(vert, ctx, idx_v, idx_c, idx_n, mask, lr, *,
                      block_b: int = 256, combine: str | None = None,
                      interpret: bool = False):
    """One fully-fused SGNS SGD minibatch: gather + grads + apply in a single
    pallas_call with the tables aliased input→output.

    vert: (Nv, d); ctx: (Nc, d) (same dtype); idx_v/idx_c: (B,); idx_n: (S,);
    mask: (B,); lr: scalar. B must be a multiple of min(block_b, B) —
    ops.sgns_step pads. Returns (vert', ctx', loss).

    ``combine`` selects the duplicate-combine strategy: ``"eq"`` (equality-
    matrix matmuls, O(B²) VMEM — the small-B reference), ``"segsum"``
    (sort-based segment sum, O(B·d) — scales to B ≫ 2k), or ``None`` to pick
    by B. ops.plan_fused_update makes the production choice from the full
    VMEM model.
    """
    B = idx_v.shape[0]
    d = vert.shape[1]
    S = idx_n.shape[0]
    assert vert.dtype == ctx.dtype, (vert.dtype, ctx.dtype)
    if combine is None:
        combine = "eq" if B <= _EQ_COMBINE_MAX_B else "segsum"
    assert combine in ("eq", "segsum"), combine
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    f32 = jnp.float32
    iv32 = idx_v.astype(jnp.int32)
    ic32 = idx_c.astype(jnp.int32)
    in32 = idx_n.astype(jnp.int32)
    out_shape = (
        jax.ShapeDtypeStruct(vert.shape, vert.dtype),
        jax.ShapeDtypeStruct(ctx.shape, ctx.dtype),
        jax.ShapeDtypeStruct((1, 1), f32),
    )
    table_scratch = [
        pltpu.VMEM((B, d), vert.dtype),                  # v_s
        pltpu.VMEM((B, d), ctx.dtype),                   # c_s
        pltpu.VMEM((S, d), ctx.dtype),                   # n_s
        pltpu.VMEM((B, d), f32),                         # dv_s
        pltpu.VMEM((B, d), f32),                         # dc_s
        pltpu.VMEM((S, d), f32),                         # dn_s
    ]
    sems = [
        pltpu.SemaphoreType.DMA((2,)),                   # gather (rotating)
        pltpu.SemaphoreType.DMA,                         # negatives
        pltpu.SemaphoreType.DMA((_NWRITE,)),             # write-back ring
    ]
    if combine == "eq":
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B // bb,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),        # vert (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),        # ctx (HBM)
                pl.BlockSpec((B, 1), lambda i, *_: (0, 0)),  # idx_v as vector
                pl.BlockSpec((B, 1), lambda i, *_: (0, 0)),  # idx_c as vector
                pl.BlockSpec((S, 1), lambda i, *_: (0, 0)),  # idx_n as vector
                pl.BlockSpec((bb, 1), lambda i, *_: (i, 0)),  # mask tile
                pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),  # lr
            ],
            out_specs=(
                pl.BlockSpec(memory_space=pltpu.ANY),        # vert' (aliased)
                pl.BlockSpec(memory_space=pltpu.ANY),        # ctx'  (aliased)
                pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),  # loss (accum)
            ),
            scratch_shapes=table_scratch + sems,
        )
        vert2, ctx2, loss = pl.pallas_call(
            _sgns_update_kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            # operands 0..2 are the scalar-prefetch index vectors.
            input_output_aliases={3: 0, 4: 1},
            interpret=interpret,
        )(iv32, ic32, in32, vert, ctx,
          iv32.reshape(B, 1), ic32.reshape(B, 1), in32.reshape(S, 1),
          mask.reshape(B, 1), jnp.asarray(lr, f32).reshape(1, 1))
        return vert2, ctx2, loss[0, 0]

    # segsum: sort each scatter index space once on the XLA side; the kernel
    # combines duplicates over the sorted runs in O(B·d)
    L = B + S
    perm_v = jnp.argsort(iv32).astype(jnp.int32)          # stable
    ivs = jnp.take(iv32, perm_v)
    icn = jnp.concatenate([ic32, in32])
    perm_c = jnp.argsort(icn).astype(jnp.int32)
    icns = jnp.take(icn, perm_c)
    upos_v, nuniq_v = _unique_write_plan(ivs)
    upos_c, nuniq_c = _unique_write_plan(icns)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=13,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),            # vert (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),            # ctx (HBM)
            pl.BlockSpec((bb, 1), lambda i, *_: (i, 0)),     # mask tile
            pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),      # lr
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pltpu.ANY),            # vert' (aliased)
            pl.BlockSpec(memory_space=pltpu.ANY),            # ctx'  (aliased)
            pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),      # loss (accum)
        ),
        scratch_shapes=table_scratch + [
            pltpu.VMEM((B, d), vert.dtype),                  # fv_s (finals)
            pltpu.VMEM((L, d), ctx.dtype),                   # fc_s (finals)
            pltpu.VMEM((L, d), f32),                         # ps_s (prefixes)
        ] + sems,
    )
    vert2, ctx2, loss = pl.pallas_call(
        _sgns_update_kernel_segsum,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # operands 0..12 are the scalar-prefetch index/permutation/dedup
        # vectors.
        input_output_aliases={13: 0, 14: 1},
        interpret=interpret,
    )(iv32, ic32, in32,
      perm_v, ivs, _run_flags(ivs), perm_c, icns, _run_flags(icns),
      upos_v, nuniq_v, upos_c, nuniq_c,
      vert, ctx, mask.reshape(B, 1), jnp.asarray(lr, f32).reshape(1, 1))
    return vert2, ctx2, loss[0, 0]


# --------------------------------------------------------------------------
# row gather: multi-row blocks, overlapped async copies (all of a block's
# row DMAs are in flight before the first wait)
# --------------------------------------------------------------------------
def _gather_block_kernel(idx_ref, table_ref, out_ref, sem, *, valid: int):
    i = pl.program_id(0)
    rb = out_ref.shape[0]
    # padded tail rows (global index >= valid) are discarded by the caller's
    # out[:B] slice — skip their DMAs entirely

    def start(j, _):
        @pl.when(i * rb + j < valid)
        def _():
            pltpu.make_async_copy(table_ref.at[idx_ref[i * rb + j]],
                                  out_ref.at[j], sem.at[j]).start()
        return 0
    jax.lax.fori_loop(0, rb, start, 0)

    def wait(j, _):
        @pl.when(i * rb + j < valid)
        def _():
            pltpu.make_async_copy(table_ref.at[idx_ref[i * rb + j]],
                                  out_ref.at[j], sem.at[j]).wait()
        return 0
    jax.lax.fori_loop(0, rb, wait, 0)


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def gather_rows(table, idx, *, rows_per_block: int = 8,
                interpret: bool = False):
    """(N, d) table, (B,) int32 → (B, d). One grid step per `rows_per_block`
    rows; each block's HBM→VMEM row copies are all started before any wait,
    so the DMAs overlap each other (and the previous block's writeout)."""
    B = idx.shape[0]
    N, d = table.shape
    rb = min(rows_per_block, B)
    Bp = -(-B // rb) * rb
    idx_p = jnp.pad(idx.astype(jnp.int32), (0, Bp - B))  # pad rows: no DMA
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Bp // rb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],    # table (HBM)
        out_specs=pl.BlockSpec((rb, d), lambda i, idx: (i, 0)),
        scratch_shapes=[pltpu.SemaphoreType.DMA((rb,))],
    )
    out = pl.pallas_call(
        functools.partial(_gather_block_kernel, valid=B),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Bp, d), table.dtype),
        interpret=interpret,
    )(idx_p, table)
    return out[:B]


def _gather_rowwise_kernel(idx_ref, table_ref, out_ref):
    del idx_ref  # consumed by the index_map
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_rowwise(table, idx, *, interpret: bool = False):
    """Original one-row-per-grid-step gather, kept as the interpret-mode
    reference for :func:`gather_rows`."""
    B = idx.shape[0]
    N, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_rowwise_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


# --------------------------------------------------------------------------
# row scatter-add: multi-row blocks with PER-BLOCK duplicate flags. A block
# whose own indices are duplicate-free runs the overlapped path (reads all
# overlap, the adds vectorize, the writes all overlap); only blocks with an
# internal collision fall back to serialized per-row read-modify-write (the
# only order that accumulates correctly). Duplicates *across* blocks are
# safe on the overlapped path: the grid is sequential and every block's
# writes are waited before its step ends, so a later block's read of the
# same row sees the earlier block's write.
# --------------------------------------------------------------------------
def _scatter_add_block_kernel(idx_ref, dup_ref, table_ref, upd_ref, out_ref,
                              row_s, sem, *, valid: int):
    del table_ref  # aliased: current rows are read through out_ref
    i = pl.program_id(0)
    rb = upd_ref.shape[0]
    # padded tail rows (global index >= valid) do no DMA at all, so padding
    # neither races real row updates nor forces the serialized path

    @pl.when(dup_ref[i] == 0)
    def _overlapped():
        def rstart(j, _):
            @pl.when(i * rb + j < valid)
            def _():
                pltpu.make_async_copy(out_ref.at[idx_ref[i * rb + j]],
                                      row_s.at[j], sem.at[j]).start()
            return 0
        jax.lax.fori_loop(0, rb, rstart, 0)
        def rwait(j, _):
            @pl.when(i * rb + j < valid)
            def _():
                pltpu.make_async_copy(out_ref.at[idx_ref[i * rb + j]],
                                      row_s.at[j], sem.at[j]).wait()
            return 0
        jax.lax.fori_loop(0, rb, rwait, 0)
        row_s[...] = row_s[...] + upd_ref[...].astype(row_s.dtype)
        def wstart(j, _):
            @pl.when(i * rb + j < valid)
            def _():
                pltpu.make_async_copy(row_s.at[j],
                                      out_ref.at[idx_ref[i * rb + j]],
                                      sem.at[j]).start()
            return 0
        jax.lax.fori_loop(0, rb, wstart, 0)
        def wwait(j, _):
            @pl.when(i * rb + j < valid)
            def _():
                pltpu.make_async_copy(row_s.at[j],
                                      out_ref.at[idx_ref[i * rb + j]],
                                      sem.at[j]).wait()
            return 0
        jax.lax.fori_loop(0, rb, wwait, 0)

    @pl.when(dup_ref[i] != 0)
    def _serialized():
        def body(j, _):
            @pl.when(i * rb + j < valid)
            def _():
                r = idx_ref[i * rb + j]
                cp = pltpu.make_async_copy(out_ref.at[r], row_s.at[0],
                                           sem.at[0])
                cp.start()
                cp.wait()
                row_s[0, :] = row_s[0, :] + upd_ref[j, :].astype(row_s.dtype)
                cp = pltpu.make_async_copy(row_s.at[0], out_ref.at[r],
                                           sem.at[0])
                cp.start()
                cp.wait()
            return 0
        jax.lax.fori_loop(0, rb, body, 0)


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def scatter_add_rows(table, idx, upd, *, rows_per_block: int = 8,
                     interpret: bool = False):
    """table[idx[i]] += upd[i] (duplicates accumulate), `rows_per_block` rows
    per grid step. A host-side per-block duplicate check (sorted-adjacent
    compare within each block) selects the overlapped fast path or the
    serialized RMW path block by block, so one colliding block no longer
    serializes the whole scatter."""
    B = idx.shape[0]
    N, d = table.shape
    rb = min(rows_per_block, B)
    Bp = -(-B // rb) * rb
    idx32 = idx.astype(jnp.int32)
    idx_p = jnp.pad(idx32, (0, Bp - B))   # pad rows are skipped in-kernel
    upd_p = _pad_rows(upd, Bp)
    # per-block duplicate flags over the REAL indices (padded tail positions
    # get unique negative sentinels so they can't fake a collision with a
    # real index; the kernel skips them regardless)
    sentinels = -1 - jnp.arange(Bp - B, dtype=jnp.int32)
    srt = jnp.sort(jnp.concatenate([idx32, sentinels]).reshape(Bp // rb, rb),
                   axis=1)
    dup = jnp.any(srt[:, 1:] == srt[:, :-1], axis=1).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Bp // rb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),            # table: alias
            pl.BlockSpec((rb, d), lambda i, *_: (i, 0)),     # upd block
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((rb, d), table.dtype),
            pltpu.SemaphoreType.DMA((rb,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_scatter_add_block_kernel, valid=B),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, d), table.dtype),
        # operands 0/1 are the scalar-prefetch idx/dup; operand 2 is `table`.
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx_p, dup, table, upd_p)


def _pad_rows(x, n_rows):
    pad = n_rows - x.shape[0]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


def _scatter_add_rowwise_kernel(idx_ref, table_ref, upd_ref, out_ref):
    del idx_ref, table_ref  # table is aliased to out; its rows arrive in out_ref
    out_ref[...] += upd_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_add_rows_rowwise(table, idx, upd, *, interpret: bool = False):
    """Original one-row-per-grid-step scatter-add (aliased input→output;
    sequential grid ⇒ duplicates accumulate), kept as the interpret-mode
    reference for :func:`scatter_add_rows`."""
    B = idx.shape[0]
    N, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),           # table: alias only
            pl.BlockSpec((1, d), lambda i, idx: (i, 0)),    # upd row
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0)),
    )
    return pl.pallas_call(
        _scatter_add_rowwise_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, d), table.dtype),
        # operand 0 is the scalar-prefetch idx; operand 1 is `table`.
        input_output_aliases={1: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), table, upd)
