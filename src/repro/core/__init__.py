"""The paper's primary contribution: hybrid model-data parallel node-embedding
training with hierarchical partitioning, two-level ring rotation, and a
pipelined episode trainer. See DESIGN.md §1/§5. ``tiered`` extends the
trainer past device memory: host-RAM master tables + a fixed-budget HBM
cache of hot rows, bitwise identical to the resident path."""
from repro.core.hybrid import (HybridConfig, HybridEmbeddingTrainer,
                               StagedEpisodeBlocks, build_episode_fn)
from repro.core.partition import NodePartition, EpisodeBlocks, build_episode_blocks
from repro.core.baseline_ps import ParameterServerTrainer
from repro.core.pipeline import EpisodePipeline
from repro.core.tiered import (CACHE_POLICIES, CacheStats,
                               StagedTieredEpisode, TieredEmbeddingTrainer,
                               TieredTable)

__all__ = [
    "HybridConfig", "HybridEmbeddingTrainer", "StagedEpisodeBlocks",
    "build_episode_fn", "NodePartition", "EpisodeBlocks",
    "build_episode_blocks", "ParameterServerTrainer", "EpisodePipeline",
    "CACHE_POLICIES", "CacheStats", "StagedTieredEpisode",
    "TieredEmbeddingTrainer", "TieredTable",
]
