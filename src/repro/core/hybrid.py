"""Hybrid model–data parallel embedding training (paper §III — the core).

Data parallelism: each episode's edge samples are 2D-partitioned into blocks
(`core.partition`) and each device trains only blocks whose endpoints are
resident. Model parallelism: the context table is pinned (row-sharded over
every mesh axis); the vertex table is row-sharded the same way but **rotates**
through nested rings (`core.rotation`) so each vertex shard meets each
context shard exactly once per episode.

The episode step is a single `shard_map`-ed, jit-ted function:

    scan over pod ring (Q)              ppermute "pod"   (DCN, slow)
      scan over data ring (D)           ppermute "data"  (ICI)
        scan over model ring (M)        per-sub-part ppermute "model" (fast)
          unrolled k sub-parts          <- paper's ping-pong pipelining:
            scan over minibatches          sub-part j's ppermute overlaps
              kernels.ops.sgns_step        sub-part j+1's training

XLA's async collective scheduling provides the compute/communication overlap
that the paper implements manually with CUDA streams and ping-pong buffers.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.partition import EpisodeBlocks, NodePartition
from repro.kernels import ops
from repro.sharding import compat


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    dim: int = 128
    lr: float = 0.025
    negatives: int = 16           # shared negatives per minibatch
    minibatch: int = 64           # shared-negative group size (Ji et al. [19])
    reduction: str = "sum"        # word2vec-faithful; see kernels.ops.sgns_step
    subparts: int = 4             # paper's k (ping-pong sub-parts)
    neg_pool: int = 8192          # deg^0.75-sampled per-device negative pool
    # kernels.ops impl: "ref" | "pallas" | "pallas_fused" | "pallas_fused2".
    # "pallas_fused2" is the pipelined fully-fused update kernel (double-
    # buffered DMA gather + in-kernel SGD apply) — the production path on TPU.
    impl: str = "ref"
    # kernel tile rows. None (default) = VMEM-aware autotune at trace time
    # (kernels.ops.plan_fused_update picks tile size, duplicate-combine
    # strategy and per-launch chunking from B/d/S/dtype); set an int only to
    # pin the tile size for experiments.
    block_b: int | None = None
    seed: int = 0
    # bf16 tables halve BOTH the ring-rotation bytes and the HBM footprint;
    # grads are computed in f32 inside the kernel (beyond-paper, §Perf A.3).
    # Default since the AUC-parity gate in tests/test_eval_auc.py showed
    # bf16 within 0.5% AUC of f32 on the small-graph run; pass
    # dtype="float32" (CLI: --dtype float32) for the paper-faithful tables.
    dtype: str = "bfloat16"
    # ablation switches (used by §Perf):
    fuse_subpart_permute: bool = True   # False -> one whole-shard ppermute/round


@dataclasses.dataclass(frozen=True)
class StagedEpisodeBlocks:
    """An episode's block layout already device_put with the episode-step
    shardings — the output of the pipeline's staging stage, accepted by
    ``train_episode`` directly so the H2D copies happen on a pipeline worker
    instead of the training loop's critical path."""

    blocks: object                 # jax.Array, sharded like eb.blocks
    counts: object                 # jax.Array, sharded like eb.counts
    num_samples: int               # host-side valid-sample count (logging)
    dropped: int = 0


def _shift_perm(n: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % n) for i in range(n)]


def build_episode_fn(mesh: Mesh, part: NodePartition, cfg: HybridConfig):
    """Returns (jitted episode fn, in_shardings dict). Shapes are static per
    (part, block_cap) so the caller re-lowers only when the layout changes."""
    axis_names = tuple(mesh.axis_names)
    dims = tuple(mesh.devices.shape)
    assert dims == tuple(part.dims), (dims, part.dims)
    k = part.subparts
    rows_sub = part.rows_per_subpart
    rows = part.padded_rows_per_shard
    mb = cfg.minibatch
    S = cfg.negatives

    def train_block(vert_j, ctx, blk, cnt, pool, key, lr):
        """All minibatches of one (sub-part, round) block. blk: (Bmax, 2)."""
        bmax = blk.shape[0]
        nmb = bmax // mb
        blk3 = blk.reshape(nmb, mb, 2)
        offsets = jnp.arange(nmb, dtype=jnp.int32) * mb

        def body(carry, xs):
            vj, ctx, key, lacc = carry
            blk_mb, off = xs
            key, kneg = jax.random.split(key)
            pidx = jax.random.randint(kneg, (S,), 0, pool.shape[0])
            idx_n = pool[pidx]
            mask = ((off + jnp.arange(mb, dtype=jnp.int32)) < cnt).astype(vj.dtype)
            vj, ctx, loss = ops.sgns_step(
                vj, ctx, blk_mb[:, 0], blk_mb[:, 1], idx_n, mask, lr,
                impl=cfg.impl, reduction=cfg.reduction, block_b=cfg.block_b)
            return (vj, ctx, key, lacc + loss), None

        (vert_j, ctx, key, loss), _ = jax.lax.scan(
            body, (vert_j, ctx, key, jnp.float32(0.0)), (blk3, offsets))
        return vert_j, ctx, loss, key

    model_axis = axis_names[-1]
    model_perm = _shift_perm(dims[-1])

    def model_round(carry, xs):
        vert, ctx, key, lacc = carry          # vert: k-tuple of (rows_sub, d)
        blk_r, cnt_r = xs                     # (k, Bmax, 2), (k,)
        # NOTE: vert is a TUPLE of sub-part arrays, not a stacked (k, ...)
        # array: slicing/stacking a stacked carry copies the whole shard
        # twice per ring round (§Perf hillclimb A, iteration 2).
        slots = []
        for j in range(k):
            vj, ctx, lj, key = train_block(
                vert[j], ctx, blk_r[j], cnt_r[j], _pool[0], key, _lr[0])
            if cfg.fuse_subpart_permute:
                # paper-faithful: ppermute sub-part j immediately; its
                # transfer overlaps sub-part j+1's compute.
                vj = jax.lax.ppermute(vj, model_axis, model_perm)
            slots.append(vj)
            lacc = lacc + lj
        if not cfg.fuse_subpart_permute:
            # naive variant (§Perf ablation): train everything, then one
            # bulk transfer — no overlap opportunity.
            slots = [jax.lax.ppermute(vj, model_axis, model_perm)
                     for vj in slots]
        return (tuple(slots), ctx, key, lacc), None

    # nested ring scans, innermost (model) to outermost (pod)
    def make_level(level_fn, axis: str, n: int):
        perm = _shift_perm(n)

        def level(carry, xs):
            carry, _ = jax.lax.scan(level_fn, carry, xs)
            vert, ctx, key, lacc = carry
            vert = jax.lax.ppermute(vert, axis, perm)
            return (vert, ctx, key, lacc), None

        return level

    # closure cells for pool/lr (set per-call below, avoids threading them
    # through every scan carry)
    _pool = [None]
    _lr = [None]

    def episode_device_fn(vert, ctx, blocks, counts, pool, seed, lr):
        # local views; vert becomes a k-tuple of sub-part arrays (see
        # model_round) — the split/concat happen once per episode, not per
        # ring round.
        vert = tuple(vert.reshape(k, rows_sub, -1))
        blocks = blocks[0]                    # (Q, D, M, k, Bmax, 2)
        counts = counts[0]
        _pool[0] = pool[0]
        _lr[0] = lr

        key = jax.random.fold_in(
            jax.random.PRNGKey(seed[0]),
            compat.axis_flat_index(axis_names, dims))

        fn = model_round
        # wrap middle/outer rings (skip the innermost axis: handled per round)
        for axis, n in list(zip(axis_names, dims))[:-1][::-1]:
            fn = make_level(fn, axis, n)
        carry = (vert, ctx, key, jnp.float32(0.0))
        carry, _ = jax.lax.scan(fn, carry, (blocks, counts))
        vert, ctx, key, lacc = carry

        total = jnp.maximum(jnp.sum(counts).astype(jnp.float32), 1.0)
        loss = jax.lax.psum(lacc, axis_names) / jax.lax.psum(total, axis_names)
        return jnp.concatenate(vert, axis=0), ctx, loss

    all_axes = P(axis_names)
    in_specs = (
        all_axes,                  # vert (N_pad, d) row-sharded over all axes
        all_axes,                  # ctx
        P(axis_names),             # blocks (P, ...): dim0 over all axes
        P(axis_names),             # counts
        P(axis_names),             # pool (P, pool_n)
        P(),                       # seed (1,) replicated
        P(),                       # lr scalar
    )
    out_specs = (all_axes, all_axes, P())

    fn = compat.shard_map(episode_device_fn, mesh, in_specs, out_specs)
    shardings = {
        "table": NamedSharding(mesh, all_axes),
        "blocks": NamedSharding(mesh, P(axis_names)),
        "replicated": NamedSharding(mesh, P()),
    }
    jitted = jax.jit(
        fn, donate_argnums=(0, 1),
        in_shardings=(shardings["table"], shardings["table"],
                      shardings["blocks"], shardings["blocks"],
                      shardings["blocks"], shardings["replicated"],
                      shardings["replicated"]))
    return jitted, shardings


class HybridEmbeddingTrainer:
    """Driver tying partition + rotation + episode step together."""

    def __init__(self, num_nodes: int, mesh: Mesh, cfg: HybridConfig,
                 degrees: np.ndarray | None = None):
        self.mesh = mesh
        self.cfg = cfg
        self.part = NodePartition(
            num_nodes, dims=tuple(mesh.devices.shape), subparts=cfg.subparts)
        self.num_nodes = num_nodes
        self._built = None
        self.vert = None
        self.ctx = None
        self.pool = self._build_neg_pool(degrees)

    # ---------------------------------------------------------------- setup
    def _build_neg_pool(self, degrees: np.ndarray | None) -> np.ndarray:
        """Per-device pool of local context rows, sampled ∝ deg^0.75."""
        part, cfg = self.part, self.cfg
        P_shards, rows = part.num_shards, part.padded_rows_per_shard
        rng = np.random.default_rng(cfg.seed + 17)
        pool = np.zeros((P_shards, cfg.neg_pool), dtype=np.int32)
        for s in range(P_shards):
            lo = s * rows
            hi = min((s + 1) * rows, self.num_nodes)
            if hi <= lo:
                continue
            local_n = hi - lo
            if degrees is None:
                pool[s] = rng.integers(0, local_n, cfg.neg_pool)
            else:
                w = degrees[lo:hi].astype(np.float64) ** 0.75
                w = np.maximum(w, 1e-12)
                w /= w.sum()
                pool[s] = rng.choice(local_n, size=cfg.neg_pool, p=w)
        return pool

    def init_embeddings(self):
        """word2vec-style init: vertex ~ U(-0.5/d, 0.5/d), context = 0."""
        part, cfg = self.part, self.cfg
        d = cfg.dim
        rng = np.random.default_rng(cfg.seed)
        dt = np.dtype(cfg.dtype)
        vert = ((rng.random((part.padded_num_nodes, d), dtype=np.float32)
                 - 0.5) / d).astype(dt)
        ctx = np.zeros((part.padded_num_nodes, d), dtype=dt)
        _, sh = self._episode_fn()
        self.vert = jax.device_put(vert, sh["table"])
        self.ctx = jax.device_put(ctx, sh["table"])

    def _episode_fn(self):
        if self._built is None:
            self._built = build_episode_fn(self.mesh, self.part, self.cfg)
        return self._built

    # ---------------------------------------------------------------- train
    def stage_blocks(self, eb: EpisodeBlocks) -> StagedEpisodeBlocks:
        """device_put an episode's blocks with the episode-step shardings.
        Safe to call from a pipeline worker thread — the H2D copies then
        overlap the previous episode's device compute."""
        _, sh = self._episode_fn()
        return StagedEpisodeBlocks(
            blocks=jax.device_put(eb.blocks, sh["blocks"]),
            counts=jax.device_put(eb.counts, sh["blocks"]),
            num_samples=int(eb.counts.sum()),
            dropped=eb.dropped)

    def train_episode(self, eb: EpisodeBlocks | StagedEpisodeBlocks,
                      *, lr: float | None = None) -> float:
        fn, sh = self._episode_fn()
        if not isinstance(eb, StagedEpisodeBlocks):
            eb = self.stage_blocks(eb)
        pool = jax.device_put(self.pool, sh["blocks"])
        seed = jax.device_put(
            np.array([self.cfg.seed], np.int32), sh["replicated"])
        lr_arr = jax.device_put(
            np.float32(self.cfg.lr if lr is None else lr), sh["replicated"])
        self.vert, self.ctx, loss = fn(
            self.vert, self.ctx, eb.blocks, eb.counts, pool, seed, lr_arr)
        return float(loss)

    def set_embeddings(self, vert: np.ndarray, ctx: np.ndarray) -> None:
        """Install externally-provided (num_nodes, d) tables — the resume
        path. Pads to the partition geometry (padded rows never enter
        training math, so zero-padding restored tables is exact) and
        device_puts with the episode-step shardings."""
        part = self.part
        dt = np.dtype(self.cfg.dtype)
        _, sh = self._episode_fn()
        self.vert = jax.device_put(
            part.pad_table(np.asarray(vert).astype(dt, copy=False)),
            sh["table"])
        self.ctx = jax.device_put(
            part.pad_table(np.asarray(ctx).astype(dt, copy=False)),
            sh["table"])

    def embeddings(self) -> np.ndarray:
        return self.part.unpad_table(np.asarray(self.vert))

    def context_embeddings(self) -> np.ndarray:
        return self.part.unpad_table(np.asarray(self.ctx))
