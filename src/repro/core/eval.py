"""Evaluation utilities: link-prediction AUC (paper §V-B, Tables IV/V).

Following the paper (which follows GraphVite): score a node pair by the dot
product of the **vertex** embedding of the source and the **context**
embedding of the destination; AUC over held-out positive edges vs. uniformly
sampled non-edge node pairs.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def split_edges(graph: CSRGraph, test_frac: float, *, seed: int = 0):
    """Split the (directed) edge list into train/test; returns (train_edges,
    test_edges). Symmetrized duplicates are kept together by splitting on
    canonical (min, max) keys."""
    edges = graph.edge_list()
    canon = np.minimum(edges[:, 0], edges[:, 1]).astype(np.int64) * graph.num_nodes \
        + np.maximum(edges[:, 0], edges[:, 1])
    uniq = np.unique(canon)
    rng = np.random.default_rng(seed)
    test_keys = rng.choice(uniq, size=max(1, int(len(uniq) * test_frac)),
                           replace=False)
    is_test = np.isin(canon, test_keys)
    return edges[~is_test], edges[is_test]


def sample_negative_pairs(graph: CSRGraph, num: int, *, seed: int = 0) -> np.ndarray:
    """Random node pairs that are not edges (rejection sampling)."""
    rng = np.random.default_rng(seed)
    out = []
    need = num
    edge_keys = (graph.edge_list()[:, 0].astype(np.int64) * graph.num_nodes
                 + graph.edge_list()[:, 1])
    edge_keys = np.sort(edge_keys)
    while need > 0:
        cand = rng.integers(0, graph.num_nodes, size=(2 * need, 2))
        cand = cand[cand[:, 0] != cand[:, 1]]
        keys = cand[:, 0].astype(np.int64) * graph.num_nodes + cand[:, 1]
        pos = np.searchsorted(edge_keys, keys)
        pos = np.minimum(pos, edge_keys.size - 1)
        ok = edge_keys[pos] != keys
        cand = cand[ok][:need]
        out.append(cand)
        need -= len(cand)
    return np.concatenate(out, axis=0)


def auc_score(pos_scores: np.ndarray, neg_scores: np.ndarray) -> float:
    """Rank-based AUC (exact, ties get 0.5 credit)."""
    scores = np.concatenate([pos_scores, neg_scores])
    labels = np.concatenate([np.ones(len(pos_scores)), np.zeros(len(neg_scores))])
    order = np.argsort(scores, kind="stable")
    ranks = np.empty_like(order, dtype=np.float64)
    # average ranks for ties
    sorted_scores = scores[order]
    ranks[order] = np.arange(1, len(scores) + 1)
    # tie correction
    i = 0
    sr = sorted_scores
    while i < len(sr):
        j = i
        while j + 1 < len(sr) and sr[j + 1] == sr[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = (i + 1 + j + 1) / 2.0
        i = j + 1
    n_pos, n_neg = len(pos_scores), len(neg_scores)
    return float((ranks[labels == 1].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


def link_prediction_auc(vert: np.ndarray, ctx: np.ndarray,
                        pos_edges: np.ndarray, neg_edges: np.ndarray) -> float:
    def score(pairs):
        return np.einsum("ij,ij->i", vert[pairs[:, 0]], ctx[pairs[:, 1]])
    return auc_score(score(pos_edges), score(neg_edges))
