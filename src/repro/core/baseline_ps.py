"""GraphVite-style parameter-server baseline (paper §VI-C, Tables III/VI).

The paper's speedups are measured against GraphVite [4]: a single-node system
where the CPU acts as a parameter server — embeddings live in host memory,
each round the vertex (and sample) blocks are copied host→device, trained,
and copied back, with **no pipeline overlap** and **all inter-GPU exchange
bouncing through the host**. We implement the same execution structure so the
benchmark comparison is structural, not a strawman:

  * identical SGNS math (same `kernels.ops.sgns_step`, including the
    `pallas_fused2` fully-fused update path when `cfg.impl` selects it),
  * identical 2D orthogonal-block schedule,
  * but: synchronous host round-trips for every vertex block each round,
    no ppermute, no overlap, per-round dispatch from Python.

On this CPU-only container the measured gap is dispatch + copy overhead; the
benchmark additionally reports *structural* counters (host syncs, bytes
through host) that scale the gap on real hardware.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridConfig
from repro.core.partition import NodePartition, EpisodeBlocks
from repro.kernels import ops
from repro.obs import register_source


@dataclasses.dataclass
class PSCounters:
    host_syncs: int = 0
    bytes_through_host: int = 0


class ParameterServerTrainer:
    """Single-node multi-device trainer with CPU parameter server."""

    def __init__(self, num_nodes: int, num_devices: int, cfg: HybridConfig,
                 degrees: np.ndarray | None = None):
        self.cfg = cfg
        self.num_nodes = num_nodes
        self.devices = jax.devices()[:num_devices]
        self.n = num_devices
        # same partition geometry as the hybrid trainer on a (1, n) mesh
        self.part = NodePartition(num_nodes, dims=(1, num_devices),
                                  subparts=cfg.subparts)
        self.counters = PSCounters()
        # surface the structural counters through the telemetry registry
        # (no-op unless obs is enabled): one snapshot covers this baseline
        # alongside the pipeline/transport/serve surfaces
        register_source("baseline_ps",
                        lambda: dataclasses.asdict(self.counters))
        rng = np.random.default_rng(cfg.seed)
        d = cfg.dim
        dt = np.dtype(cfg.dtype)     # same table dtype as the hybrid trainer
        self.vert = ((rng.random((self.part.padded_num_nodes, d),
                                 dtype=np.float32) - 0.5) / d).astype(dt)
        self.ctx = np.zeros((self.part.padded_num_nodes, d), dt)
        self._pool = self._build_pool(degrees)
        self._block_fn = self._make_block_fn()

    def _build_pool(self, degrees):
        part, cfg = self.part, self.cfg
        rng = np.random.default_rng(cfg.seed + 17)
        rows = part.padded_rows_per_shard
        pool = np.zeros((part.num_shards, cfg.neg_pool), np.int32)
        for s in range(part.num_shards):
            lo, hi = s * rows, min((s + 1) * rows, self.num_nodes)
            if hi <= lo:
                continue
            if degrees is None:
                pool[s] = rng.integers(0, hi - lo, cfg.neg_pool)
            else:
                w = np.maximum(degrees[lo:hi].astype(np.float64) ** 0.75, 1e-12)
                pool[s] = rng.choice(hi - lo, size=cfg.neg_pool, p=w / w.sum())
        return pool

    def _make_block_fn(self):
        cfg = self.cfg
        mb, S = cfg.minibatch, cfg.negatives

        def block_fn(vert_shard, ctx_shard, blk, cnt, pool, key, lr):
            bmax = blk.shape[0]
            nmb = bmax // mb
            blk3 = blk.reshape(nmb, mb, 2)
            offs = jnp.arange(nmb, dtype=jnp.int32) * mb

            def body(carry, xs):
                v, c, key, lacc = carry
                blk_mb, off = xs
                key, kneg = jax.random.split(key)
                idx_n = pool[jax.random.randint(kneg, (S,), 0, pool.shape[0])]
                mask = ((off + jnp.arange(mb, dtype=jnp.int32)) < cnt).astype(v.dtype)
                v, c, loss = ops.sgns_step(v, c, blk_mb[:, 0], blk_mb[:, 1],
                                           idx_n, mask, lr, impl=cfg.impl,
                                           reduction=cfg.reduction,
                                           block_b=cfg.block_b)
                return (v, c, key, lacc + loss), None

            (vert_shard, ctx_shard, key, loss), _ = jax.lax.scan(
                body, (vert_shard, ctx_shard, key, jnp.float32(0.0)),
                (blk3, offs))
            return vert_shard, ctx_shard, loss

        return jax.jit(block_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------ train
    def train_episode(self, eb: EpisodeBlocks, *, lr: float | None = None) -> float:
        """Orthogonal-block rounds; every vertex block round-trips the host."""
        cfg = self.cfg
        part = self.part
        n, k = self.n, part.subparts
        rows = part.padded_rows_per_shard
        rows_sub = part.rows_per_subpart
        lr_f = np.float32(cfg.lr if lr is None else lr)
        # blocks layout: (P, 1, n, k, Bmax, 2) on the (1, n) ring
        blocks = eb.blocks
        counts = eb.counts
        loss_sum, samples = 0.0, max(int(counts.sum()), 1)
        # context shards pinned per device (loaded once per episode — GraphVite
        # keeps them on device) — but vertex shards bounce via the host.
        ctx_dev = [jax.device_put(self.ctx[i * rows:(i + 1) * rows],
                                  self.devices[i]) for i in range(n)]
        pool_dev = [jax.device_put(self._pool[i], self.devices[i])
                    for i in range(n)]
        step = 0
        for r in range(n):  # ring rounds
            for i in range(n):  # devices (serial on CPU; parallel on GPU)
                vs = (i - r) % n  # vertex shard at device i this round
                for j in range(k):
                    blk = blocks[i, 0, r, j]
                    cnt = counts[i, 0, r, j]
                    if cnt == 0:
                        continue
                    lo = vs * rows + j * rows_sub
                    # host -> device (the PS fetch)
                    v_dev = jax.device_put(self.vert[lo:lo + rows_sub],
                                           self.devices[i])
                    blk_dev = jax.device_put(np.asarray(blk), self.devices[i])
                    key = jax.random.PRNGKey(cfg.seed + 131 * step)
                    step += 1
                    v_dev, ctx_dev[i], loss = self._block_fn(
                        v_dev, ctx_dev[i], blk_dev, jnp.int32(cnt),
                        pool_dev[i], key, lr_f)
                    # device -> host (the PS writeback), synchronous
                    self.vert[lo:lo + rows_sub] = np.asarray(v_dev)
                    loss_sum += float(loss)
                    self.counters.host_syncs += 2
                    self.counters.bytes_through_host += (
                        2 * v_dev.size * v_dev.dtype.itemsize)
        for i in range(n):
            self.ctx[i * rows:(i + 1) * rows] = np.asarray(ctx_dev[i])
            self.counters.host_syncs += 1
            self.counters.bytes_through_host += (
                ctx_dev[i].size * ctx_dev[i].dtype.itemsize)
        return loss_sum / samples

    def embeddings(self) -> np.ndarray:
        return self.part.unpad_table(self.vert)

    def context_embeddings(self) -> np.ndarray:
        return self.part.unpad_table(self.ctx)
