"""Episode pipeline: overlap host-side walk-wait, block building and H2D
staging with device compute (paper §III-C, Fig. 3 stages 5/7).

On TPU+JAX the intra-episode overlap (stages 2/4/6) is XLA's async collective
scheduling inside the jitted episode step; what remains for the host is
preparing upcoming episodes (walk consumption, 2D bucketing, device_put)
while the current one trains. ``EpisodePipeline`` runs that as a bounded
multi-stage pipeline:

    walk-wait (store.get)  ->  block-build (2D bucketing)  ->  device staging

Each stage has its own worker pool, so episode e+1's walk-wait overlaps
episode e's build which overlaps episode e-1's staging; ``depth`` bounds how
many episodes are in flight at once. Prefetches are keyed by
(epoch, episode): a ``get`` for anything not in flight falls back to a
synchronous build instead of handing back the wrong episode's blocks.
jax dispatch is async, so ``train_episode`` returns as soon as the step is
enqueued and the staging workers' ``device_put``s interleave with device
compute.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.partition import NodePartition, build_episode_blocks
from repro.obs import observe, span
from repro.runtime import CorruptEpisodeError

# Registry histogram / trace track per pipeline stage: every _record lands
# in the process histogram too, so per-stage durations survive even when a
# caller never pops (or the bounded per-episode table evicts the entry).
_STAGE_METRIC = {"walk_wait_s": "pipeline.walk_wait_s",
                 "build_s": "pipeline.build_s",
                 "stage_s": "pipeline.stage_s"}


class EpisodePipeline:
    """Bounded multi-stage prefetcher for episode blocks.

    Parameters
    ----------
    store : SampleStore — walk-engine output, keyed (epoch, episode).
    part, pad_multiple, block_cap, build_chunk — block-build geometry
        (forwarded to :func:`build_episode_blocks`; pass ``block_cap`` to pin
        the block shape so streaming consumers compile once).
    depth : max episodes in flight (prefetched but not yet consumed).
    stage_fn : optional third-stage callable ``EpisodeBlocks -> staged``
        (e.g. ``HybridEmbeddingTrainer.stage_blocks`` for device_put, or
        ``TieredEmbeddingTrainer.stage_blocks``, which additionally
        precomputes each block's unique-row miss sets, compact remaps and
        negative replay one stage ahead of training — the walk store sees
        every id before the trainer does); when None the pipeline is
        two-stage and ``get`` returns EpisodeBlocks.
        Contract: stage_fn may run on a stage worker OR inline on the
        consumer thread (prefetch miss, ``_build_sync``), so it must not
        touch consumer-thread-only state — the tiered trainer defers all
        cache promotion and cold-row *value* reads to ``train_episode``
        for exactly this reason (a stage-time value read could race an
        in-flight episode's write-back and break bitwise replay).
    drop_consumed : call ``store.drop(epoch, episode)`` once the build stage
        has bucketed the pairs — with a bounded store this is what frees the
        walker's backpressure slots.
    workers_per_stage : worker threads per stage pool.
    rewalk : optional ``(epoch, episode) -> pairs`` regenerator (e.g.
        ``WalkEngine.episode_pairs``). When the store raises
        ``CorruptEpisodeError`` — a torn or bit-flipped episode file — the
        fetch stage re-walks the episode (bitwise-identical by RNG keying)
        instead of failing the run, and repairs the file via the store's
        ``rewrite`` if it has one.
    """

    def __init__(self, store, part: NodePartition, *, pad_multiple: int,
                 block_cap: int | None = None, depth: int = 2,
                 stage_fn=None, drop_consumed: bool = False,
                 build_chunk: int | None = None, workers_per_stage: int = 1,
                 rewalk=None):
        self.store = store
        self.rewalk = rewalk
        self.recovered: list[tuple[int, int]] = []  # corrupt episodes re-walked
        self.part = part
        self.pad_multiple = pad_multiple
        self.block_cap = block_cap
        self.build_chunk = build_chunk
        self.depth = max(1, depth)
        self.stage_fn = stage_fn
        self.drop_consumed = drop_consumed
        w = max(1, workers_per_stage)
        self._fetch_pool = ThreadPoolExecutor(w, thread_name_prefix="ep-fetch")
        self._build_pool = ThreadPoolExecutor(w, thread_name_prefix="ep-build")
        self._stage_pool = (ThreadPoolExecutor(w, thread_name_prefix="ep-stage")
                            if stage_fn is not None else None)
        self._inflight: dict[tuple[int, int], object] = {}
        self._times: dict[tuple[int, int], dict] = {}
        self._times_mu = threading.Lock()   # stage workers write concurrently
        # Bound on retained per-episode timing entries. Entries leave via
        # pop_times; callers that consume out of prefetch order (or never
        # pop) are covered by oldest-first eviction instead of the old
        # liveness sweep in get(), which deleted timings for any episode
        # already consumed — losing them before pop_times could run.
        self._times_cap = max(64, 8 * self.depth)

    def _record(self, key, stage, seconds):
        observe(_STAGE_METRIC[stage], seconds)  # registry copy: never dropped
        with self._times_mu:
            self._times.setdefault(key, {})[stage] = seconds
            while len(self._times) > self._times_cap:
                self._times.pop(next(iter(self._times)))

    # ------------------------------------------------------------- stages
    def _get_pairs(self, epoch: int, episode: int):
        """store.get with corrupt-episode recovery (when ``rewalk`` is set):
        regenerate the pairs deterministically and repair the stored file."""
        try:
            return self.store.get(epoch, episode)
        except CorruptEpisodeError:
            if self.rewalk is None:
                raise
            pairs = self.rewalk(epoch, episode)
            rewrite = getattr(self.store, "rewrite", None)
            if callable(rewrite):
                rewrite(epoch, episode, pairs)
            self.recovered.append((epoch, episode))
            return pairs

    def _fetch(self, key):
        t0 = time.perf_counter()
        with span("walk_wait", "walk", {"epoch": key[0], "episode": key[1]}):
            pairs = self._get_pairs(*key)
        self._record(key, "walk_wait_s", time.perf_counter() - t0)
        return pairs

    def _build_from(self, key, fetch_fut):
        pairs = fetch_fut.result()
        t0 = time.perf_counter()
        with span("build", "build", {"epoch": key[0], "episode": key[1]}):
            eb = build_episode_blocks(
                np.asarray(pairs), self.part, block_cap=self.block_cap,
                pad_multiple=self.pad_multiple, chunk=self.build_chunk)
        self._record(key, "build_s", time.perf_counter() - t0)
        if self.drop_consumed:
            self.store.drop(*key)   # pairs are bucketed; free the slot
        return eb

    def _stage_from(self, key, build_fut):
        eb = build_fut.result()
        t0 = time.perf_counter()
        with span("stage", "stage", {"epoch": key[0], "episode": key[1]}):
            staged = self.stage_fn(eb)
        self._record(key, "stage_s", time.perf_counter() - t0)
        return staged

    def _build_sync(self, epoch: int, episode: int):
        """Prefetch-miss fallback: the same three stages inline, recording
        the same per-stage timings (sync-built episodes used to record
        nothing, leaving pop_times empty and the stage histograms blind to
        exactly the episodes that were built on the critical path)."""
        key = (epoch, episode)
        t0 = time.perf_counter()
        with span("walk_wait", "walk", {"epoch": epoch, "episode": episode,
                                        "sync": True}):
            pairs = self._get_pairs(epoch, episode)
        self._record(key, "walk_wait_s", time.perf_counter() - t0)
        t0 = time.perf_counter()
        with span("build", "build", {"epoch": epoch, "episode": episode,
                                     "sync": True}):
            eb = build_episode_blocks(
                np.asarray(pairs), self.part, block_cap=self.block_cap,
                pad_multiple=self.pad_multiple, chunk=self.build_chunk)
        self._record(key, "build_s", time.perf_counter() - t0)
        if self.drop_consumed:
            self.store.drop(epoch, episode)
        if self.stage_fn is None:
            return eb
        t0 = time.perf_counter()
        with span("stage", "stage", {"epoch": epoch, "episode": episode,
                                     "sync": True}):
            staged = self.stage_fn(eb)
        self._record(key, "stage_s", time.perf_counter() - t0)
        return staged

    # ---------------------------------------------------------------- API
    def prefetch(self, epoch: int, episode: int) -> bool:
        """Enqueue (epoch, episode) through the stage chain. Idempotent; a
        no-op (returns False) when already in flight or ``depth`` is full."""
        key = (epoch, episode)
        if key in self._inflight:
            return False
        if len(self._inflight) >= self.depth:
            return False
        f = self._fetch_pool.submit(self._fetch, key)
        f = self._build_pool.submit(self._build_from, key, f)
        if self._stage_pool is not None:
            f = self._stage_pool.submit(self._stage_from, key, f)
        self._inflight[key] = f
        return True

    def prefetch_window(self, epoch: int, episode: int, num_episodes: int) -> None:
        """Keep the next ``depth`` episodes of the epoch in flight."""
        for ep in range(episode, min(episode + self.depth, num_episodes)):
            self.prefetch(epoch, ep)

    def get(self, epoch: int, episode: int):
        """Returns the prefetched (staged) blocks, building synchronously on
        a miss. The prefetch is keyed by (epoch, episode): asking for a key
        that was never prefetched leaves other in-flight prefetches (e.g.
        later episodes of a depth-window) untouched and falls back to a
        synchronous build, instead of silently handing back the wrong
        episode's blocks."""
        fut = self._inflight.pop((epoch, episode), None)
        if fut is not None:
            return fut.result()
        return self._build_sync(epoch, episode)

    def pop_times(self, epoch: int, episode: int) -> dict:
        """Per-stage seconds recorded for a consumed episode:
        ``walk_wait_s`` (blocked in store.get), ``build_s``, ``stage_s``
        (absent for two-stage pipelines). Entries persist until popped
        (bounded by oldest-first eviction, cap ``max(64, 8*depth)``), so
        consuming episodes out of prefetch order no longer loses their
        timings; the ``pipeline.*_s`` registry histograms additionally
        keep every duration regardless of pops."""
        with self._times_mu:
            return self._times.pop((epoch, episode), {})

    def close(self):
        """Shut down the stage workers, waiting for in-flight work: a build
        racing interpreter teardown can die inside numpy with the module
        half-unloaded. Queued-but-unstarted futures are cancelled."""
        for pool in (self._fetch_pool, self._build_pool, self._stage_pool):
            if pool is not None:
                pool.shutdown(wait=True, cancel_futures=True)
        self._inflight.clear()
        self._times.clear()
