"""Episode pipeline: overlap host-side block building + H2D staging with
device compute (paper §III-C, Fig. 3 stages 5/7).

On TPU+JAX the intra-episode overlap (stages 2/4/6) is XLA's async collective
scheduling inside the jitted episode step; what remains for the host is
preparing episode e+1 (walk consumption, 2D bucketing, device_put) while
episode e trains. ``EpisodePipeline`` does exactly that with one worker
thread: jax dispatch is async, so `train_episode` returns as soon as the step
is enqueued and the worker's `device_put`s interleave with device compute.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.partition import NodePartition, build_episode_blocks


class EpisodePipeline:
    """Prefetches episode blocks one step ahead of training."""

    def __init__(self, store, part: NodePartition, *, pad_multiple: int,
                 block_cap: int | None = None):
        self.store = store
        self.part = part
        self.pad_multiple = pad_multiple
        self.block_cap = block_cap
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._next = None

    def _build(self, epoch: int, episode: int):
        pairs = self.store.get(epoch, episode)
        return build_episode_blocks(
            np.asarray(pairs), self.part,
            block_cap=self.block_cap, pad_multiple=self.pad_multiple)

    def prefetch(self, epoch: int, episode: int) -> None:
        self._next = ((epoch, episode),
                      self._pool.submit(self._build, epoch, episode))

    def get(self, epoch: int, episode: int):
        """Returns the prefetched blocks (or builds synchronously on miss).

        The prefetch is keyed by (epoch, episode): asking for anything else
        than what was prefetched discards the stale future (cancelled if it
        hasn't started; otherwise it finishes idle on the worker) and falls
        back to a synchronous build, instead of silently handing back the
        wrong episode's blocks."""
        if self._next is not None:
            (key, fut), self._next = self._next, None
            if key == (epoch, episode):
                return fut.result()
            fut.cancel()
        return self._build(epoch, episode)

    def close(self):
        """Shut down the worker, waiting for any in-flight build: a prefetch
        racing interpreter teardown can die inside numpy with the module
        half-unloaded. Queued-but-unstarted builds are cancelled."""
        self._pool.shutdown(wait=True, cancel_futures=True)
        self._next = None
