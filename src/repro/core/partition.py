"""Hierarchical data partitioning (paper §III-B).

Two cooperating partitions:

* **Node partition** — both embedding matrices are row-partitioned into
  P = Q·D·M contiguous shards (one per device). The vertex shard on each
  device is further split into ``k`` sub-parts. Nodes are block-assigned:
  node n → shard n // rows, local row n % rows.

* **2D edge partition** — an episode's edge samples (u, v) are bucketed by
  (vertex sub-shard of u, context shard of v) and laid out *by the rotation
  schedule*: ``blocks[dev, u, t, r, j]`` holds exactly the samples device
  ``dev`` can train at round (u, t, r) on sub-part j, with both endpoints
  resident. This is the paper's "orthogonal vertex usage" guarantee.

Everything here is host-side numpy; the arrays it emits are what
`core.hybrid` device_puts (pipelined, see `core.pipeline`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import rotation


@dataclasses.dataclass(frozen=True)
class NodePartition:
    """Row partition of the (padded) node id space."""

    num_nodes: int
    dims: tuple[int, ...]        # ring dims, e.g. (D, M) or (Q, D, M)
    subparts: int = 4            # paper's k

    @property
    def num_shards(self) -> int:
        return int(np.prod(self.dims))

    @property
    def rows_per_shard(self) -> int:
        return -(-self.num_nodes // self.num_shards)  # ceil

    @property
    def rows_per_subpart(self) -> int:
        return -(-self.rows_per_shard // self.subparts)

    @property
    def padded_rows_per_shard(self) -> int:
        return self.rows_per_subpart * self.subparts

    @property
    def padded_num_nodes(self) -> int:
        return self.padded_rows_per_shard * self.num_shards

    # node id -> (shard, subpart, row-within-subpart); vectorized
    def locate(self, nodes: np.ndarray):
        rows = self.padded_rows_per_shard
        shard = nodes // rows
        local = nodes % rows
        sub = local // self.rows_per_subpart
        subrow = local % self.rows_per_subpart
        return shard, sub, subrow

    def subpart_global_rows(self, sub: int, subrows: np.ndarray,
                            shard: int = 0) -> np.ndarray:
        """Inverse of :meth:`locate` for one (shard, subpart): row-within-
        subpart indices -> rows into the padded global table. The map is
        monotone in ``subrows``, which is what lets the tiered trainer's
        compact working-set remap preserve the kernels' sort/equality
        structure (see ``core.tiered``)."""
        return (shard * self.padded_rows_per_shard
                + sub * self.rows_per_subpart + subrows)

    def shard_coord(self, shard: np.ndarray):
        """Flat shard id -> mesh coordinate arrays."""
        coords = []
        rem = shard
        for n in self.dims[::-1]:
            coords.append(rem % n)
            rem = rem // n
        return tuple(coords[::-1])

    def pad_table(self, table: np.ndarray) -> np.ndarray:
        """(N, d) -> (padded_N, d) so shards/subparts divide evenly."""
        pad = self.padded_num_nodes - table.shape[0]
        if pad == 0:
            return table
        return np.concatenate([table, np.zeros((pad, table.shape[1]), table.dtype)])

    def unpad_table(self, table: np.ndarray) -> np.ndarray:
        return table[: self.num_nodes]


@dataclasses.dataclass
class EpisodeBlocks:
    """Device-major block layout for one episode.

    blocks: (P, Q, D, M, k, Bmax, 2) int32 — (vertex subrow, context row).
    counts: (P, Q, D, M, k) int32 — valid samples per cell.
    dropped: samples discarded because a cell overflowed Bmax (0 unless capped).
    """

    blocks: np.ndarray
    counts: np.ndarray
    dropped: int

    @property
    def block_cap(self) -> int:
        return int(self.blocks.shape[-2])


def _pair_cells(pairs: np.ndarray, part: NodePartition):
    """(u, v) pairs -> (flat cell id, vertex subrow, context row) arrays."""
    dims = part.dims
    P = part.num_shards
    k = part.subparts
    u, v = pairs[:, 0].astype(np.int64), pairs[:, 1].astype(np.int64)
    v_shard, v_sub, v_subrow = part.locate(u)           # u indexes vertex table
    c_shard, _, _ = part.locate(v)  # context side: shard id then local row
    c_row = v % part.padded_rows_per_shard

    # the device that trains a pair is the context owner (contexts are pinned)
    dev = c_shard
    # the round at which that device holds the pair's vertex shard
    dev_coords = part.shard_coord(dev)
    vs_coords = part.shard_coord(v_shard)
    rnd_coords = [(d - vv) % n for d, vv, n in zip(dev_coords, vs_coords, dims)]
    rnd_flat = rnd_coords[0]
    for c, n in zip(rnd_coords[1:], dims[1:]):
        rnd_flat = rnd_flat * n + c

    cell = (dev * P + rnd_flat) * k + v_sub              # flat cell id
    return cell, v_subrow, c_row


# pairs per chunk of the two-pass builder: bounds the transient per-chunk
# index arrays (~6 int64 vectors) to ~50 MB regardless of episode size
BUILD_CHUNK_PAIRS = 1 << 20


def build_episode_blocks(pairs: np.ndarray, part: NodePartition, *,
                         block_cap: int | None = None,
                         pad_multiple: int = 64,
                         chunk: int | None = None) -> EpisodeBlocks:
    """Bucket (u, v) pairs into the rotation-schedule block layout.

    Two streaming passes over ``chunk``-sized pair slices (default
    ``BUILD_CHUNK_PAIRS``): a counting pass fixes per-cell counts and the
    block capacity, then a scatter pass writes each slice straight into the
    preallocated block tensor — peak transient memory is O(chunk), not
    O(episode), and the output is bitwise identical for any chunk size
    (a pair's slot is its occurrence index within its cell in pair order).

    ``block_cap`` both caps AND pins the per-cell capacity: when set, every
    episode gets the same (cap rounded up to ``pad_multiple``) block shape
    even if its cells are emptier, so a streaming consumer compiles the
    episode step once instead of re-lowering per episode.
    """
    P = part.num_shards
    k = part.subparts
    n = pairs.shape[0]
    n_cells = P * P * k
    chunk = BUILD_CHUNK_PAIRS if chunk is None else max(1, chunk)
    # common case: the episode fits in one chunk — compute the cell ids once
    # and share them between the two passes instead of re-deriving
    one_shot = _pair_cells(pairs, part) if n <= chunk else None

    # pass 1: count pairs per cell
    counts_flat = np.zeros(n_cells, dtype=np.int64)
    if one_shot is not None:
        counts_flat += np.bincount(one_shot[0], minlength=n_cells)
    else:
        for lo in range(0, n, chunk):
            cell, _, _ = _pair_cells(pairs[lo: lo + chunk], part)
            counts_flat += np.bincount(cell, minlength=n_cells)

    if block_cap is not None:
        bmax = block_cap          # pinned: static shape across episodes
    else:
        bmax = int(counts_flat.max(initial=0))
    bmax = max(pad_multiple, -(-bmax // pad_multiple) * pad_multiple)

    # pass 2: chunked scatter. `fill` carries per-cell occupancy across
    # chunks so a pair's rank equals its rank in the one-shot sorted build.
    blocks = np.zeros((n_cells, bmax, 2), dtype=np.int32)
    fill = np.zeros(n_cells, dtype=np.int64)
    dropped = 0
    lstarts = np.zeros(n_cells + 1, dtype=np.int64)
    for lo in range(0, n, chunk):
        cell, v_subrow, c_row = (one_shot if one_shot is not None
                                 else _pair_cells(pairs[lo: lo + chunk], part))
        order = np.argsort(cell, kind="stable")
        cs = cell[order]
        local_counts = np.bincount(cs, minlength=n_cells)
        np.cumsum(local_counts, out=lstarts[1:])
        rank = fill[cs] + (np.arange(cs.size, dtype=np.int64) - lstarts[cs])
        keep = rank < bmax
        dropped += int((~keep).sum())
        sel = order[keep]
        blocks[cs[keep], rank[keep], 0] = v_subrow[sel]
        blocks[cs[keep], rank[keep], 1] = c_row[sel]
        fill += local_counts
    counts = np.minimum(counts_flat, bmax).astype(np.int32)

    Q_D_M = tuple(part.dims)
    blocks = blocks.reshape(P, *Q_D_M, k, bmax, 2)
    counts = counts.reshape(P, *Q_D_M, k)
    return EpisodeBlocks(blocks=blocks, counts=counts, dropped=dropped)


def episode_input_shapes(part: NodePartition, block_cap: int):
    """ShapeDtypeStruct-compatible shapes for the dry-run (no allocation)."""
    P, k = part.num_shards, part.subparts
    return {
        "blocks": (P, *part.dims, k, block_cap, 2),
        "counts": (P, *part.dims, k),
    }
