"""Tiered embedding tables: host-RAM master + fixed-budget HBM hot-row cache.

ROADMAP direction 2. The paper's headline run holds a 1B-node table across
40 GPUs — far beyond one device's HBM — by exploiting the power-law access
skew of walk samples: a small cache of hot (hub) rows absorbs most of the
row traffic while the full table lives in host RAM (GraphVite's CPU–GPU
hybrid and PyTorch-BigGraph's partition swap are the same trade; PAPERS.md).

Two pieces:

* :class:`TieredTable` — one logical (rows, d) table split into a host-RAM
  (optionally disk-backed) **master** holding every row and a fixed-budget
  device **cache** of hot rows, with an index ``slot_of: row id -> cache
  slot`` (−1 = cold). A frequency- or LRU-style promotion policy, fed by
  observed per-episode access counts, decides residency at episode
  boundaries; evicted rows write back to the master, promoted rows stream
  up. Hit/miss/eviction counters and byte-movement totals feed the
  ``repro.obs`` registry and the bench's hit-rate × bytes-moved model.

* :class:`TieredEmbeddingTrainer` — a drop-in for
  :class:`~repro.core.hybrid.HybridEmbeddingTrainer` (single-shard meshes)
  whose tables are tiered. Each episode block trains on a **compact
  working-set table**: the block's unique rows are assembled on device —
  hot rows gathered from the cache, cold rows streamed in (one batched
  ``device_put`` of the miss set) — the unmodified minibatch scan
  (``kernels.ops.sgns_step``) updates the compact tables in place, then hot
  rows scatter back to their cache slots and cold rows write back to the
  master. Because the compact remap is **monotone** (rows keep their
  relative order), every duplicate-combine path in the kernels sees the
  identical sort/equality structure, and training is bitwise identical to
  the fully-resident path for ANY cache budget (gated in
  ``tests/test_tiered.py``, budget 0 and budget = all rows included).

The heavy host-side prep — per-block unique/remap, the negative-index
replay, access-count extraction, and the H2D of the block index arrays —
is all done in :meth:`TieredEmbeddingTrainer.stage_blocks`, i.e. one
pipeline stage ahead of training (the walk store sees every id before the
trainer does). Streaming the miss-set *values* a stage ahead needs
dirty-row invalidation to stay bitwise-safe and is a recorded follow-on
(ROADMAP), as is a UVA-style zero-copy host tier.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hybrid import HybridConfig, HybridEmbeddingTrainer
from repro.core.partition import EpisodeBlocks
from repro.kernels import ops
from repro.obs import counter_add, gauge_set, span

CACHE_POLICIES = ("freq", "lru")

# working-set caps round up GEOMETRICALLY (128·2^k) so the per-(Wv, Wc)
# block step compiles O(log max-working-set) times per run, not once per
# distinct unique-row count — per-episode unique counts wander by a few
# percent, and a ~0.5 s XLA compile per new shape would otherwise dwarf
# the ~15 ms block step it feeds (measured on the bench's 2048-node run)
_CAP_MULTIPLE = 128


def _round_up(n: int, m: int) -> int:
    return max(m, -(-n // m) * m)


def _cap_for(n: int) -> int:
    cap = _CAP_MULTIPLE
    while cap < n:
        cap *= 2
    return cap


# Fixed-shape residency ops. Promote/evict set sizes vary every episode, so
# a naive ``cache[slots]`` / ``cache.at[free].set(...)`` would compile a
# fresh XLA executable per distinct size (hundreds of ms each — far more
# than the block step itself). Instead the index/value arrays pad up to a
# _CAP_MULTIPLE cap (the scratch row absorbs padded positions) and these
# two jitted helpers compile once per cap.
@jax.jit
def _gather_rows(cache: jax.Array, idx: jax.Array) -> jax.Array:
    return cache[idx]


_scatter_rows = jax.jit(lambda cache, idx, vals: cache.at[idx].set(vals),
                        donate_argnums=0)


@dataclasses.dataclass
class CacheStats:
    """Traffic- and byte-movement accounting for one tiered table.

    hits/misses are position-level (traffic-weighted) row accesses — the
    skew-sensitive headline rate; row_hits/row_misses count unique-per-block
    row *gathers*, which is what actually moves bytes (a block fetches each
    needed row once however many positions reference it). hbm_bytes_moved
    is cache-tier traffic (hot gather + scatter-back), host_bytes_moved is
    master-tier traffic (miss stream-in + write-back + promotion/eviction).
    """

    hits: int = 0
    misses: int = 0
    row_hits: int = 0
    row_misses: int = 0
    evictions: int = 0
    promotions: int = 0
    hbm_bytes_moved: int = 0
    host_bytes_moved: int = 0

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


@dataclasses.dataclass(frozen=True)
class TierPlan:
    """Device-ready gather/scatter plan for one block's unique rows against
    one tiered table, all arrays padded to the compile-pinned cap ``W``.

    For compact position p (the block's p-th unique row, ascending row id —
    the monotone remap): ``hot[p]`` selects between ``cache[slot[p]]`` and
    ``staged_cold[rank[p]]``; ``wslot[p]`` is the cache scatter-back target
    (the scratch row for cold/pad positions); ``coldpos[:n_cold]`` lists the
    compact positions whose final rows write back to the master.
    """

    uids: np.ndarray          # (U,) unique row ids, sorted
    cold_ids: np.ndarray      # (C,) subset of uids not cache-resident
    hot: jax.Array            # (W,) bool
    slot: jax.Array           # (W,) i32 cache slot (0 for cold/pad)
    rank: jax.Array           # (W,) i32 rank into the staged miss block
    wslot: jax.Array          # (W,) i32 scatter-back slot (scratch if cold)
    coldpos: jax.Array        # (W,) i32 compact positions of cold rows
    n_hot_traffic: int        # position-level accesses that hit
    n_traffic: int            # position-level accesses total


class TieredTable:
    """Host-RAM master + fixed-budget device cache for one (rows, d) table.

    The cache array carries one extra scratch row (index ``budget``): the
    block step scatters cold/pad working-set rows there so its cache
    write-back is a single dense scatter with no host-side masking.

    ``spill_path`` backs the master with a ``np.memmap`` instead of RAM —
    the optional disk tier for tables beyond host memory.
    """

    def __init__(self, rows: int, dim: int, dtype, budget: int, *,
                 policy: str = "freq", name: str = "table",
                 spill_path: str | None = None):
        if policy not in CACHE_POLICIES:
            raise ValueError(
                f"unknown cache policy {policy!r}; expected {CACHE_POLICIES}")
        self.rows = int(rows)
        self.dim = int(dim)
        self.dtype = np.dtype(dtype)
        self.budget = int(min(max(budget, 0), rows))
        self.policy = policy
        self.name = name
        self.itemsize = self.dtype.itemsize
        if spill_path is not None:
            self.master = np.memmap(spill_path, dtype=self.dtype, mode="w+",
                                    shape=(self.rows, self.dim))
        else:
            self.master = np.zeros((self.rows, self.dim), self.dtype)
        self.cache = jnp.zeros((self.budget + 1, self.dim),
                               dtype=jnp.dtype(self.dtype.name))
        self.slot_of = np.full(self.rows, -1, np.int64)
        self.row_of = np.full(self.budget, -1, np.int64)
        self.counts = np.zeros(self.rows, np.float64)   # freq policy state
        self.last_used = np.full(self.rows, -1, np.int64)  # lru policy state
        self._clock = 0
        self.stats = CacheStats()

    # ------------------------------------------------------------- policy
    def note_access(self, ids: np.ndarray, weights: np.ndarray) -> None:
        """Fold one episode's observed accesses into the policy state."""
        ids = np.asarray(ids, np.int64)
        np.add.at(self.counts, ids, np.asarray(weights, np.float64))
        self.last_used[ids] = self._clock
        self._clock += 1

    def desired_hot(self) -> np.ndarray:
        """The rows the policy wants resident, sorted ascending (row id is
        the deterministic tie-break). Only rows that have actually been
        accessed are candidates — an undersubscribed cache stays partial
        rather than pinning arbitrary rows."""
        if self.budget == 0:
            return np.empty(0, np.int64)
        if self.policy == "freq":
            score, seen = self.counts, self.counts > 0
        else:
            score = self.last_used.astype(np.float64)
            seen = self.last_used >= 0
        order = np.lexsort((np.arange(self.rows), -score))
        order = order[seen[order]]
        return np.sort(order[: self.budget])

    def promote(self) -> tuple[int, int]:
        """Reconcile residency with :meth:`desired_hot`: evicted rows write
        back to the master, promoted rows stream up into the freed slots
        (deterministic: promotion order is ascending row id into ascending
        free slots). Returns (n_promoted, n_evicted)."""
        desired = self.desired_hot()
        want = np.zeros(self.rows, bool)
        want[desired] = True
        cur = self.row_of[self.row_of >= 0]
        evict_ids = np.sort(cur[~want[cur]])
        new_ids = desired[self.slot_of[desired] < 0]
        row_bytes = self.dim * self.itemsize
        if evict_ids.size:
            slots = self.slot_of[evict_ids]
            cap = _cap_for(slots.size)
            idx = np.full(cap, self.budget, np.int32)   # pads hit the scratch
            idx[: slots.size] = slots
            rows = np.asarray(_gather_rows(self.cache, jnp.asarray(idx)))
            self.master[evict_ids] = rows[: slots.size]
            self.slot_of[evict_ids] = -1
            self.row_of[slots] = -1
        if new_ids.size:
            free = np.flatnonzero(self.row_of < 0)[: new_ids.size]
            cap = _cap_for(free.size)
            idx = np.full(cap, self.budget, np.int32)   # pads hit the scratch
            idx[: free.size] = free
            vals = np.zeros((cap, self.dim), self.dtype)
            vals[: free.size] = self.master[new_ids]
            self.cache = _scatter_rows(self.cache, jnp.asarray(idx),
                                       jnp.asarray(vals))
            self.slot_of[new_ids] = free
            self.row_of[free] = new_ids
        self.stats.evictions += int(evict_ids.size)
        self.stats.promotions += int(new_ids.size)
        self.stats.host_bytes_moved += (evict_ids.size + new_ids.size) * row_bytes
        counter_add(f"cache.{self.name}.evictions", int(evict_ids.size))
        counter_add(f"cache.{self.name}.promotions", int(new_ids.size))
        gauge_set(f"cache.{self.name}.resident_rows",
                  int((self.row_of >= 0).sum()))
        return int(new_ids.size), int(evict_ids.size)

    # ------------------------------------------------------------ gathers
    def plan(self, uids: np.ndarray, cap: int,
             traffic_ids: np.ndarray) -> TierPlan:
        """Build the gather/scatter plan for a block's unique rows (sorted
        ``uids``) padded to ``cap``, and account the hit/miss traffic.
        ``traffic_ids`` are the block's position-level accesses (with
        multiplicity) for the skew-weighted hit rate."""
        U = uids.size
        slots = self.slot_of[uids]
        is_hot = slots >= 0
        cold_ids = uids[~is_hot]
        rank = np.cumsum(~is_hot) - 1
        pad = cap - U
        hot = np.pad(is_hot, (0, pad))
        slot = np.pad(np.where(is_hot, slots, 0).astype(np.int32), (0, pad))
        rnk = np.pad(np.where(is_hot, 0, rank).astype(np.int32), (0, pad))
        wslot = np.pad(
            np.where(is_hot, slots, self.budget).astype(np.int32),
            (0, pad), constant_values=self.budget)
        coldpos = np.zeros(cap, np.int32)
        cp = np.flatnonzero(~is_hot).astype(np.int32)
        coldpos[: cp.size] = cp
        n_hot_traffic = int((self.slot_of[traffic_ids] >= 0).sum())
        n_traffic = int(traffic_ids.size)
        row_bytes = self.dim * self.itemsize
        n_hot_rows = int(is_hot.sum())
        self.stats.hits += n_hot_traffic
        self.stats.misses += n_traffic - n_hot_traffic
        self.stats.row_hits += n_hot_rows
        self.stats.row_misses += int(cold_ids.size)
        # each unique row moves twice (gather + write-back) on its tier
        self.stats.hbm_bytes_moved += 2 * n_hot_rows * row_bytes
        self.stats.host_bytes_moved += 2 * int(cold_ids.size) * row_bytes
        counter_add(f"cache.{self.name}.hits", n_hot_traffic)
        counter_add(f"cache.{self.name}.misses", n_traffic - n_hot_traffic)
        return TierPlan(
            uids=uids, cold_ids=cold_ids,
            hot=jnp.asarray(hot), slot=jnp.asarray(slot),
            rank=jnp.asarray(rnk), wslot=jnp.asarray(wslot),
            coldpos=jnp.asarray(coldpos),
            n_hot_traffic=n_hot_traffic, n_traffic=n_traffic)

    def stage_misses(self, plan: TierPlan, cap: int) -> jax.Array:
        """Batched device_put of the plan's miss set, padded to ``cap``."""
        buf = np.zeros((cap, self.dim), self.dtype)
        if plan.cold_ids.size:
            buf[: plan.cold_ids.size] = self.master[plan.cold_ids]
        return jnp.asarray(buf)

    def write_back(self, plan: TierPlan, cold_out: jax.Array) -> None:
        """Master update for a trained block's miss set (the cache side was
        updated in place by the block step's scatter)."""
        C = plan.cold_ids.size
        if C:
            # whole-buffer D2H then a host-side slice: cold_out's shape is
            # the compile-pinned cap, so this never mints a new executable
            # the way a per-C device slice would
            self.master[plan.cold_ids] = np.asarray(cold_out)[:C]

    # ------------------------------------------------------------- export
    def flush(self) -> None:
        """Write every cache-resident row back to the master (residency and
        policy state are untouched) so the master is a complete snapshot."""
        live = self.row_of >= 0
        if live.any():
            slots = np.flatnonzero(live)
            cache_np = np.asarray(self.cache)    # one fixed-shape D2H
            self.master[self.row_of[slots]] = cache_np[slots]

    def set_master(self, table: np.ndarray) -> None:
        """Install externally-provided rows (the resume path) and drop all
        cache residency — policy state survives, so promotion resumes from
        the observed access history."""
        self.master[...] = np.asarray(table).astype(self.dtype, copy=False)
        self.slot_of[:] = -1
        self.row_of[:] = -1
        self.cache = jnp.zeros_like(self.cache)

    def snapshot(self) -> np.ndarray:
        self.flush()
        return np.array(self.master)


# ------------------------------------------------------------------ trainer
@dataclasses.dataclass(frozen=True)
class BlockPrep:
    """Promotion-independent host prep for one (round, sub-part) block, done
    at stage time: the monotone compact remap, the replayed negative
    indices, and per-table unique/traffic id sets."""

    v_uids: np.ndarray        # unique global vertex rows (sorted)
    c_uids: np.ndarray        # unique global ctx rows incl. negatives (sorted)
    v_traffic: np.ndarray     # position-level vertex accesses (real samples)
    c_traffic: np.ndarray     # position-level ctx accesses (real + negatives)
    blk3: jax.Array           # (nmb, mb, 2) compact (v, c) indices, staged
    negs: jax.Array           # (nmb, S) compact negative indices, staged
    cnt: np.int32             # valid samples in the block
    Wv: int                   # compile-pinned caps (geometric, 128*2^k)
    Wc: int
    nmb: int


@dataclasses.dataclass(frozen=True)
class StagedTieredEpisode:
    """stage_blocks output: every block's prep + the episode's access-count
    vectors (what promotion will consume), ready for train_episode."""

    blocks: tuple            # BlockPrep, schedule order
    v_ids: np.ndarray        # episode access counts, vertex table
    v_counts: np.ndarray
    c_ids: np.ndarray        # episode access counts, ctx table
    c_counts: np.ndarray
    num_samples: int
    dropped: int = 0


@functools.partial(jax.jit, static_argnames=("total", "S", "pool_n"))
def _replay_neg_indices(seed, *, total: int, S: int, pool_n: int):
    """Replay the episode step's negative-sampling key chain: the resident
    path splits the episode key once per minibatch in schedule order, so the
    (total, S) pool-index draws are a pure function of the seed."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), jnp.int32(0))

    def body(key, _):
        key, kneg = jax.random.split(key)
        return key, jax.random.randint(kneg, (S,), 0, pool_n)

    _, pidx = jax.lax.scan(body, key, None, length=total)
    return pidx


class TieredEmbeddingTrainer(HybridEmbeddingTrainer):
    """Hybrid trainer whose tables are tiered (host master + HBM hot cache).

    Drop-in for single-shard meshes: same partition/negative-pool/RNG
    machinery, same public surface (stage_blocks / train_episode /
    embeddings / set_embeddings), bitwise-identical training for any cache
    budget. Multi-shard tiering (ring rotation over partial shards) is a
    recorded follow-on; this class raises on P > 1 meshes.

    hbm_rows: cache budget in rows, per table (vertex and context caches
    are sized independently with the same budget). policy: "freq" promotes
    by cumulative access count, "lru" by most-recent episode touch; both
    break ties toward the smaller row id, so promotion is deterministic.
    """

    def __init__(self, num_nodes: int, mesh, cfg: HybridConfig,
                 degrees: np.ndarray | None = None, *, hbm_rows: int,
                 policy: str = "freq", spill_dir: str | None = None):
        super().__init__(num_nodes, mesh, cfg, degrees=degrees)
        if self.part.num_shards != 1:
            raise ValueError(
                "TieredEmbeddingTrainer supports single-shard meshes; "
                f"got dims {self.part.dims} (multi-shard tiering is a "
                "ROADMAP follow-on)")
        rows = self.part.padded_num_nodes
        paths = (None, None)
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            paths = (os.path.join(spill_dir, "vertex.master"),
                     os.path.join(spill_dir, "context.master"))
        self.hbm_rows = int(hbm_rows)
        self.vert_t = TieredTable(rows, cfg.dim, np.dtype(cfg.dtype),
                                  hbm_rows, policy=policy, name="vertex",
                                  spill_path=paths[0])
        self.ctx_t = TieredTable(rows, cfg.dim, np.dtype(cfg.dtype),
                                 hbm_rows, policy=policy, name="context",
                                 spill_path=paths[1])
        self._block_fns: dict = {}
        self._neg_cache: dict = {}
        self._vmem_checked: set = set()

    # ---------------------------------------------------------------- setup
    def init_embeddings(self):
        """Same init stream as the resident trainer, landing in the masters."""
        part, cfg = self.part, self.cfg
        rng = np.random.default_rng(cfg.seed)
        dt = np.dtype(cfg.dtype)
        vert = ((rng.random((part.padded_num_nodes, cfg.dim),
                            dtype=np.float32) - 0.5) / cfg.dim).astype(dt)
        self.vert_t.set_master(vert)
        self.ctx_t.set_master(
            np.zeros((part.padded_num_nodes, cfg.dim), dt))

    def set_embeddings(self, vert: np.ndarray, ctx: np.ndarray) -> None:
        dt = np.dtype(self.cfg.dtype)
        self.vert_t.set_master(self.part.pad_table(
            np.asarray(vert).astype(dt, copy=False)))
        self.ctx_t.set_master(self.part.pad_table(
            np.asarray(ctx).astype(dt, copy=False)))

    def embeddings(self) -> np.ndarray:
        return self.part.unpad_table(self.vert_t.snapshot()).copy()

    def context_embeddings(self) -> np.ndarray:
        return self.part.unpad_table(self.ctx_t.snapshot()).copy()

    def cache_stats(self) -> dict:
        v, c = self.vert_t.stats, self.ctx_t.stats
        hits, misses = v.hits + c.hits, v.misses + c.misses
        return {
            "hbm_rows": self.hbm_rows,
            "policy": self.vert_t.policy,
            "hit_rate": hits / max(hits + misses, 1),
            "hbm_bytes_moved": v.hbm_bytes_moved + c.hbm_bytes_moved,
            "host_bytes_moved": v.host_bytes_moved + c.host_bytes_moved,
            "vertex": v.as_dict(),
            "context": c.as_dict(),
        }

    # ---------------------------------------------------------------- train
    def _negatives(self, total: int) -> np.ndarray:
        """(total, S) global ctx rows: the replayed pool draws mapped through
        the per-device pool (single shard -> pool[0])."""
        got = self._neg_cache.get(total)
        if got is None:
            pidx = np.asarray(_replay_neg_indices(
                np.int32(self.cfg.seed), total=total, S=self.cfg.negatives,
                pool_n=self.cfg.neg_pool))
            got = self.pool[0][pidx].astype(np.int64)
            self._neg_cache[total] = got
        return got

    def stage_blocks(self, eb: EpisodeBlocks) -> StagedTieredEpisode:
        """All promotion-independent prep, safe on a pipeline worker thread:
        compact remaps, negative replay, access-count extraction, and the
        H2D staging of the block index arrays — one stage ahead of training."""
        part, cfg = self.part, self.cfg
        mb = cfg.minibatch
        k = part.subparts
        bmax = eb.block_cap
        nmb = bmax // mb
        blocks = eb.blocks[0].reshape(-1, k, bmax, 2)
        counts = eb.counts[0].reshape(-1, k)
        R = blocks.shape[0]
        negs_all = self._negatives(R * k * nmb)

        preps = []
        v_acc, c_acc = [], []
        t = 0
        for r in range(R):
            for j in range(k):
                blk = blocks[r, j].astype(np.int64)
                cnt = int(counts[r, j])
                v_glob = part.subpart_global_rows(j, blk[:, 0])
                c_glob = blk[:, 1]
                negs = negs_all[t: t + nmb]
                t += nmb
                v_uids = np.unique(v_glob)
                c_uids = np.unique(np.concatenate([c_glob, negs.ravel()]))
                Wv = _cap_for(v_uids.size)
                Wc = _cap_for(c_uids.size)
                # monotone compact remap: sorted-unique rank preserves the
                # relative order (and tie structure) of every index vector,
                # so the kernels' duplicate-combine sees identical sort and
                # equality structure -> bitwise-identical updates
                v_c = np.searchsorted(v_uids, v_glob).astype(np.int32)
                c_c = np.searchsorted(c_uids, c_glob).astype(np.int32)
                n_c = np.searchsorted(c_uids, negs).astype(np.int32)
                blk3 = np.stack([v_c, c_c], axis=1).reshape(nmb, mb, 2)
                v_traffic = v_glob[:cnt]
                c_traffic = np.concatenate([c_glob[:cnt], negs.ravel()])
                v_acc.append(v_traffic)
                c_acc.append(c_traffic)
                preps.append(BlockPrep(
                    v_uids=v_uids, c_uids=c_uids,
                    v_traffic=v_traffic, c_traffic=c_traffic,
                    blk3=jnp.asarray(blk3), negs=jnp.asarray(n_c),
                    cnt=np.int32(cnt), Wv=Wv, Wc=Wc, nmb=nmb))
        v_ids, v_counts = np.unique(np.concatenate(v_acc), return_counts=True)
        c_ids, c_counts = np.unique(np.concatenate(c_acc), return_counts=True)
        return StagedTieredEpisode(
            blocks=tuple(preps), v_ids=v_ids, v_counts=v_counts,
            c_ids=c_ids, c_counts=c_counts,
            num_samples=int(eb.counts.sum()), dropped=eb.dropped)

    def _block_fn(self, Wv: int, Wc: int, nmb: int):
        key = (Wv, Wc, nmb)
        fn = self._block_fns.get(key)
        if fn is not None:
            return fn
        cfg = self.cfg
        mb, S = cfg.minibatch, cfg.negatives
        self._check_vmem(Wv, Wc)

        def step(vcache, ccache, vcold, ccold,
                 v_hot, v_slot, v_rank, v_wslot, v_coldpos,
                 c_hot, c_slot, c_rank, c_wslot, c_coldpos,
                 blk3, negs, cnt, lr, lacc):
            # assemble the compact working-set tables: hot rows from the
            # cache, cold rows from the staged miss block
            vcomp = jnp.where(v_hot[:, None], vcache[v_slot], vcold[v_rank])
            ccomp = jnp.where(c_hot[:, None], ccache[c_slot], ccold[c_rank])
            offsets = jnp.arange(nmb, dtype=jnp.int32) * mb

            def body(carry, xs):
                vj, cj, la = carry
                blk_mb, off, idx_n = xs
                mask = ((off + jnp.arange(mb, dtype=jnp.int32))
                        < cnt).astype(vj.dtype)
                vj, cj, loss = ops.sgns_step(
                    vj, cj, blk_mb[:, 0], blk_mb[:, 1], idx_n, mask, lr,
                    impl=cfg.impl, reduction=cfg.reduction,
                    block_b=cfg.block_b)
                return (vj, cj, la + loss), None

            # block loss sums from zero, then adds to the episode
            # accumulator — the resident path's exact f32 association
            (vcomp, ccomp, bl), _ = jax.lax.scan(
                body, (vcomp, ccomp, jnp.float32(0.0)), (blk3, offsets, negs))
            lacc = lacc + bl
            # hot rows scatter back to their slots in place; cold and pad
            # positions land on the cache's scratch row
            vcache = vcache.at[v_wslot].set(vcomp)
            ccache = ccache.at[c_wslot].set(ccomp)
            return (vcache, ccache, vcomp[v_coldpos], ccomp[c_coldpos], lacc)

        fn = jax.jit(step, donate_argnums=(0, 1))
        self._block_fns[key] = fn
        return fn

    def _check_vmem(self, Wv: int, Wc: int) -> None:
        """Satellite VMEM accounting: on real hardware a fused update with a
        co-resident miss-staging block must still fit the budget; surface the
        extended model's verdict once per working-set shape."""
        key = (Wv, Wc)
        if key in self._vmem_checked:
            return
        self._vmem_checked.add(key)
        cfg = self.cfg
        plan = ops.plan_fused_update(
            cfg.minibatch, cfg.dim, cfg.negatives, np.dtype(cfg.dtype),
            block_b=cfg.block_b, staging_rows=Wv + Wc)
        gauge_set("cache.staging_rows", Wv + Wc)
        gauge_set("cache.fused_chunk_rows", plan.chunk_rows)

    def train_episode(self, eb, *, lr: float | None = None) -> float:
        if isinstance(eb, EpisodeBlocks):
            eb = self.stage_blocks(eb)
        cfg = self.cfg
        lr32 = np.float32(cfg.lr if lr is None else lr)
        # promotion first: the access counts arrived a pipeline stage ahead
        # (stage_blocks), so this episode's hot set is resident before its
        # first block trains
        with span("cache_promote", "train",
                  {"vertex_rows": int(self.vert_t.budget),
                   "context_rows": int(self.ctx_t.budget)}):
            self.vert_t.note_access(eb.v_ids, eb.v_counts)
            self.ctx_t.note_access(eb.c_ids, eb.c_counts)
            self.vert_t.promote()
            self.ctx_t.promote()
        lacc = jnp.float32(0.0)
        total = 0
        for bp in eb.blocks:
            vplan = self.vert_t.plan(bp.v_uids, bp.Wv, bp.v_traffic)
            cplan = self.ctx_t.plan(bp.c_uids, bp.Wc, bp.c_traffic)
            vcold = self.vert_t.stage_misses(vplan, bp.Wv)
            ccold = self.ctx_t.stage_misses(cplan, bp.Wc)
            fn = self._block_fn(bp.Wv, bp.Wc, bp.nmb)
            (self.vert_t.cache, self.ctx_t.cache,
             vcold_out, ccold_out, lacc) = fn(
                self.vert_t.cache, self.ctx_t.cache, vcold, ccold,
                vplan.hot, vplan.slot, vplan.rank, vplan.wslot, vplan.coldpos,
                cplan.hot, cplan.slot, cplan.rank, cplan.wslot, cplan.coldpos,
                bp.blk3, bp.negs, bp.cnt, lr32, lacc)
            self.vert_t.write_back(vplan, vcold_out)
            self.ctx_t.write_back(cplan, ccold_out)
            total += int(bp.cnt)
        # same normalizer (and f32 op order) as the resident episode step
        return float(lacc / jnp.float32(max(float(total), 1.0)))
