"""Two-level (three on multi-pod) ring rotation schedules (paper §III-B, §IV-B).

Devices form nested rings: the fast inner ring is the ``"model"`` mesh axis
(paper: NVLink P2P inside a node → ICI here), the middle ring is ``"data"``
(paper: inter-node IB ring), and on multi-pod meshes an outer ``"pod"`` ring
(DCN). Context embedding shards are pinned to devices; vertex embedding
shards rotate through the rings so that every vertex shard meets every
context shard exactly once per episode.

Each device's vertex shard is further split into ``k`` **sub-parts**
(paper §III-B, k=4) which are trained and ppermuted one at a time so the
transfer of sub-part j overlaps the training of sub-part j+1 (the paper's
ping-pong buffers). Sub-parts rotate *with* their parent shard, so the
sub-part index is schedule-invariant.

Schedule (derived in DESIGN.md): device coordinate (q, a, b) on mesh
(Q, D, M), at round (u, t, r):
    vertex shard held = flatten(((q-u) mod Q, (a-t) mod D, (b-r) mod M))
    context shard     = flatten((q, a, b))     (pinned)
The inner scan runs r = 0..M-1 with a shift-by-one ppermute over "model"
after each round; after M inner rounds the shard is home again and a single
ppermute over "data" advances t; likewise for "pod".
"""
from __future__ import annotations

import itertools

import numpy as np


def flatten_coord(coord: tuple[int, ...], dims: tuple[int, ...]) -> int:
    out = 0
    for c, n in zip(coord, dims):
        out = out * n + c
    return out


def vertex_shard_at(device: tuple[int, ...], rounds: tuple[int, ...],
                    dims: tuple[int, ...]) -> int:
    """Vertex shard held by `device` at round index tuple `rounds`."""
    coord = tuple((d - r) % n for d, r, n in zip(device, rounds, dims))
    return flatten_coord(coord, dims)


def context_shard_at(device: tuple[int, ...], dims: tuple[int, ...]) -> int:
    return flatten_coord(device, dims)


def round_of_pair(device: tuple[int, ...], v_shard_coord: tuple[int, ...],
                  dims: tuple[int, ...]) -> tuple[int, ...]:
    """Inverse schedule: at which round does `device` hold vertex shard v?"""
    return tuple((d - v) % n for d, v, n in zip(device, v_shard_coord, dims))


def full_schedule(dims: tuple[int, ...]) -> np.ndarray:
    """sched[dev_flat, round_flat] = vertex shard id. For tests/analysis."""
    P = int(np.prod(dims))
    sched = np.zeros((P, P), dtype=np.int64)
    for dev in itertools.product(*[range(n) for n in dims]):
        for rnd in itertools.product(*[range(n) for n in dims]):
            sched[flatten_coord(dev, dims), flatten_coord(rnd, dims)] = (
                vertex_shard_at(dev, rnd, dims)
            )
    return sched


def check_schedule(dims: tuple[int, ...]) -> None:
    """Invariants: (1) every device sees every vertex shard exactly once per
    episode (row bijection); (2) at any round, no two devices hold the same
    vertex shard (column bijection) — the orthogonality that makes the 2D
    block updates conflict-free."""
    sched = full_schedule(dims)
    P = sched.shape[0]
    want = np.arange(P)
    for i in range(P):
        assert np.array_equal(np.sort(sched[i]), want), f"row {i} not a bijection"
        assert np.array_equal(np.sort(sched[:, i]), want), f"round {i} collision"
