"""Two-tier quantized retrieval: int8 first-pass scan + exact f32 rescore.

The exact MIPS scan is bandwidth-bound — every table byte is read once per
query block, so scan bytes *are* the latency roofline. This module trades
arithmetic for bandwidth the way GraphVite trades capacity for compact
on-GPU tables: a symmetric per-row int8 copy of each shard is scanned
first (4x fewer bytes than f32), keeping an over-fetched top-``m``
candidate set per query (``m = ceil(k * overfetch)``), and only the ``m``
survivors' full-precision rows are gathered back and re-scored exactly.

Tier one (:func:`repro.embed_serve.topk.topk_mips_quant`) is approximate
by at most the quantization error, which is bounded per row (see
:func:`quantize_rows`); tier two (:func:`rescore_exact`) re-ranks the
survivors with the same f32 scores and smaller-index tie rule as the full
exact scan, so whenever the candidate set contains the true top-k — the
overfetch margin's job — the final (Q, k) result equals
``kernels.ref.topk_mips_ref`` exactly. That containment is not proven a
priori on arbitrary data; it is *gated*: the CLI's ``--check-recall`` and
``bench_serve``'s recall assertion compare against the numpy oracle every
run, so a too-thin margin fails loudly instead of serving quietly wrong.
(Concretely observed: cosine serving over a barely-trained, near-collinear
table compresses the score range until the rank-m boundary sits inside the
quantization error — the gate fails at the default margin, and a wider
``--overfetch`` restores exactness. Size the margin per workload.)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.embed_serve import topk as tk
from repro.kernels import sgns as _k

INT8_QMAX = 127          # symmetric: values in [-127, 127]; -128 unused so
                         # the range (and the error bound) is sign-balanced
DEFAULT_OVERFETCH = 4.0  # m = ceil(k * overfetch) tier-one survivors


def quantize_rows(table):
    """Symmetric per-row int8 quantization of a (N, d) table.

    Returns ``(q (N, d) int8, scale (N,) f32)`` with
    ``scale_r = max|row_r| / 127`` (1.0 for an all-zero row, which
    round-trips exactly) and ``q = round(row / scale_r)``.

    Round-trip bound (documented and property-tested): no value clips —
    ``|x| <= 127 * scale_r`` by construction — so the only error is the
    rounding, ``|scale_r * q - x| <= scale_r / 2 = max|row_r| / 254``
    elementwise. A quantized MIPS score against query ``u`` is therefore
    off by at most ``||u||_1 * scale_r / 2`` for row r.

    bf16 tables are quantized through their f32 values (bitwise-stable:
    bf16 -> f32 is exact), so serving's quant tier sees the same numbers
    the exact tier scores.
    """
    x = np.asarray(jnp.asarray(table).astype(jnp.float32))
    amax = np.max(np.abs(x), axis=1)
    scale = np.where(amax > 0, amax / INT8_QMAX, 1.0).astype(np.float32)
    q = np.clip(np.rint(x / scale[:, None]), -INT8_QMAX, INT8_QMAX)
    return q.astype(np.int8), scale


def dequantize_rows(q, scale) -> np.ndarray:
    """(N, d) int8 + (N,) f32 scales -> the (N, d) f32 reconstruction."""
    return np.asarray(q, np.float32) * np.asarray(scale, np.float32)[:, None]


def overfetch_m(k: int, overfetch: float, n_rows: int) -> int:
    """Tier-one candidate count: ceil(k * overfetch), at least k, clamped
    to the shard's rows (a shard can't yield more candidates than rows —
    and at m == n_rows the two-tier scan degenerates to exhaustive-exact,
    so small/degraded shards are automatically safe)."""
    return max(1, min(max(k, math.ceil(k * overfetch)), n_rows))


@functools.partial(jax.jit, static_argnames=("k", "gather", "interpret"))
def rescore_exact(table, queries, cand_idx, *, k: int, gather: str = "xla",
                  interpret: bool = False):
    """Tier two: gather the surviving rows, re-score in f32, re-rank.

    table: the (N, d) full-precision shard (f32/bf16); cand_idx: (Q, m)
    shard-local ids from the int8 first pass (sentinel slots from short
    shards allowed — they gather row 0 but score -inf and keep losing).
    ``gather="pallas"`` routes the (Q*m,) flat gather through the
    training-side blocked-DMA ``kernels.sgns.gather_rows``; ``"xla"`` is
    the plain ``jnp.take`` CPU path. Selection is the shared
    :func:`topk.select_topk`, so the tie rule cannot diverge from the
    exact scan's.

    Returns ((Q, k) f32, (Q, k) i32) — the exact top-k *of the candidate
    set* under the oracle's total order.
    """
    Q, m = cand_idx.shape
    d = table.shape[1]
    idx = cand_idx.astype(jnp.int32)
    safe = jnp.where(idx == tk.IDX_SENTINEL, 0, idx).reshape(-1)
    if gather == "pallas":
        rows = _k.gather_rows(table, safe, interpret=interpret)
    else:
        rows = jnp.take(table, safe, axis=0)
    rows = rows.reshape(Q, m, d).astype(jnp.float32)
    scores = jnp.einsum("qd,qmd->qm", queries.astype(jnp.float32), rows)
    scores = jnp.where(idx == tk.IDX_SENTINEL, tk.NEG_INF, scores)
    return tk.select_topk(scores, idx, k)


def topk_mips_quant_rescored(table, qtable, scales, queries, *, k: int,
                             overfetch: float = DEFAULT_OVERFETCH,
                             valid: int | None = None,
                             block_q: int = tk.DEFAULT_BLOCK_Q,
                             block_n: int | None = None,
                             impl: str = "pallas",
                             interpret: bool = False):
    """The full two-tier shard scan: int8 top-m, exact rescore to top-k.

    table and (qtable, scales) must cover the same rows in the same order
    (``quantize_rows(table)``); `valid` masks padded tail rows in both
    tiers. impl: "pallas" streams int8 tiles through the double-buffered
    DMA kernel and gathers survivors with the blocked-DMA gather; "xla" is
    the plain-jnp CPU path. Output layout matches :func:`topk.topk_mips`.
    """
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown quant impl {impl!r}")
    n_rows = valid if valid is not None else qtable.shape[0]
    m = overfetch_m(k, overfetch, n_rows)
    if impl == "pallas":
        _, ci = tk.topk_mips_quant(qtable, scales, queries, m=m,
                                   valid=valid, block_q=block_q,
                                   block_n=block_n, interpret=interpret)
        return rescore_exact(table, queries, ci, k=k, gather="pallas",
                             interpret=interpret)
    _, ci = tk.topk_mips_quant_xla(qtable, scales, queries, m=m,
                                   valid=valid)
    return rescore_exact(table, queries, ci, k=k, gather="xla")
