"""Device-sharded embedding store: trained tables → servable shards.

The serving twin of the training layout: tables are row-partitioned with
the same ``NodePartition`` block rule the trainer uses (node n → shard
n // rows, local row n % rows), one shard per device, so a checkpoint
written by ``launch/train.py`` loads without any re-indexing — shard s of
the store holds exactly the rows device s held during training (subparts=1:
serving has no rotation, so the sub-part split is irrelevant here).

Queries fan out to every shard (each runs the Pallas top-k kernel over its
resident rows — the GraphVite-style shard-local lookup), and the per-shard
(k) lists meet in ``topk.merge_topk``. Tables keep their checkpoint dtype
(bf16 by default, honoring ``HybridConfig.dtype``) and are loaded bitwise;
``normalize=True`` rescales rows to unit norm at load so the same MIPS
kernel serves cosine retrieval.

``quant="int8"`` additionally builds a symmetric per-row int8 copy of every
shard (``embed_serve.quant``), enabling the two-tier scan
(``impl="quant"``): int8 first pass at 4x less scan traffic, exact rescore
of the over-fetched survivors, same cross-shard merge.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import NodePartition
from repro.embed_serve import quant as qz
from repro.embed_serve import topk as tk
from repro.kernels import ref as kref
from repro.train.checkpoint import load_arrays

_ON_TPU = jax.default_backend() == "tpu"

QUERY_IMPLS = ("auto", "pallas", "rowwise", "xla",
               "quant", "quant_pallas", "quant_xla")
QUANT_TIERS = (None, "int8")


class ShardedEmbeddingStore:
    """Row-sharded embedding table + exact top-k retrieval over it."""

    def __init__(self, shards, part: NodePartition, valid, devices, *,
                 host_table, block_n: int, step: int = -1,
                 qshards=None, quant=None,
                 overfetch: float = qz.DEFAULT_OVERFETCH):
        self.shards = shards                  # per-device (rows_p, d) arrays
        self.part = part
        self.valid = tuple(valid)             # real rows per shard
        self.devices = tuple(devices)
        self.host_table = host_table          # (num_nodes, d) as served,
        self.block_n = block_n                # or None (keep_host_table off)
        self.step = step
        self.qshards = qshards                # per-device (int8, scales) or
        self.quant = quant                    # None (no quantized tier)
        self.overfetch = overfetch            # default tier-one margin

    # ------------------------------------------------------------- loading
    @classmethod
    def from_array(cls, table, *, devices=None, dtype=None,
                   block_n: int | None = None, normalize: bool = False,
                   keep_host_table: bool = True, quant: str | None = None,
                   overfetch: float = qz.DEFAULT_OVERFETCH,
                   step: int = -1) -> "ShardedEmbeddingStore":
        """Shard an in-memory (num_nodes, d) table across `devices`.

        dtype=None keeps the array's dtype (the checkpoint's, i.e. the
        training ``HybridConfig.dtype``). Shard rows are padded to a
        block_n multiple once, here, so serving never re-materializes the
        table; padded rows are masked out of every query by ``valid``.
        block_n=None sizes the scan tile with ``topk.choose_block_n``
        against the VMEM budget (k not known yet — planned at the
        ``DEFAULT_PLAN_K`` candidate allowance).
        keep_host_table=False drops the host copy after sharding (serving
        itself never reads it — it only backs ``oracle_topk`` and query
        sampling; at production table sizes it would double the footprint).
        quant="int8" builds the two-tier scan's per-shard int8 copies
        (``quant.quantize_rows`` of the served — post-normalize — rows,
        same row order and padding as the exact shards); `overfetch` is
        the default tier-one margin ``topk(impl="quant")`` uses.
        """
        devices = list(devices) if devices is not None else jax.devices()
        if quant not in QUANT_TIERS:
            raise ValueError(f"unknown quant tier {quant!r}; "
                             f"one of {QUANT_TIERS}")
        table = np.asarray(table)
        if dtype is not None and np.dtype(jnp.dtype(dtype)) != table.dtype:
            table = np.asarray(jnp.asarray(table).astype(jnp.dtype(dtype)))
        if normalize:                         # cosine via the MIPS kernel
            f32 = table.astype(np.float32)
            f32 /= np.linalg.norm(f32, axis=1, keepdims=True) + 1e-12
            table = np.asarray(jnp.asarray(f32).astype(table.dtype))
        num_nodes, d = table.shape
        part = NodePartition(num_nodes, dims=(len(devices),), subparts=1)
        rows = part.padded_rows_per_shard
        if block_n is None:
            block_n = tk.choose_block_n(d, table.dtype)
        bn = min(block_n, rows)
        rows_p = -(-rows // bn) * bn
        padded = part.pad_table(table)
        shards, qshards, valid = [], [], []
        for s, dev in enumerate(devices):
            sh = padded[s * rows:(s + 1) * rows]
            if rows_p > rows:
                sh = np.concatenate(
                    [sh, np.zeros((rows_p - rows, d), sh.dtype)])
            shards.append(jax.device_put(sh, dev))
            valid.append(int(np.clip(num_nodes - s * rows, 0, rows)))
            if quant == "int8":
                q8, sc = qz.quantize_rows(sh)
                qshards.append((jax.device_put(q8, dev),
                                jax.device_put(sc, dev)))
        return cls(shards, part, valid, devices,
                   host_table=table if keep_host_table else None,
                   block_n=bn, step=step,
                   qshards=qshards if quant else None, quant=quant,
                   overfetch=overfetch)

    @classmethod
    def load(cls, path: str, *, table: str = "vertex",
             **kwargs) -> "ShardedEmbeddingStore":
        """Load one embedding table from a ``launch/train.py`` checkpoint
        (``save_checkpoint({"vertex": ..., "context": ...})`` layout)."""
        arrays, step = load_arrays(path)
        if table not in arrays:
            raise KeyError(f"checkpoint {path!r} has no table {table!r}; "
                           f"keys: {sorted(arrays)}")
        return cls.from_array(arrays[table], step=step, **kwargs)

    # ------------------------------------------------------------ querying
    @property
    def num_nodes(self) -> int:
        return self.part.num_nodes

    @property
    def dim(self) -> int:
        return self.shards[0].shape[1]

    def topk(self, queries, k: int, *, impl: str = "auto",
             overfetch: float | None = None):
        """Exact MIPS top-k over all shards.

        queries: (Q, d). Returns ((Q, k) f32 scores, (Q, k) i32 global node
        ids), k clamped to num_nodes. impl: "pallas" (the blocked DMA
        kernel; interpret mode off-TPU), "rowwise" (reference kernel),
        "xla" (plain jnp — the CPU serving path), "auto" (pallas on TPU,
        xla elsewhere), "quant" (the two-tier int8 scan + exact rescore —
        requires ``quant="int8"`` at load; kernel path on TPU, jnp path
        elsewhere, or force with "quant_pallas"/"quant_xla"). `overfetch`
        overrides the store's default tier-one margin for quant impls.
        """
        if impl not in QUERY_IMPLS:
            raise ValueError(f"unknown impl {impl!r}; one of {QUERY_IMPLS}")
        if impl == "auto":
            impl = "pallas" if _ON_TPU else "xla"
        elif impl == "quant":
            impl = "quant_pallas" if _ON_TPU else "quant_xla"
        if impl.startswith("quant") and self.qshards is None:
            raise RuntimeError("store has no quantized tier; build it with "
                               "quant='int8'")
        ov = self.overfetch if overfetch is None else overfetch
        k = min(k, self.num_nodes)
        q = jnp.asarray(np.asarray(queries, dtype=np.float32))
        rows = self.part.padded_rows_per_shard
        # dispatch every shard before syncing any: jax dispatch is async, so
        # P devices scan concurrently instead of one behind the other
        launched = []
        for s, shard in enumerate(self.shards):
            if self.valid[s] == 0:      # num_nodes < s * rows: nothing here
                continue
            if impl == "pallas":
                v, i = tk.topk_mips(shard, q, k=k, valid=self.valid[s],
                                    block_n=self.block_n,
                                    interpret=not _ON_TPU)
            elif impl == "rowwise":
                v, i = tk.topk_mips_rowwise(shard, q, k=k,
                                            valid=self.valid[s],
                                            interpret=not _ON_TPU)
            elif impl.startswith("quant"):
                q8, sc = self.qshards[s]
                v, i = qz.topk_mips_quant_rescored(
                    shard, q8, sc, q, k=k, overfetch=ov,
                    valid=self.valid[s], block_n=self.block_n,
                    impl="pallas" if impl == "quant_pallas" else "xla",
                    interpret=not _ON_TPU)
            else:
                v, i = tk.topk_mips_xla(shard, q, k=k, valid=self.valid[s])
            # shard-local → global node ids on the shard's own device
            # (elementwise, overlaps the other shards' scans), preserving
            # the sentinel of any sub-k shard so it keeps losing the merge
            gi = jnp.where(i == tk.IDX_SENTINEL, tk.IDX_SENTINEL,
                           i + s * rows)
            launched.append((v, gi))
        # one host sync for all shards, after everything is dispatched
        staged = jax.device_get(launched)
        per_v = [v for v, _ in staged]
        per_i = [i for _, i in staged]
        if len(per_v) == 1:
            return per_v[0], per_i[0]
        gv, gi = tk.merge_topk(jnp.asarray(np.stack(per_v)),
                               jnp.asarray(np.stack(per_i)), k=k)
        return np.asarray(gv), np.asarray(gi)

    def oracle_topk(self, queries, k: int):
        """Numpy ground truth over the full (unsharded) table."""
        if self.host_table is None:
            raise RuntimeError("store was built with keep_host_table=False; "
                               "the oracle needs the host copy")
        return kref.topk_mips_ref(self.host_table, queries,
                                  min(k, self.num_nodes))

    def score_ids(self, queries, ids) -> np.ndarray:
        """Ground-truth numpy f32 scores of specific (Q, k) candidate ids.

        This is what ``recall_at_k``'s tie tolerance should be fed — NOT a
        kernel's own reported values, which would let a broken kernel
        vouch for its own answers."""
        if self.host_table is None:
            raise RuntimeError("store was built with keep_host_table=False; "
                               "rescoring needs the host copy")
        q = np.asarray(queries, dtype=np.float32)
        rows = self.host_table.astype(np.float32)[np.asarray(ids)]  # (Q,k,d)
        return np.einsum("qd,qkd->qk", q, rows)


def recall_at_k(got_ids, oracle_ids, *, got_vals=None, oracle_vals=None,
                rtol: float = 1e-6) -> float:
    """Mean |top-k ∩ oracle top-k| / k over queries.

    With scores supplied, an id outside the oracle's list still counts if
    its score reaches the oracle's k-th score within rtol: the kernels
    (XLA/MXU accumulation) and the numpy oracle (BLAS) are not bitwise-
    identical on continuous data, so an exact tie at the rank-k boundary
    can ulp-flip between the two — and any row scoring at the boundary is
    a legitimate top-k member. ``got_vals`` must be GROUND-TRUTH scores of
    the returned ids (``ShardedEmbeddingStore.score_ids``), not the
    kernel's own claims. Duplicate returned ids count once — a kernel that
    repeats rank-1 k times scores 1/k here, not 1.0. Real retrieval bugs
    surface as scores well below the boundary and still count as misses.
    The single recall definition shared by the CLI gate and bench_serve,
    so the two can't drift."""
    got_ids = np.asarray(got_ids)
    oracle_ids = np.asarray(oracle_ids)
    hits = 0
    for qi in range(oracle_ids.shape[0]):
        o = set(oracle_ids[qi].tolist())
        seen = set()
        for j, g in enumerate(got_ids[qi].tolist()):
            if g in seen:                # duplicates can't double-count
                continue
            seen.add(g)
            if g in o:
                hits += 1
            elif got_vals is not None and oracle_vals is not None:
                kth = float(oracle_vals[qi][-1])
                if float(got_vals[qi][j]) >= kth - rtol * max(1.0, abs(kth)):
                    hits += 1
    return hits / oracle_ids.size
