"""Device-sharded embedding store: trained tables → servable shards.

The serving twin of the training layout: tables are row-partitioned with
the same ``NodePartition`` block rule the trainer uses (node n → shard
n // rows, local row n % rows), one shard per device, so a checkpoint
written by ``launch/train.py`` loads without any re-indexing — shard s of
the store holds exactly the rows device s held during training (subparts=1:
serving has no rotation, so the sub-part split is irrelevant here).

Queries fan out to every shard (each runs the Pallas top-k kernel over its
resident rows — the GraphVite-style shard-local lookup), and the per-shard
(k) lists meet in ``topk.merge_topk``. Tables keep their checkpoint dtype
(bf16 by default, honoring ``HybridConfig.dtype``) and are loaded bitwise;
``normalize=True`` rescales rows to unit norm at load so the same MIPS
kernel serves cosine retrieval.

``quant="int8"`` additionally builds a symmetric per-row int8 copy of every
shard (``embed_serve.quant``), enabling the two-tier scan
(``impl="quant"``): int8 first pass at 4x less scan traffic, exact rescore
of the over-fetched survivors, same cross-shard merge.

``enable_hot_tier(budget, counts=...)`` physically splits each shard into
an exact hot tier (the budget's hottest rows by observed access counts —
hub nodes under power-law traffic) and a compacted int8 cold remainder.
``impl="tiered"`` then scans the hot tier exactly (hits return exact
rows, so hub results never pay quantization error) and runs the quant
scan + exact rescore over only the cold rows; both per-shard lists merge
under the one smaller-index tie rule. Hot/returned-from-hot counters feed
``repro.obs`` and ``hot_tier_stats()`` for the bench's hit-rate ×
scan-bytes model.

Degraded mode: ``topk(shard_timeout_s=...)`` runs each shard's scan as its
own task; shards that miss the deadline are excluded from the merge and the
response is tagged degraded (``return_meta=True`` → :class:`TopKMeta` with
the failed shard list), so one slow or dead device degrades recall over its
rows instead of stalling every query — the answer over surviving shards is
still exact (``oracle_topk(exclude_shards=...)`` is the test oracle).
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import wait as _fut_wait

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import NodePartition
from repro.embed_serve import quant as qz
from repro.embed_serve import topk as tk
from repro.kernels import ref as kref
from repro.obs import counter_add, gauge_set
from repro.runtime import fault_point
from repro.train.checkpoint import load_arrays

_ON_TPU = jax.default_backend() == "tpu"

QUERY_IMPLS = ("auto", "pallas", "rowwise", "xla",
               "quant", "quant_pallas", "quant_xla", "tiered")
QUANT_TIERS = (None, "int8")

_UNSET = object()   # "use the store's shard_timeout_s" vs an explicit None


@dataclasses.dataclass(frozen=True)
class TopKMeta:
    """Per-query-batch serving outcome (``topk(return_meta=True)``)."""

    degraded: bool = False
    failed_shards: tuple = ()
    timeout_s: float | None = None


@dataclasses.dataclass(frozen=True)
class _HotShard:
    """One shard's hot/cold physical split (``enable_hot_tier``).

    hot_shard holds the shard's hot rows exactly (served dtype), cold_*
    the compacted remainder: exact rows (the rescore source), the int8
    scan copy, and the compact-row → global-id maps. Row counts are
    padded to scan-tile multiples; *_valid mask the padding out.
    """

    hot_shard: object
    hot_map: object
    hot_valid: int
    cold_shard: object
    cold_q8: object
    cold_sc: object
    cold_map: object
    cold_valid: int


class ShardedEmbeddingStore:
    """Row-sharded embedding table + exact top-k retrieval over it."""

    def __init__(self, shards, part: NodePartition, valid, devices, *,
                 host_table, block_n: int, step: int = -1,
                 qshards=None, quant=None,
                 overfetch: float = qz.DEFAULT_OVERFETCH,
                 shard_timeout_s: float | None = None):
        self.shards = shards                  # per-device (rows_p, d) arrays
        self.part = part
        self.valid = tuple(valid)             # real rows per shard
        self.devices = tuple(devices)
        self.host_table = host_table          # (num_nodes, d) as served,
        self.block_n = block_n                # or None (keep_host_table off)
        self.step = step
        self.qshards = qshards                # per-device (int8, scales) or
        self.quant = quant                    # None (no quantized tier)
        self.overfetch = overfetch            # default tier-one margin
        self.shard_timeout_s = shard_timeout_s  # None = never degrade
        self._pool = None                     # lazy shard-scan executor
        self._pool_mu = threading.Lock()
        self.hot_tiers = None                 # per-shard _HotShard or None
        self.hot_budget = 0
        self._hot_mask = None                 # (num_nodes,) bool, host
        self._tiered_bn = block_n             # hot-aware cold-scan tile
        self._hot_stats = {"queries": 0, "returned": 0, "returned_hot": 0}

    # ------------------------------------------------------------- loading
    @classmethod
    def from_array(cls, table, *, devices=None, dtype=None,
                   block_n: int | None = None, normalize: bool = False,
                   keep_host_table: bool = True, quant: str | None = None,
                   overfetch: float = qz.DEFAULT_OVERFETCH,
                   shard_timeout_s: float | None = None,
                   step: int = -1) -> "ShardedEmbeddingStore":
        """Shard an in-memory (num_nodes, d) table across `devices`.

        dtype=None keeps the array's dtype (the checkpoint's, i.e. the
        training ``HybridConfig.dtype``). Shard rows are padded to a
        block_n multiple once, here, so serving never re-materializes the
        table; padded rows are masked out of every query by ``valid``.
        block_n=None sizes the scan tile with ``topk.choose_block_n``
        against the VMEM budget (k not known yet — planned at the
        ``DEFAULT_PLAN_K`` candidate allowance).
        keep_host_table=False drops the host copy after sharding (serving
        itself never reads it — it only backs ``oracle_topk`` and query
        sampling; at production table sizes it would double the footprint).
        quant="int8" builds the two-tier scan's per-shard int8 copies
        (``quant.quantize_rows`` of the served — post-normalize — rows,
        same row order and padding as the exact shards); `overfetch` is
        the default tier-one margin ``topk(impl="quant")`` uses.
        shard_timeout_s is the default per-shard scan deadline for
        degraded-mode queries (None = wait forever). `devices` may repeat
        a device (e.g. ``[cpu]*4``) to get a multi-shard layout on fewer
        physical devices — how the degraded-serving tests and CI leg run.
        """
        devices = list(devices) if devices is not None else jax.devices()
        if quant not in QUANT_TIERS:
            raise ValueError(f"unknown quant tier {quant!r}; "
                             f"one of {QUANT_TIERS}")
        table = np.asarray(table)
        if dtype is not None and np.dtype(jnp.dtype(dtype)) != table.dtype:
            table = np.asarray(jnp.asarray(table).astype(jnp.dtype(dtype)))
        if normalize:                         # cosine via the MIPS kernel
            f32 = table.astype(np.float32)
            f32 /= np.linalg.norm(f32, axis=1, keepdims=True) + 1e-12
            table = np.asarray(jnp.asarray(f32).astype(table.dtype))
        num_nodes, d = table.shape
        part = NodePartition(num_nodes, dims=(len(devices),), subparts=1)
        rows = part.padded_rows_per_shard
        if block_n is None:
            block_n = tk.choose_block_n(d, table.dtype)
        bn = min(block_n, rows)
        rows_p = -(-rows // bn) * bn
        padded = part.pad_table(table)
        shards, qshards, valid = [], [], []
        for s, dev in enumerate(devices):
            sh = padded[s * rows:(s + 1) * rows]
            if rows_p > rows:
                sh = np.concatenate(
                    [sh, np.zeros((rows_p - rows, d), sh.dtype)])
            shards.append(jax.device_put(sh, dev))
            valid.append(int(np.clip(num_nodes - s * rows, 0, rows)))
            if quant == "int8":
                q8, sc = qz.quantize_rows(sh)
                qshards.append((jax.device_put(q8, dev),
                                jax.device_put(sc, dev)))
        return cls(shards, part, valid, devices,
                   host_table=table if keep_host_table else None,
                   block_n=bn, step=step,
                   qshards=qshards if quant else None, quant=quant,
                   overfetch=overfetch, shard_timeout_s=shard_timeout_s)

    @classmethod
    def load(cls, path: str, *, table: str = "vertex",
             **kwargs) -> "ShardedEmbeddingStore":
        """Load one embedding table from a ``launch/train.py`` checkpoint
        (``save_checkpoint({"vertex": ..., "context": ...})`` layout)."""
        arrays, step = load_arrays(path)
        if table not in arrays:
            raise KeyError(f"checkpoint {path!r} has no table {table!r}; "
                           f"keys: {sorted(arrays)}")
        return cls.from_array(arrays[table], step=step, **kwargs)

    # ------------------------------------------------------------ querying
    @property
    def num_nodes(self) -> int:
        return self.part.num_nodes

    @property
    def dim(self) -> int:
        return self.shards[0].shape[1]

    def _dispatch_shard(self, s: int, q, k: int, impl: str, ov: float):
        """Dispatch shard s's scan (async) → (scores, GLOBAL ids) device
        arrays. Sub-k shards keep the IDX_SENTINEL so they lose the merge."""
        if impl == "tiered":
            # hot/cold split scans carry their own global-id maps
            return self._dispatch_shard_tiered(s, q, k, ov)
        shard = self.shards[s]
        if impl == "pallas":
            v, i = tk.topk_mips(shard, q, k=k, valid=self.valid[s],
                                block_n=self.block_n,
                                interpret=not _ON_TPU)
        elif impl == "rowwise":
            v, i = tk.topk_mips_rowwise(shard, q, k=k,
                                        valid=self.valid[s],
                                        interpret=not _ON_TPU)
        elif impl.startswith("quant"):
            q8, sc = self.qshards[s]
            v, i = qz.topk_mips_quant_rescored(
                shard, q8, sc, q, k=k, overfetch=ov,
                valid=self.valid[s], block_n=self.block_n,
                impl="pallas" if impl == "quant_pallas" else "xla",
                interpret=not _ON_TPU)
        else:
            v, i = tk.topk_mips_xla(shard, q, k=k, valid=self.valid[s])
        # shard-local → global node ids on the shard's own device
        # (elementwise, overlaps the other shards' scans)
        rows = self.part.padded_rows_per_shard
        gi = jnp.where(i == tk.IDX_SENTINEL, tk.IDX_SENTINEL, i + s * rows)
        return v, gi

    # ------------------------------------------------------------ hot tier
    def enable_hot_tier(self, budget: int, *, ids=None, counts=None) -> int:
        """Split every shard into an exact hot tier + compacted int8 cold
        remainder for ``impl="tiered"`` queries.

        The hot set is the ``budget`` hottest rows by ``counts`` (observed
        access counts — training-episode traffic, degrees, or a query log;
        ties break toward the smaller id so the split is deterministic), or
        an explicit ``ids`` list. Hot hits are scanned exactly in the
        served dtype; cold rows get a fresh compacted int8 copy (genuinely
        fewer cold-scan bytes than the full quant tier — the byte model
        in bench_serve measures exactly this). The cold-scan tile is
        re-chosen with the hot tile's VMEM footprint accounted
        (``topk.choose_block_n(hot_rows=...)``). Returns the realized hot
        row count.
        """
        if ids is None:
            if counts is None:
                raise ValueError("enable_hot_tier needs ids or counts")
            counts = np.asarray(counts, np.float64)
            if counts.shape != (self.num_nodes,):
                raise ValueError(f"counts shape {counts.shape} != "
                                 f"({self.num_nodes},)")
            order = np.lexsort((np.arange(self.num_nodes), -counts))
            order = order[counts[order] > 0]
            ids = np.sort(order[: budget])
        else:
            ids = np.unique(np.asarray(ids, np.int64))
            ids = ids[(ids >= 0) & (ids < self.num_nodes)][: budget]
        mask = np.zeros(self.num_nodes, bool)
        mask[ids] = True
        rows = self.part.padded_rows_per_shard
        bn = self.block_n
        tiers = []
        for s, dev in enumerate(self.devices):
            n_valid = self.valid[s]
            sh = np.asarray(self.shards[s])       # padded (rows_p, d) host
            d = sh.shape[1]
            loc_mask = np.zeros(sh.shape[0], bool)
            loc_mask[:n_valid] = mask[s * rows: s * rows + n_valid]
            hot_loc = np.flatnonzero(loc_mask)
            cold_loc = np.flatnonzero(~loc_mask[:n_valid])

            def _compact(loc):
                n = loc.size
                n_p = max(bn, -(-max(n, 1) // bn) * bn)
                tbl = np.zeros((n_p, d), sh.dtype)
                tbl[:n] = sh[loc]
                gmap = np.zeros(n_p, np.int32)
                gmap[:n] = (s * rows + loc).astype(np.int32)
                return tbl, gmap, n

            hot_tbl, hot_map, n_hot = _compact(hot_loc)
            cold_tbl, cold_map, n_cold = _compact(cold_loc)
            q8, sc = qz.quantize_rows(cold_tbl)
            tiers.append(_HotShard(
                hot_shard=jax.device_put(hot_tbl, dev),
                hot_map=jax.device_put(jnp.asarray(hot_map), dev),
                hot_valid=n_hot,
                cold_shard=jax.device_put(cold_tbl, dev),
                cold_q8=jax.device_put(q8, dev),
                cold_sc=jax.device_put(sc, dev),
                cold_map=jax.device_put(jnp.asarray(cold_map), dev),
                cold_valid=n_cold))
        self.hot_tiers = tiers
        self.hot_budget = int(ids.size)
        self._hot_mask = mask
        self._tiered_bn = min(bn, tk.choose_block_n(
            self.dim, np.int8, hot_rows=int(ids.size)))
        self._hot_stats = {"queries": 0, "returned": 0, "returned_hot": 0}
        gauge_set("serve.hot_tier.rows", int(ids.size))
        return int(ids.size)

    def hot_tier_stats(self) -> dict:
        """Serving-side cache telemetry: realized hot rows, the fraction of
        returned results served from the exact tier, and the modeled scan
        bytes per query of the tiered vs full-quant layouts."""
        st = dict(self._hot_stats)
        d = self.dim
        item = np.dtype(self.shards[0].dtype).itemsize
        n_cold = sum(t.cold_valid for t in (self.hot_tiers or []))
        n_hot = sum(t.hot_valid for t in (self.hot_tiers or []))
        return {
            **st,
            "hot_rows": n_hot,
            "cold_rows": n_cold,
            "returned_hot_frac": st["returned_hot"] / max(st["returned"], 1),
            # per-query scan bytes: exact hot rows + int8 cold (value + f32
            # scale) vs the untiered int8 scan of every row
            "scan_bytes_tiered": n_hot * d * item + n_cold * (d + 4),
            "scan_bytes_quant": (n_hot + n_cold) * (d + 4),
        }

    def _pad_k(self, v, i, k: int):
        pad = k - v.shape[1]
        if pad <= 0:
            return v, i
        return (jnp.pad(v, ((0, 0), (0, pad)), constant_values=tk.NEG_INF),
                jnp.pad(i, ((0, 0), (0, pad)),
                        constant_values=tk.IDX_SENTINEL))

    def _dispatch_shard_tiered(self, s: int, q, k: int, ov: float):
        """Shard s under the two-physical-tier layout: exact hot scan +
        quant-with-rescore cold scan, merged under the global tie rule.
        Compact → global maps live on the device, so like the plain path
        nothing syncs until the caller's device_get."""
        ht = self.hot_tiers[s]
        outs = []
        if ht.hot_valid > 0:
            kh = min(k, ht.hot_shard.shape[0])
            if _ON_TPU:
                hv, hi = tk.topk_mips(
                    ht.hot_shard, q, k=kh, valid=ht.hot_valid,
                    block_n=min(self._tiered_bn, ht.hot_shard.shape[0]))
            else:
                hv, hi = tk.topk_mips_xla(ht.hot_shard, q, k=kh,
                                          valid=ht.hot_valid)
            hg = jnp.where(
                hi == tk.IDX_SENTINEL, tk.IDX_SENTINEL,
                jnp.take(ht.hot_map,
                         jnp.minimum(hi, ht.hot_map.shape[0] - 1)))
            outs.append(self._pad_k(hv, hg, k))
        if ht.cold_valid > 0:
            kc = min(k, ht.cold_shard.shape[0])
            cv, ci = qz.topk_mips_quant_rescored(
                ht.cold_shard, ht.cold_q8, ht.cold_sc, q, k=kc,
                overfetch=ov, valid=ht.cold_valid,
                block_n=min(self._tiered_bn, ht.cold_shard.shape[0]),
                impl="pallas" if _ON_TPU else "xla",
                interpret=not _ON_TPU)
            cg = jnp.where(
                ci == tk.IDX_SENTINEL, tk.IDX_SENTINEL,
                jnp.take(ht.cold_map,
                         jnp.minimum(ci, ht.cold_map.shape[0] - 1)))
            outs.append(self._pad_k(cv, cg, k))
        if not outs:
            raise RuntimeError(f"shard {s} has no valid rows")
        if len(outs) == 1:
            return outs[0]
        return tk.merge_topk(jnp.stack([v for v, _ in outs]),
                             jnp.stack([i for _, i in outs]), k=k)

    def _note_tiered_result(self, gi) -> None:
        gi = np.asarray(gi)
        real = gi[gi != tk.IDX_SENTINEL]
        n_hot = int(self._hot_mask[real].sum())
        self._hot_stats["queries"] += int(gi.shape[0])
        self._hot_stats["returned"] += int(real.size)
        self._hot_stats["returned_hot"] += n_hot
        counter_add("serve.hot_tier.hits", n_hot)
        counter_add("serve.hot_tier.misses", int(real.size) - n_hot)

    def _merge(self, per_v, per_i, k: int):
        if len(per_v) == 1:
            return per_v[0], per_i[0]
        gv, gi = tk.merge_topk(jnp.asarray(np.stack(per_v)),
                               jnp.asarray(np.stack(per_i)), k=k)
        return np.asarray(gv), np.asarray(gi)

    def _resolve_impl(self, impl: str) -> str:
        if impl not in QUERY_IMPLS:
            raise ValueError(f"unknown impl {impl!r}; one of {QUERY_IMPLS}")
        if impl == "auto":
            impl = "pallas" if _ON_TPU else "xla"
        elif impl == "quant":
            impl = "quant_pallas" if _ON_TPU else "quant_xla"
        if impl.startswith("quant") and self.qshards is None:
            raise RuntimeError("store has no quantized tier; build it with "
                               "quant='int8'")
        if impl == "tiered" and self.hot_tiers is None:
            raise RuntimeError("store has no hot tier; call "
                               "enable_hot_tier(budget, counts=...) first")
        return impl

    def _scan_pool(self) -> ThreadPoolExecutor:
        with self._pool_mu:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=max(1, len(self.shards)),
                    thread_name_prefix="shard-scan")
            return self._pool

    def topk(self, queries, k: int, *, impl: str = "auto",
             overfetch: float | None = None,
             shard_timeout_s=_UNSET,
             return_meta: bool = False):
        """Exact MIPS top-k over all shards.

        queries: (Q, d). Returns ((Q, k) f32 scores, (Q, k) i32 global node
        ids), k clamped to num_nodes. impl: "pallas" (the blocked DMA
        kernel; interpret mode off-TPU), "rowwise" (reference kernel),
        "xla" (plain jnp — the CPU serving path), "auto" (pallas on TPU,
        xla elsewhere), "quant" (the two-tier int8 scan + exact rescore —
        requires ``quant="int8"`` at load; kernel path on TPU, jnp path
        elsewhere, or force with "quant_pallas"/"quant_xla"). `overfetch`
        overrides the store's default tier-one margin for quant impls.

        shard_timeout_s (unset: the store's ``shard_timeout_s``; an
        explicit None = wait forever, e.g. for compile warmup) runs each
        shard's scan as its own task and merges
        only the shards that answered in time — exact over the survivors,
        degraded over the failed shards' rows. All shards failing raises.
        return_meta=True appends a :class:`TopKMeta` (degraded flag +
        failed shard list) to the return tuple.
        """
        impl = self._resolve_impl(impl)
        ov = self.overfetch if overfetch is None else overfetch
        k = min(k, self.num_nodes)
        q = jnp.asarray(np.asarray(queries, dtype=np.float32))
        timeout = (self.shard_timeout_s if shard_timeout_s is _UNSET
                   else shard_timeout_s)
        live = [s for s in range(len(self.shards)) if self.valid[s] > 0]

        if timeout is None:
            # fast path (unchanged from the always-healthy store): dispatch
            # every shard before syncing any — jax dispatch is async, so P
            # devices scan concurrently instead of one behind the other
            launched = [self._dispatch_shard(s, q, k, impl, ov)
                        for s in live]
            staged = jax.device_get(launched)
            gv, gi = self._merge([v for v, _ in staged],
                                 [i for _, i in staged], k)
            if impl == "tiered":
                self._note_tiered_result(gi)
            return (gv, gi, TopKMeta()) if return_meta else (gv, gi)

        def scan(s):
            fault_point("serve.shard", (s,))
            return jax.device_get(self._dispatch_shard(s, q, k, impl, ov))

        pool = self._scan_pool()
        futs = {s: pool.submit(scan, s) for s in live}
        # wait for ALL to complete (a crashed shard completes immediately
        # with its exception; healthy shards keep their full deadline)
        _fut_wait(list(futs.values()), timeout=timeout)
        per_v, per_i, failed = [], [], []
        for s, f in futs.items():
            if f.done() and f.exception() is None:
                v, i = f.result()
                per_v.append(v)
                per_i.append(i)
            else:
                # timed out (result, if it ever lands, is discarded) or
                # crashed — either way the shard is out of this answer
                failed.append(s)
        if not per_v:
            raise RuntimeError(
                f"all {len(live)} shard scans failed or timed out "
                f"({timeout}s); shards: {failed}")
        gv, gi = self._merge(per_v, per_i, k)
        if impl == "tiered":
            self._note_tiered_result(gi)
        if return_meta:
            return gv, gi, TopKMeta(degraded=bool(failed),
                                    failed_shards=tuple(sorted(failed)),
                                    timeout_s=timeout)
        return gv, gi

    def oracle_topk(self, queries, k: int, *, exclude_shards=()):
        """Numpy ground truth over the full (unsharded) table.

        ``exclude_shards`` drops those shards' rows first — the surviving-
        shards oracle a degraded response must match exactly. The id remap
        is monotone, so the kernel's smaller-index tie rule is preserved."""
        if self.host_table is None:
            raise RuntimeError("store was built with keep_host_table=False; "
                               "the oracle needs the host copy")
        if not exclude_shards:
            return kref.topk_mips_ref(self.host_table, queries,
                                      min(k, self.num_nodes))
        rows = self.part.padded_rows_per_shard
        keep = np.ones(self.num_nodes, dtype=bool)
        for s in exclude_shards:
            keep[s * rows: min((s + 1) * rows, self.num_nodes)] = False
        idx = np.nonzero(keep)[0]
        if idx.size == 0:
            raise ValueError("exclude_shards leaves no rows to rank")
        v, i = kref.topk_mips_ref(self.host_table[idx], queries,
                                  min(k, idx.size))
        return v, idx[np.asarray(i)].astype(np.asarray(i).dtype)

    def score_ids(self, queries, ids) -> np.ndarray:
        """Ground-truth numpy f32 scores of specific (Q, k) candidate ids.

        This is what ``recall_at_k``'s tie tolerance should be fed — NOT a
        kernel's own reported values, which would let a broken kernel
        vouch for its own answers."""
        if self.host_table is None:
            raise RuntimeError("store was built with keep_host_table=False; "
                               "rescoring needs the host copy")
        q = np.asarray(queries, dtype=np.float32)
        rows = self.host_table.astype(np.float32)[np.asarray(ids)]  # (Q,k,d)
        return np.einsum("qd,qkd->qk", q, rows)


def recall_at_k(got_ids, oracle_ids, *, got_vals=None, oracle_vals=None,
                rtol: float = 1e-6) -> float:
    """Mean |top-k ∩ oracle top-k| / k over queries.

    With scores supplied, an id outside the oracle's list still counts if
    its score reaches the oracle's k-th score within rtol: the kernels
    (XLA/MXU accumulation) and the numpy oracle (BLAS) are not bitwise-
    identical on continuous data, so an exact tie at the rank-k boundary
    can ulp-flip between the two — and any row scoring at the boundary is
    a legitimate top-k member. ``got_vals`` must be GROUND-TRUTH scores of
    the returned ids (``ShardedEmbeddingStore.score_ids``), not the
    kernel's own claims. Duplicate returned ids count once — a kernel that
    repeats rank-1 k times scores 1/k here, not 1.0. Real retrieval bugs
    surface as scores well below the boundary and still count as misses.
    The single recall definition shared by the CLI gate and bench_serve,
    so the two can't drift."""
    got_ids = np.asarray(got_ids)
    oracle_ids = np.asarray(oracle_ids)
    hits = 0
    for qi in range(oracle_ids.shape[0]):
        o = set(oracle_ids[qi].tolist())
        seen = set()
        for j, g in enumerate(got_ids[qi].tolist()):
            if g in seen:                # duplicates can't double-count
                continue
            seen.add(g)
            if g in o:
                hits += 1
            elif got_vals is not None and oracle_vals is not None:
                kth = float(oracle_vals[qi][-1])
                if float(got_vals[qi][j]) >= kth - rtol * max(1.0, abs(kth)):
                    hits += 1
    return hits / oracle_ids.size
