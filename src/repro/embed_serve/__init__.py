"""Sharded embedding retrieval serving (the paper's downstream consumer).

Training produces billion-row embedding tables so recommendation can ask
"nearest neighbors of this user/item" — this package serves that query:
``ShardedEmbeddingStore`` loads a training checkpoint into the same
``NodePartition`` row layout training used (one shard per device),
``topk`` scans shards with a Pallas blocked MIPS kernel and merges the
per-shard lists, and ``MicroBatcher`` coalesces single-query traffic into
kernel-sized batches. ``launch/embed_serve.py`` is the CLI."""
from repro.embed_serve.batcher import (BatcherStats, MicroBatcher,
                                       drive_open_loop)
from repro.embed_serve.store import ShardedEmbeddingStore, recall_at_k
from repro.embed_serve.topk import (merge_topk, select_topk, topk_mips,
                                    topk_mips_rowwise, topk_mips_xla)

__all__ = [
    "BatcherStats", "MicroBatcher", "ShardedEmbeddingStore",
    "drive_open_loop", "merge_topk", "recall_at_k", "select_topk",
    "topk_mips", "topk_mips_rowwise", "topk_mips_xla",
]
