"""Sharded embedding retrieval serving (the paper's downstream consumer).

Training produces billion-row embedding tables so recommendation can ask
"nearest neighbors of this user/item" — this package serves that query:
``ShardedEmbeddingStore`` loads a training checkpoint into the same
``NodePartition`` row layout training used (one shard per device),
``topk`` scans shards with a Pallas blocked MIPS kernel and merges the
per-shard lists (optionally through the two-tier ``quant`` scan: int8
first pass at 4x less traffic, exact rescore of the survivors), and
``MicroBatcher`` coalesces single-query traffic into kernel-sized
batches. ``launch/embed_serve.py`` is the CLI."""
from repro.embed_serve.batcher import (BatcherStats, MicroBatcher,
                                       drive_open_loop)
from repro.embed_serve.quant import (DEFAULT_OVERFETCH, dequantize_rows,
                                     overfetch_m, quantize_rows,
                                     rescore_exact,
                                     topk_mips_quant_rescored)
from repro.embed_serve.store import (ShardedEmbeddingStore, TopKMeta,
                                     recall_at_k)
from repro.embed_serve.topk import (choose_block_n, merge_topk, select_topk,
                                    topk_mips, topk_mips_quant,
                                    topk_mips_quant_xla, topk_mips_rowwise,
                                    topk_mips_xla, topk_scan_vmem_bytes)

__all__ = [
    "BatcherStats", "DEFAULT_OVERFETCH", "MicroBatcher",
    "ShardedEmbeddingStore", "choose_block_n", "dequantize_rows",
    "drive_open_loop", "merge_topk", "overfetch_m", "quantize_rows",
    "TopKMeta", "recall_at_k", "rescore_exact", "select_topk", "topk_mips",
    "topk_mips_quant", "topk_mips_quant_rescored", "topk_mips_quant_xla",
    "topk_mips_rowwise", "topk_mips_xla", "topk_scan_vmem_bytes",
]
