"""Pallas blocked MIPS top-k kernels for sharded embedding retrieval.

The serving hot loop is the training hot loop run backwards: instead of
gathering a minibatch of rows by index, a query batch scans every table row
once — a (Q, d) x (d, N) matmul that is O(1) arithmetic intensity per table
byte, so (like training) the kernel's job is to touch each HBM row exactly
once and keep the MXU fed while the next tile's DMA is in flight.

Kernels (all validated against :func:`repro.kernels.ref.topk_mips_ref`):

  * :func:`topk_mips`          — the production kernel: the table stays in
    HBM; (bn, d) row tiles are double-buffered into VMEM by explicit DMA
    (tile t+1's copy flies while tile t is scored on the MXU), and each
    query block folds every tile into a running (bq, k) top-k held in the
    revisited output block. One HBM read per table row per query block.
  * :func:`topk_mips_rowwise`  — one table row per grid step through a
    BlockSpec-pipelined (1, d) block; the interpret-mode reference, in the
    spirit of ``kernels.sgns.gather_rows_rowwise``.
  * :func:`topk_mips_xla`      — plain-jnp scores + the same selection
    network; the CPU/XLA serving path and the shard-level oracle.
  * :func:`merge_topk`         — the small jitted cross-shard reduce: P
    per-shard (Q, k) results (global ids) → the global (Q, k).

Exactness: scores are f32 (tables cast up before the dot, like the SGNS
kernels), selection is exact MIPS with ties broken toward the smaller row
index — the same total order as the numpy oracle's stable argsort.
Sentinels: invalid positions (padded table rows, masked candidates) carry
(-inf, int32 max), so they lose every comparison and a shard with fewer
than k valid rows degrades gracefully in the cross-shard merge.

Interpret mode on CPU; TPU is the compilation target (lane-alignment
follow-ons for the (bq, k) outputs are in the ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")
IDX_SENTINEL = jnp.iinfo(jnp.int32).max
DEFAULT_BLOCK_Q = 128   # query rows per resident block (topk_mips default);
                        # the table is re-scanned once per query block


def select_topk(vals: jax.Array, idx: jax.Array, k: int):
    """Exact top-k over (Q, M) candidate (value, index) pairs.

    k unrolled VPU-shaped passes: each selects the row-wise max value, and
    among equal values the smallest index, then masks the taken slot to the
    (-inf, sentinel) pair. Shared by the kernels' per-tile merge (M = k +
    tile rows) and the cross-shard reduce (M = shards * k) so the tie rule
    cannot diverge between the two levels.

    Returns ((Q, k) f32, (Q, k) i32).
    """
    vals = vals.astype(jnp.float32)
    idx = idx.astype(jnp.int32)
    out_v, out_i = [], []
    for _ in range(k):
        v = jnp.max(vals, axis=1)
        is_max = vals == v[:, None]
        i = jnp.min(jnp.where(is_max, idx, IDX_SENTINEL), axis=1)
        taken = is_max & (idx == i[:, None])
        vals = jnp.where(taken, NEG_INF, vals)
        idx = jnp.where(taken, IDX_SENTINEL, idx)
        out_v.append(v)
        out_i.append(i)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _scored_tile(q_f32, tile, tile_start: jax.Array, valid: int):
    """(bq, bn) f32 scores + global-index matrix for one table tile, with
    padded rows (global index >= valid) already demoted to sentinels."""
    f32 = jnp.float32
    scores = jax.lax.dot_general(q_f32, tile.astype(f32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
    gidx = tile_start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    invalid = gidx >= valid
    return (jnp.where(invalid, NEG_INF, scores),
            jnp.where(invalid, IDX_SENTINEL, gidx))


def _merge_into(out_v_ref, out_i_ref, scores, gidx, k: int):
    """Fold a scored tile into the running top-k held in the output refs."""
    cand_v = jnp.concatenate([out_v_ref[...], scores], axis=1)
    cand_i = jnp.concatenate([out_i_ref[...], gidx], axis=1)
    nv, ni = select_topk(cand_v, cand_i, k)
    out_v_ref[...] = nv
    out_i_ref[...] = ni


# --------------------------------------------------------------------------
# production kernel: HBM-resident table, double-buffered (bn, d) tile DMA
# --------------------------------------------------------------------------
def _topk_kernel(tbl_hbm, q_ref, out_v_ref, out_i_ref, tile_s, sem, *,
                 k: int, bn: int, valid: int):
    t = pl.program_id(1)
    T = pl.num_programs(1)

    def tile_copy(tt, op):
        """start/wait tile tt's contiguous-row DMA on buffer slot tt % 2."""
        getattr(pltpu.make_async_copy(
            tbl_hbm.at[pl.ds(tt * bn, bn)],
            tile_s.at[pl.ds((tt % 2) * bn, bn)],
            sem.at[tt % 2]), op)()

    @pl.when(t == 0)
    def _prologue():           # new query block: restart the tile pipeline
        tile_copy(0, "start")
        out_v_ref[...] = jnp.full_like(out_v_ref, NEG_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, IDX_SENTINEL)

    @pl.when(t + 1 < T)
    def _prefetch_next():      # double buffering: next tile's DMA flies
        tile_copy(t + 1, "start")   # while this tile is scored on the MXU

    tile_copy(t, "wait")

    tile = tile_s[pl.ds((t % 2) * bn, bn), :]
    scores, gidx = _scored_tile(q_ref[...].astype(jnp.float32), tile,
                                t * bn, valid)
    _merge_into(out_v_ref, out_i_ref, scores, gidx, k)


@functools.partial(jax.jit, static_argnames=("k", "valid", "block_q",
                                             "block_n", "interpret"))
def topk_mips(table, queries, *, k: int, valid: int | None = None,
              block_q: int = DEFAULT_BLOCK_Q, block_n: int = 256,
              interpret: bool = False):
    """Exact-MIPS top-k of `queries` against one table shard.

    table: (N, d) HBM-resident shard (bf16 or f32 — scored in f32);
    queries: (Q, d). `valid` masks padded tail rows (row >= valid scores
    -inf and can never be returned); rows are padded here to a block_n
    multiple if the caller didn't (the store pre-pads at load so serving
    never re-materializes the table).

    Returns ((Q, k) f32 scores, (Q, k) i32 shard-local row ids), both
    sorted by the oracle's total order (descending score, ascending index
    on ties). If valid < k the tail entries are (-inf, int32 max).
    """
    N, d = table.shape
    Q = queries.shape[0]
    valid = N if valid is None else valid
    assert 0 < valid <= N, (valid, N)
    bn = min(block_n, N)
    if N % bn:
        table = jnp.pad(table, ((0, (-N) % bn), (0, 0)))
        N = table.shape[0]
    bq = min(block_q, Q)
    Qp = -(-Q // bq) * bq
    qp = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    grid = (Qp // bq, N // bn)        # table tiles innermost (sequential)
    out_v, out_i = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, bn=bn, valid=valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),           # table (HBM)
            pl.BlockSpec((bq, d), lambda qi, t: (qi, 0)),   # query block
        ],
        out_specs=(
            pl.BlockSpec((bq, k), lambda qi, t: (qi, 0)),   # running top-k
            pl.BlockSpec((bq, k), lambda qi, t: (qi, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((2 * bn, d), table.dtype),           # tile slots
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(table, qp)
    return out_v[:Q], out_i[:Q]


# --------------------------------------------------------------------------
# rowwise reference: one table row per grid step, BlockSpec-pipelined
# --------------------------------------------------------------------------
def _topk_rowwise_kernel(row_ref, q_ref, out_v_ref, out_i_ref, *, k: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_v_ref[...] = jnp.full_like(out_v_ref, NEG_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, IDX_SENTINEL)

    f32 = jnp.float32
    score = jax.lax.dot_general(q_ref[...].astype(f32),
                                row_ref[...].astype(f32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)   # (Q, 1)
    gidx = jnp.full_like(score, t, dtype=jnp.int32)
    _merge_into(out_v_ref, out_i_ref, score, gidx, k)


@functools.partial(jax.jit, static_argnames=("k", "valid", "interpret"))
def topk_mips_rowwise(table, queries, *, k: int, valid: int | None = None,
                      interpret: bool = False):
    """One-row-per-grid-step top-k, kept as the interpret-mode reference for
    :func:`topk_mips` (grid covers only the valid rows, so padding needs no
    masking here)."""
    N, d = table.shape
    Q = queries.shape[0]
    valid = N if valid is None else valid
    assert 0 < valid <= N, (valid, N)   # grid=(0,) would return garbage
    return pl.pallas_call(
        functools.partial(_topk_rowwise_kernel, k=k),
        grid=(valid,),
        in_specs=[
            pl.BlockSpec((1, d), lambda t: (t, 0)),         # table row
            pl.BlockSpec((Q, d), lambda t: (0, 0)),         # queries resident
        ],
        out_specs=(
            pl.BlockSpec((Q, k), lambda t: (0, 0)),
            pl.BlockSpec((Q, k), lambda t: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ),
        interpret=interpret,
    )(table, queries)


# --------------------------------------------------------------------------
# XLA paths: the CPU serving path and the cross-shard merge
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "valid"))
def topk_mips_xla(table, queries, *, k: int, valid: int | None = None):
    """Plain-jnp shard top-k: full (Q, N) scores + the shared selection
    network. The serving path on CPU (Pallas interpret mode is Python-slow)
    and the jnp-level oracle for the kernels."""
    N = table.shape[0]
    valid = N if valid is None else valid
    f32 = jnp.float32
    scores = queries.astype(f32) @ table.astype(f32).T
    gidx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    invalid = gidx >= valid
    return select_topk(jnp.where(invalid, NEG_INF, scores),
                       jnp.where(invalid, IDX_SENTINEL, gidx), k)


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(vals, idx, *, k: int):
    """Cross-shard reduce: (P, Q, kk) per-shard results (ids already global)
    → the global (Q, k). Each shard's list is exact for its rows, so the
    global top-k is the top-k of the P*kk candidates — one selection pass,
    same tie rule."""
    P, Q, kk = vals.shape
    return select_topk(jnp.swapaxes(vals, 0, 1).reshape(Q, P * kk),
                       jnp.swapaxes(idx, 0, 1).reshape(Q, P * kk), k)
