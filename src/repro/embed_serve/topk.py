"""Pallas blocked MIPS top-k kernels for sharded embedding retrieval.

The serving hot loop is the training hot loop run backwards: instead of
gathering a minibatch of rows by index, a query batch scans every table row
once — a (Q, d) x (d, N) matmul that is O(1) arithmetic intensity per table
byte, so (like training) the kernel's job is to touch each HBM row exactly
once and keep the MXU fed while the next tile's DMA is in flight.

Kernels (all validated against :func:`repro.kernels.ref.topk_mips_ref`):

  * :func:`topk_mips`          — the production kernel: the table stays in
    HBM; (bn, d) row tiles are double-buffered into VMEM by explicit DMA
    (tile t+1's copy flies while tile t is scored on the MXU), and each
    query block folds every tile into a running (bq, k) top-k held in the
    revisited output block. One HBM read per table row per query block.
  * :func:`topk_mips_quant`    — the int8 first pass of the two-tier
    quantized scan (``embed_serve.quant``): the same double-buffered
    tile-DMA skeleton streaming (bn, d) *int8* tiles (4x less DMA traffic
    than f32), per-row scales riding a pipelined (1, bn) block, keeping an
    over-fetched running top-``m`` candidate set per query block. Its
    output is approximate by the quantization error — survivors are
    re-scored exactly by ``quant.rescore_exact``.
  * :func:`topk_mips_quant_xla` — plain-jnp quantized first pass; the CPU
    serving path for the quant tier and the kernel's cross-check (int8
    scores are exact integers in f32, so the two agree bitwise).
  * :func:`topk_mips_rowwise`  — one table row per grid step through a
    BlockSpec-pipelined (1, d) block; the interpret-mode reference, in the
    spirit of ``kernels.sgns.gather_rows_rowwise``.
  * :func:`topk_mips_xla`      — plain-jnp scores + the same selection
    network; the CPU/XLA serving path and the shard-level oracle.
  * :func:`merge_topk`         — the small jitted cross-shard reduce: P
    per-shard (Q, k) results (global ids) → the global (Q, k).

Launch geometry: ``block_n=None`` (the default everywhere) sizes the scan
tile with :func:`choose_block_n` — the serving mirror of
``kernels.ops.choose_block_b``, fitting the (2*bn, d) double-buffer
scratch plus the merge working set against ``roofline.VMEM_BYTES``.

Exactness: scores are f32 (tables cast up before the dot, like the SGNS
kernels), selection is exact MIPS with ties broken toward the smaller row
index — the same total order as the numpy oracle's stable argsort.
Sentinels: invalid positions (padded table rows, masked candidates) carry
(-inf, int32 max), so they lose every comparison and a shard with fewer
than k valid rows degrades gracefully in the cross-shard merge.

Interpret mode on CPU; TPU is the compilation target (lane-alignment
follow-ons for the (bq, k) outputs are in the ROADMAP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.launch import roofline

NEG_INF = float("-inf")
IDX_SENTINEL = jnp.iinfo(jnp.int32).max
DEFAULT_BLOCK_Q = 128   # query rows per resident block (topk_mips default);
                        # the table is re-scanned once per query block
DEFAULT_PLAN_K = 128    # running-candidate allowance choose_block_n assumes
                        # when the query-time k is not yet known (store load)


# --------------------------------------------------------------------------
# VMEM-aware tile planner (the serving mirror of kernels.ops.choose_block_b:
# all decisions from static shape/dtype info, nothing at run time)
# --------------------------------------------------------------------------
def topk_scan_vmem_bytes(bn: int, d: int, dtype, *, k: int = DEFAULT_PLAN_K,
                         block_q: int = DEFAULT_BLOCK_Q,
                         hot_rows: int = 0) -> int:
    """Modeled VMEM working set of one topk_mips/topk_mips_quant launch.

    Mirrors the scratch_shapes + compute temporaries: the (2*bn, d)
    double-buffer tile slots (table dtype), the f32 cast of the scored
    tile, the resident (bq, d) query block, the (bq, bn) score/iota
    matrices, the (bq, k + bn) candidate concat the k-pass selection walks
    (vals/idx plus the per-pass masks — modeled at 4 f32-width copies),
    and the revisited (bq, k) output blocks.

    hot_rows models a co-resident hot-tier scan tile: the tiered store
    runs an exact-f32 scan over min(hot_rows, bn) rows alongside the quant
    scan of the cold remainder, so that tile's bytes come out of the same
    budget. 0 (default) is the untiered model, byte-identical to before.
    """
    item = jnp.dtype(dtype).itemsize
    total = 2 * bn * d * item            # double-buffered tile slots
    total += bn * d * 4                  # f32 cast of the scored tile
    total += block_q * d * 4             # resident query block
    total += block_q * bn * 4 * 2        # (bq, bn) scores + index iota
    total += block_q * (k + bn) * 4 * 4  # select_topk candidate working set
    total += block_q * k * 4 * 2         # running (bq, k) output blocks
    total += min(hot_rows, bn) * d * 4   # exact hot-tier scan tile (f32)
    return total


def choose_block_n(d: int, dtype, *, k: int = DEFAULT_PLAN_K,
                   block_q: int = DEFAULT_BLOCK_Q,
                   vmem_budget: int = roofline.VMEM_BYTES,
                   hot_rows: int = 0) -> int:
    """Scan-tile rows from (d, dtype, k, block_q, VMEM budget).

    Largest power-of-two tile (cap 512 — past that the merge cost per tile
    grows without more DMA overlap to win) whose modeled working set fits
    half the budget (headroom for compiler temporaries, same safety stance
    as ``ops.choose_block_b``); floor 8 (f32 sublane). The (2*bn, d)
    double-buffer scratch was previously unplanned — at d ≥ 4k an f32
    bn=256 scratch alone busts a 16 MB budget.
    """
    bn = 512
    while bn > 8 and topk_scan_vmem_bytes(
            bn, d, dtype, k=k, block_q=block_q,
            hot_rows=hot_rows) > vmem_budget // 2:
        bn //= 2
    return bn


def select_topk(vals: jax.Array, idx: jax.Array, k: int):
    """Exact top-k over (Q, M) candidate (value, index) pairs.

    k unrolled VPU-shaped passes: each selects the row-wise max value, and
    among equal values the smallest index, then masks the taken slot to the
    (-inf, sentinel) pair. Shared by the kernels' per-tile merge (M = k +
    tile rows) and the cross-shard reduce (M = shards * k) so the tie rule
    cannot diverge between the two levels.

    Returns ((Q, k) f32, (Q, k) i32).
    """
    vals = vals.astype(jnp.float32)
    idx = idx.astype(jnp.int32)
    out_v, out_i = [], []
    for _ in range(k):
        v = jnp.max(vals, axis=1)
        is_max = vals == v[:, None]
        i = jnp.min(jnp.where(is_max, idx, IDX_SENTINEL), axis=1)
        taken = is_max & (idx == i[:, None])
        vals = jnp.where(taken, NEG_INF, vals)
        idx = jnp.where(taken, IDX_SENTINEL, idx)
        out_v.append(v)
        out_i.append(i)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _scored_tile(q_f32, tile, tile_start: jax.Array, valid: int, scale=None):
    """(bq, bn) f32 scores + global-index matrix for one table tile, with
    padded rows (global index >= valid) already demoted to sentinels.
    `scale` ((1, bn) f32, int8 tiles only) rescales each row's raw integer
    scores back to embedding units before the demotion."""
    f32 = jnp.float32
    scores = jax.lax.dot_general(q_f32, tile.astype(f32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32)
    if scale is not None:
        scores = scores * scale
    gidx = tile_start + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    invalid = gidx >= valid
    return (jnp.where(invalid, NEG_INF, scores),
            jnp.where(invalid, IDX_SENTINEL, gidx))


def _merge_into(out_v_ref, out_i_ref, scores, gidx, k: int):
    """Fold a scored tile into the running top-k held in the output refs."""
    cand_v = jnp.concatenate([out_v_ref[...], scores], axis=1)
    cand_i = jnp.concatenate([out_i_ref[...], gidx], axis=1)
    nv, ni = select_topk(cand_v, cand_i, k)
    out_v_ref[...] = nv
    out_i_ref[...] = ni


# --------------------------------------------------------------------------
# production kernel: HBM-resident table, double-buffered (bn, d) tile DMA.
# One body serves both tiers — the exact f32/bf16 scan and the int8
# first pass (quant=True adds the pipelined (1, bn) row-scale block), so
# the prefetch/semaphore/padding logic cannot drift between them.
# --------------------------------------------------------------------------
def _topk_scan_kernel(*refs, k: int, bn: int, valid: int, quant: bool):
    if quant:
        tbl_hbm, scale_ref, q_ref, out_v_ref, out_i_ref, tile_s, sem = refs
    else:
        tbl_hbm, q_ref, out_v_ref, out_i_ref, tile_s, sem = refs
        scale_ref = None
    t = pl.program_id(1)
    T = pl.num_programs(1)

    def tile_copy(tt, op):
        """start/wait tile tt's contiguous-row DMA on buffer slot tt % 2."""
        getattr(pltpu.make_async_copy(
            tbl_hbm.at[pl.ds(tt * bn, bn)],
            tile_s.at[pl.ds((tt % 2) * bn, bn)],
            sem.at[tt % 2]), op)()

    @pl.when(t == 0)
    def _prologue():           # new query block: restart the tile pipeline
        tile_copy(0, "start")
        out_v_ref[...] = jnp.full_like(out_v_ref, NEG_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, IDX_SENTINEL)

    @pl.when(t + 1 < T)
    def _prefetch_next():      # double buffering: next tile's DMA flies
        tile_copy(t + 1, "start")   # while this tile is scored on the MXU

    tile_copy(t, "wait")

    tile = tile_s[pl.ds((t % 2) * bn, bn), :]
    scores, gidx = _scored_tile(
        q_ref[...].astype(jnp.float32), tile, t * bn, valid,
        scale=None if scale_ref is None else scale_ref[...])
    _merge_into(out_v_ref, out_i_ref, scores, gidx, k)


def _launch_topk_scan(table, scales, queries, *, k: int, valid: int,
                      bq: int, bn: int, interpret: bool):
    """Pad to tile multiples and launch :func:`_topk_scan_kernel`.

    scales=None is the exact scan; a (1, N) f32 scales row makes it the
    int8 first pass. Returns the unpadded ((Q, k) f32, (Q, k) i32)."""
    N, d = table.shape
    Q = queries.shape[0]
    quant = scales is not None
    if N % bn:
        pad = (-N) % bn
        table = jnp.pad(table, ((0, pad), (0, 0)))
        if quant:
            scales = jnp.pad(scales, ((0, 0), (0, pad)),
                             constant_values=1.0)
        N = table.shape[0]
    Qp = -(-Q // bq) * bq
    qp = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    grid = (Qp // bq, N // bn)        # table tiles innermost (sequential)
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]       # table (HBM)
    operands = [table]
    if quant:
        in_specs.append(pl.BlockSpec((1, bn), lambda qi, t: (0, t)))
        operands.append(scales)                             # row scales
    in_specs.append(pl.BlockSpec((bq, d), lambda qi, t: (qi, 0)))
    operands.append(qp)                                     # query block
    out_v, out_i = pl.pallas_call(
        functools.partial(_topk_scan_kernel, k=k, bn=bn, valid=valid,
                          quant=quant),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, k), lambda qi, t: (qi, 0)),   # running top-k
            pl.BlockSpec((bq, k), lambda qi, t: (qi, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((2 * bn, d), table.dtype),           # tile slots
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(*operands)
    return out_v[:Q], out_i[:Q]


@functools.partial(jax.jit, static_argnames=("k", "valid", "block_q",
                                             "block_n", "interpret"))
def topk_mips(table, queries, *, k: int, valid: int | None = None,
              block_q: int = DEFAULT_BLOCK_Q, block_n: int | None = None,
              interpret: bool = False):
    """Exact-MIPS top-k of `queries` against one table shard.

    table: (N, d) HBM-resident shard (bf16 or f32 — scored in f32);
    queries: (Q, d). `valid` masks padded tail rows (row >= valid scores
    -inf and can never be returned); rows are padded here to a block_n
    multiple if the caller didn't (the store pre-pads at load so serving
    never re-materializes the table). block_n=None sizes the scan tile
    with :func:`choose_block_n` against the VMEM budget; an explicit
    block_n is capped (not pinned) by the k-aware plan — the running
    (bq, k) list is this kernel's own working set, and the store passes
    its load-time tile (planned at ``DEFAULT_PLAN_K``) for every
    query-time k.

    Returns ((Q, k) f32 scores, (Q, k) i32 shard-local row ids), both
    sorted by the oracle's total order (descending score, ascending index
    on ties). If valid < k the tail entries are (-inf, int32 max).
    """
    N, d = table.shape
    valid = N if valid is None else valid
    assert 0 < valid <= N, (valid, N)
    bq = min(block_q, queries.shape[0])
    planned = choose_block_n(d, table.dtype, k=k, block_q=bq)
    bn = planned if block_n is None else min(block_n, planned)
    return _launch_topk_scan(table, None, queries, k=k, valid=valid,
                             bq=bq, bn=min(bn, N), interpret=interpret)


# --------------------------------------------------------------------------
# quantized first pass: int8 tiles through the same double-buffered DMA
# skeleton, over-fetched running top-m (the two-tier scan's tier one)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("m", "valid", "block_q",
                                             "block_n", "interpret"))
def topk_mips_quant(qtable, scales, queries, *, m: int,
                    valid: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_n: int | None = None, interpret: bool = False):
    """Int8 first-pass scan: approximate top-``m`` candidates per query.

    qtable: (N, d) int8 shard (``quant.quantize_rows``); scales: (N,) f32
    per-row dequantization scales; queries: (Q, d). The tile-DMA skeleton
    is :func:`topk_mips`'s (shared ``_topk_scan_kernel`` body), but the
    streamed tiles are int8 — 4x less HBM traffic per scan — with the
    per-row scales riding a BlockSpec-pipelined (1, bn) block. Scores are
    (q @ tile.T) * scale in f32; the dominant error is the quantization
    itself (bounded per row — see ``quant.quantize_rows``), which the
    exact second tier absorbs.

    Like the exact kernel, an explicit block_n is capped (not pinned) by
    the ``m``-aware :func:`choose_block_n` plan: the over-fetched (bq, m)
    candidate list is this kernel's own working set — a caller passing a
    tile planned for plain top-k (the store's load-time
    ``DEFAULT_PLAN_K`` plan) must not silently bust the VMEM budget when
    ``m = k * overfetch`` runs far past that allowance.

    Returns ((Q, m) f32 approx scores, (Q, m) i32 shard-local row ids) —
    feed the ids to ``quant.rescore_exact`` for the exact second tier.
    """
    N, d = qtable.shape
    valid = N if valid is None else valid
    assert 0 < valid <= N, (valid, N)
    assert qtable.dtype == jnp.int8, qtable.dtype
    bq = min(block_q, queries.shape[0])
    planned = choose_block_n(d, qtable.dtype, k=m, block_q=bq)
    bn = planned if block_n is None else min(block_n, planned)
    return _launch_topk_scan(qtable, scales.astype(jnp.float32).reshape(1, N),
                             queries, k=m, valid=valid, bq=bq,
                             bn=min(bn, N), interpret=interpret)


def _masked_select(scores, valid: int, k: int):
    """Demote rows >= valid to sentinels and run the selection network —
    the shared tail of the jnp scan paths."""
    gidx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    invalid = gidx >= valid
    return select_topk(jnp.where(invalid, NEG_INF, scores),
                       jnp.where(invalid, IDX_SENTINEL, gidx), k)


@functools.partial(jax.jit, static_argnames=("m", "valid"))
def topk_mips_quant_xla(qtable, scales, queries, *, m: int,
                        valid: int | None = None):
    """Plain-jnp int8 first pass: the CPU serving path for the quant tier
    and the cross-check for :func:`topk_mips_quant` (bitwise identical on
    integer queries, where every f32 dot is exact; on continuous data an
    accumulation-order ulp flip at the m-boundary is possible — and
    harmless, since tier two rescores exactly)."""
    N = qtable.shape[0]
    f32 = jnp.float32
    scores = (queries.astype(f32) @ qtable.astype(f32).T
              ) * scales.astype(f32).reshape(1, N)
    return _masked_select(scores, N if valid is None else valid, m)


# --------------------------------------------------------------------------
# rowwise reference: one table row per grid step, BlockSpec-pipelined
# --------------------------------------------------------------------------
def _topk_rowwise_kernel(row_ref, q_ref, out_v_ref, out_i_ref, *, k: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_v_ref[...] = jnp.full_like(out_v_ref, NEG_INF)
        out_i_ref[...] = jnp.full_like(out_i_ref, IDX_SENTINEL)

    f32 = jnp.float32
    score = jax.lax.dot_general(q_ref[...].astype(f32),
                                row_ref[...].astype(f32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)   # (Q, 1)
    gidx = jnp.full_like(score, t, dtype=jnp.int32)
    _merge_into(out_v_ref, out_i_ref, score, gidx, k)


@functools.partial(jax.jit, static_argnames=("k", "valid", "interpret"))
def topk_mips_rowwise(table, queries, *, k: int, valid: int | None = None,
                      interpret: bool = False):
    """One-row-per-grid-step top-k, kept as the interpret-mode reference for
    :func:`topk_mips` (grid covers only the valid rows, so padding needs no
    masking here)."""
    N, d = table.shape
    Q = queries.shape[0]
    valid = N if valid is None else valid
    assert 0 < valid <= N, (valid, N)   # grid=(0,) would return garbage
    return pl.pallas_call(
        functools.partial(_topk_rowwise_kernel, k=k),
        grid=(valid,),
        in_specs=[
            pl.BlockSpec((1, d), lambda t: (t, 0)),         # table row
            pl.BlockSpec((Q, d), lambda t: (0, 0)),         # queries resident
        ],
        out_specs=(
            pl.BlockSpec((Q, k), lambda t: (0, 0)),
            pl.BlockSpec((Q, k), lambda t: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ),
        interpret=interpret,
    )(table, queries)


# --------------------------------------------------------------------------
# XLA paths: the CPU serving path and the cross-shard merge
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "valid"))
def topk_mips_xla(table, queries, *, k: int, valid: int | None = None):
    """Plain-jnp shard top-k: full (Q, N) scores + the shared selection
    network. The serving path on CPU (Pallas interpret mode is Python-slow)
    and the jnp-level oracle for the kernels."""
    N = table.shape[0]
    f32 = jnp.float32
    scores = queries.astype(f32) @ table.astype(f32).T
    return _masked_select(scores, N if valid is None else valid, k)


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(vals, idx, *, k: int):
    """Cross-shard reduce: (P, Q, kk) per-shard results (ids already global)
    → the global (Q, k). Each shard's list is exact for its rows, so the
    global top-k is the top-k of the P*kk candidates — one selection pass,
    same tie rule."""
    P, Q, kk = vals.shape
    return select_topk(jnp.swapaxes(vals, 0, 1).reshape(Q, P * kk),
                       jnp.swapaxes(idx, 0, 1).reshape(Q, P * kk), k)
