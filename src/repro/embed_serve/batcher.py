"""Async micro-batching request frontend for embedding retrieval.

Single-query requests are individually tiny (one (d,) vector) while the
top-k kernel's cost is dominated by the per-batch table scan, so serving
heavy traffic means coalescing: requests enter a bounded queue, a worker
thread (the same single-worker pattern as ``core.pipeline.EpisodePipeline``)
collects them until either the batch-window deadline or the max batch size
hits, pads the stacked queries to ``pad_multiple`` rows, runs the backend
once, and resolves each request's future with its own row of the result.

Backpressure is the queue bound: ``submit`` blocks when the queue is full,
so an over-driven client slows to the serve rate instead of ballooning
memory. Exceptions from the backend propagate to every future of the
failed batch; ``close()`` serves everything already queued before the
worker exits (mirroring ``EpisodePipeline.close``'s drain-don't-drop
teardown).

Overload control (``repro.runtime``): ``deadline_ms`` stamps every request
at admission and expires it with ``DeadlineExceeded`` — instead of serving
it — once the stamp passes (a request never hangs past its deadline: it is
either served, expired, or shed). ``shed_on_full=True`` turns the full-
queue block into an immediate ``Overloaded`` raise, the admission-control
mode for latency-sensitive serving. When the backend returns a third
element (``ShardedEmbeddingStore.topk(return_meta=True)``'s ``TopKMeta``),
it is attached to every request of the batch, so callers see degraded
responses tagged as such.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.obs import (gauge_set, observe, register_source, span,
                       trace_counter, unregister_source)
from repro.runtime import DeadlineExceeded, Overloaded

_CLOSE = object()


@dataclasses.dataclass
class BatcherStats:
    """Coalescing + overload counters. ``shed`` is bumped by submitter
    threads, the rest by the worker — ALL under the batcher's stats lock,
    and readers should take a consistent :meth:`MicroBatcher.stats_snapshot`
    rather than reading fields off the live object mid-flight."""

    requests: int = 0
    batches: int = 0
    padded_rows: int = 0
    shed: int = 0         # rejected at admission (queue full, shed_on_full)
    expired: int = 0      # deadline passed before the batch ran
    degraded: int = 0     # requests answered from a degraded (partial) scan

    @property
    def mean_batch(self) -> float:
        return self.requests / self.batches if self.batches else 0.0


class MicroBatcher:
    """Batches single-query requests into backend calls.

    serve_fn: ``(B, d) float32 -> (vals (B, k), ids (B, k))`` — typically
    ``lambda q: store.topk(q, k)``. Each ``submit((d,) vector)`` returns a
    ``concurrent.futures.Future`` resolving to that query's
    ``(vals (k,), ids (k,))``.
    """

    def __init__(self, serve_fn, dim: int, *, max_batch: int = 256,
                 window_ms: float = 2.0, pad_multiple: int = 8,
                 queue_cap: int = 4096, fixed_batch: bool = False,
                 deadline_ms: float | None = None,
                 shed_on_full: bool = False):
        """fixed_batch=True pads every backend call to max_batch rows, so a
        jitted (shape-specialized) backend compiles exactly one batch shape
        instead of one per first-seen multiple of pad_multiple — the right
        mode for compiled serving (warm up with one max_batch call).
        deadline_ms gives every request a per-request deadline from the
        moment of admission: a request still queued when it expires fails
        with DeadlineExceeded instead of being served late. shed_on_full
        makes a full queue raise Overloaded at submit instead of blocking
        (admission control instead of backpressure)."""
        assert max_batch >= 1 and pad_multiple >= 1 and queue_cap >= 1
        self._serve_fn = serve_fn
        self._dim = dim
        self._max_batch = max_batch
        self._window_s = window_ms / 1e3
        self._pad = max_batch if fixed_batch else pad_multiple
        self._deadline_s = None if deadline_ms is None else deadline_ms / 1e3
        self._shed_on_full = shed_on_full
        self._queue = queue.Queue(maxsize=queue_cap)
        self._closed = False
        self._drained = False       # close() finished its cancel-drain
        self.stats = BatcherStats()
        self._stats_mu = threading.Lock()   # guards EVERY stats field
        self._thread = threading.Thread(target=self._worker,
                                        name="embed-serve-batcher",
                                        daemon=True)
        self._thread.start()
        # BatcherStats over the registry: the canonical counters live here
        # (under _stats_mu); the registry polls them at snapshot time, so
        # metrics.jsonl / diagnostics see the same numbers stats_snapshot
        # callers do, without a second set of books
        register_source("serve.batcher", self._stats_source)

    def _stats_source(self) -> dict:
        s = self.stats_snapshot()
        d = dataclasses.asdict(s)
        d["mean_batch"] = s.mean_batch
        d["queue_depth"] = self._queue.qsize()
        return d

    # ---------------------------------------------------------------- API
    def submit(self, query) -> Future:
        """Enqueue one (d,) query; blocks when the queue is full (or, with
        ``shed_on_full``, raises Overloaded instead of blocking)."""
        q = np.asarray(query, dtype=np.float32)
        if q.shape != (self._dim,):
            raise ValueError(f"query shape {q.shape} != ({self._dim},)")
        if self._closed:
            raise RuntimeError("MicroBatcher is closed")
        fut = Future()
        t_sub = time.perf_counter()
        dl = (None if self._deadline_s is None
              else t_sub + self._deadline_s)
        if self._shed_on_full:
            try:
                self._queue.put_nowait((q, fut, dl, t_sub))
            except queue.Full:
                with self._stats_mu:
                    self.stats.shed += 1
                raise Overloaded(
                    f"queue full ({self._queue.maxsize}); request shed"
                ) from None
        else:
            self._queue.put((q, fut, dl, t_sub))
        depth = self._queue.qsize()
        gauge_set("serve.queue_depth", depth)
        trace_counter("serve.queue_depth", depth)
        # a close() racing the check above either drains this item (worker
        # backlog or close's cancel loop) or already finished draining —
        # `_drained` was set before that final drain, so seeing it here
        # means nobody will ever pop the queue again: cancel, don't strand
        if self._drained:
            fut.cancel()
        return fut

    def close(self) -> None:
        """Stop accepting requests, serve the backlog, join the worker.

        Always synchronous: a no-wait variant cannot uphold both the
        serve-the-backlog guarantee and the no-stranded-future guarantee
        (the worker may finish its drain before a racing submit's put
        lands), so one isn't offered."""
        if self._closed:
            return
        self._closed = True
        unregister_source("serve.batcher")
        self._queue.put(_CLOSE)
        self._thread.join()
        # a submit() that raced close() past the closed check would
        # otherwise hang its caller: cancel, don't strand. `_drained`
        # goes up BEFORE the drain so a put landing after the final
        # get_nowait sees it and self-cancels (see submit).
        self._drained = True
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not _CLOSE:
                item[1].cancel()

    def stats_snapshot(self) -> BatcherStats:
        """A consistent copy of the counters. The live ``stats`` object is
        written by the worker and submitter threads under ``_stats_mu``;
        reading its fields individually can observe a torn update (e.g.
        ``requests`` from batch N+1 with ``batches`` from batch N, skewing
        ``mean_batch``). Readers take the snapshot instead."""
        with self._stats_mu:
            return dataclasses.replace(self.stats)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- worker
    def _worker(self):
        while True:
            item = self._queue.get()
            if item is _CLOSE:
                self._drain()
                return
            batch = [item]
            deadline = time.perf_counter() + self._window_s
            closing = False
            while len(batch) < self._max_batch:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                try:
                    nxt = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if nxt is _CLOSE:
                    closing = True
                    break
                batch.append(nxt)
            self._run(batch)
            if closing:
                self._drain()
                return

    def _drain(self):
        """Serve whatever was queued before the close sentinel."""
        batch = []
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _CLOSE:
                continue
            batch.append(item)
            if len(batch) == self._max_batch:
                self._run(batch)
                batch = []
        if batch:
            self._run(batch)

    def _run(self, batch):
        # expire first: a request whose deadline passed while queued gets
        # DeadlineExceeded, never a late answer
        now = time.perf_counter()
        live = []
        for q, fut, dl, t_sub in batch:
            if dl is not None and now > dl:
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(DeadlineExceeded(
                        f"request expired {now - dl:.3f}s past its "
                        f"deadline before serving"))
                    with self._stats_mu:
                        self.stats.expired += 1
                continue
            if fut.set_running_or_notify_cancel():
                live.append((q, fut, t_sub))
        if not live:
            return
        qs = np.stack([q for q, _, _ in live])
        B = qs.shape[0]
        Bp = -(-B // self._pad) * self._pad
        if Bp > B:                      # pad rows: results are discarded
            qs = np.concatenate(
                [qs, np.zeros((Bp - B, self._dim), qs.dtype)])
        try:
            with span("serve_batch", "serve", {"batch": B, "padded": Bp}):
                out = self._serve_fn(qs)
        except Exception as e:          # noqa: BLE001 — propagate to callers
            for _, fut, _ in live:
                fut.set_exception(e)
            return
        # backend returns (vals, ids) or (vals, ids, meta) — a degraded-scan
        # tag (TopKMeta) is attached to every request of the batch
        meta = out[2] if len(out) == 3 else None
        vals, ids = out[0], out[1]
        t_done = time.perf_counter()
        for i, (_, fut, t_sub) in enumerate(live):
            row = (np.asarray(vals[i]), np.asarray(ids[i]))
            fut.set_result(row if meta is None else row + (meta,))
            observe("serve.request_s", t_done - t_sub)  # admission -> served
        with self._stats_mu:
            self.stats.requests += B
            self.stats.batches += 1
            self.stats.padded_rows += Bp - B
            if meta is not None and getattr(meta, "degraded", False):
                self.stats.degraded += len(live)


def drive_open_loop(batcher: MicroBatcher, queries, *, qps: float = 0.0,
                    timeout: float = 600.0):
    """Drive a query stream through a batcher open-loop, measuring each
    request from just before its submit (queue backpressure included) to
    future resolution. qps > 0 paces submissions on a fixed schedule;
    qps = 0 bursts. The ONE load-generator definition shared by the CLI
    and bench_serve, so their reported percentiles mean the same thing.

    Returns (results, latencies_s, wall_s) — all in submission order."""
    n = len(queries)
    futs = [None] * n
    lat = [None] * n           # distinct slots: no lock needed under GIL

    def make_cb(i, t_sub):
        def cb(_fut):
            lat[i] = time.perf_counter() - t_sub
        return cb

    interval = 1.0 / qps if qps > 0 else 0.0
    t_start = time.perf_counter()
    for i in range(n):
        if interval:
            delay = t_start + i * interval - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
        t_sub = time.perf_counter()
        fut = batcher.submit(queries[i])
        fut.add_done_callback(make_cb(i, t_sub))
        futs[i] = fut
    results = [f.result(timeout=timeout) for f in futs]
    wall = time.perf_counter() - t_start
    # Future.result() wakes BEFORE done-callbacks run (CPython notifies
    # waiters first), so the last slots may still be None for an instant
    deadline = time.perf_counter() + 10.0
    while any(v is None for v in lat):
        if time.perf_counter() > deadline:
            raise RuntimeError("latency callbacks did not complete")
        time.sleep(0.0005)
    return results, lat, wall
