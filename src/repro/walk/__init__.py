from repro.walk.alias import AliasTable
from repro.walk.engine import WalkEngine, WalkConfig
from repro.walk.augment import walks_to_pairs
from repro.walk.remote import (RemoteEpisodeServer, RemoteProducer,
                               RemoteWalkCoordinator)
from repro.walk.store import SampleStore, MemorySampleStore, DiskSampleStore

__all__ = [
    "AliasTable", "WalkEngine", "WalkConfig", "walks_to_pairs",
    "RemoteEpisodeServer", "RemoteProducer", "RemoteWalkCoordinator",
    "SampleStore", "MemorySampleStore", "DiskSampleStore",
]
