"""Decoupled random-walk engine (paper §III intro + §IV-A).

The paper decouples random-walk network augmentation from embedding training:
the walk engine runs on CPUs (Plato/KnightKing in the paper), writes episode-
partitioned walk/sample files, and the GPU training engine consumes them —
either offline (slow clusters) or pipelined (fast clusters).

This module is the CPU component. It produces walks (vectorized numpy
DeepWalk / node2vec-style) and hands them to a :class:`SampleStore` partitioned
by episode, applying the degree-guided partitioning of GraphVite [4]: walk
start nodes are ordered so that high-degree nodes spread uniformly across
episode partitions, balancing per-episode work.

Streaming dataflow: each episode's start nodes are split into fixed-size
chunks, each chunk seeded independently by (seed, epoch, episode, chunk).
A worker pool (``WalkConfig.workers``) generates chunks concurrently; the
coordinator assembles them IN CHUNK ORDER and ``put``s each episode into the
store as soon as it completes, so episode e's training overlaps episode
e+1's walks. Because the chunk decomposition and per-chunk RNG streams are
fixed by the config — never by the worker count — the sample stream is
bitwise identical for any ``workers`` setting, including the synchronous
``workers=1`` path.

Fault tolerance: each chunk is a retriable unit — its RNG stream is fixed
by (seed, epoch, episode, chunk), so a crashed chunk replayed under
``WalkConfig.retries`` produces bitwise-identical pairs (test-gated). The
``walk.chunk`` fault site sits at the top of the chunk body;
:meth:`WalkEngine.alive` feeds the store's producer-liveness watchdog so a
walker that exhausts its retries fails consumers loudly instead of leaving
them blocked.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import queue as _queue
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.graph.csr import CSRGraph
from repro.obs import counter_add, observe
from repro.obs import trace as _trace
from repro.runtime import RetryPolicy, call_with_retry, fault_point
from repro.walk.augment import walks_to_pairs
from repro.walk.store import SampleStore


@dataclasses.dataclass
class WalkConfig:
    walk_length: int = 10          # paper's walk distance k
    window: int = 5                # paper's walk context length l
    walks_per_node: int = 1
    node2vec_p: float = 1.0        # return parameter (1.0 == DeepWalk)
    node2vec_q: float = 1.0        # in-out parameter
    episodes: int = 8              # partitions per epoch
    seed: int = 0
    # streaming knobs. `workers` sizes the chunk worker pool (1 = run chunks
    # inline on the coordinator). `chunk_size` fixes the canonical per-episode
    # chunk decomposition — it changes the RNG stream, `workers` never does.
    # `lookahead` bounds run-ahead: chunk futures are in flight for at most
    # this many episodes beyond the one currently being assembled, so engine-
    # side buffering stays O(lookahead · episode) even when the store's
    # backpressure stalls `put`.
    workers: int = 1
    chunk_size: int = 4096
    lookahead: int = 2
    # fault tolerance: total tries per chunk (1 = fail on first error) and
    # the base backoff between them. Replay is bitwise-safe: the chunk's RNG
    # stream depends only on (seed, epoch, episode, chunk).
    retries: int = 3
    retry_backoff_s: float = 0.05


class WalkEngine:
    """Produces augmented edge samples, episode-partitioned.

    ``run_epoch`` streams episodes into the store as they complete (chunks
    sharded over ``config.workers`` threads); ``start_async``/``join`` run the
    whole engine on a background thread so training overlaps walk generation
    — the paper's pipelined decoupling. Worker errors propagate through the
    ``_errors`` queue and re-raise in ``join``.
    """

    def __init__(self, graph: CSRGraph, config: WalkConfig,
                 store: SampleStore | None = None):
        # store=None is the producer-side mode: a remote walk producer uses
        # only the store-free generation surface (episode_chunk_stream /
        # episode_pairs) and ships chunks over the transport instead of
        # putting them locally. run_epoch/start_async require a store.
        self.graph = graph
        self.config = config
        self.store = store
        self._thread: threading.Thread | None = None
        self._errors: _queue.Queue = _queue.Queue()
        # per-episode walk BUSY seconds (sum of per-chunk processing time,
        # measured inside the worker) for the bench's per-stage accounting —
        # busy time, not wall: concurrent chunks would otherwise double-count
        self.episode_walk_s: dict[tuple[int, int], float] = {}
        self._walk_s_mu = threading.Lock()

    # ------------------------------------------------------------------ walks
    def _step(self, cur: np.ndarray, prev: np.ndarray | None,
              rng: np.random.Generator) -> np.ndarray:
        """One vectorized walk step. Uniform choice for p=q=1, else 2nd-order."""
        g = self.graph
        deg = g.indptr[cur + 1] - g.indptr[cur]
        safe_deg = np.maximum(deg, 1)
        cfg = self.config
        m = g.num_edges
        if prev is None or (cfg.node2vec_p == 1.0 and cfg.node2vec_q == 1.0):
            off = rng.integers(0, safe_deg)
            # clamp: dead-end nodes produce an in-bounds dummy index that the
            # final where(deg>0) mask discards
            nxt = g.indices[np.minimum(g.indptr[cur] + off, m - 1)]
        else:
            # node2vec biased step via rejection sampling (Knightking-style):
            # proposal = uniform neighbor; accept with weight/upper_bound.
            upper = max(1.0, 1.0 / cfg.node2vec_p, 1.0 / cfg.node2vec_q)
            nxt = np.empty_like(cur)
            pending = np.arange(cur.size)
            for _ in range(16):  # bounded retries, then fall back to uniform
                if pending.size == 0:
                    break
                c = cur[pending]
                off = rng.integers(0, np.maximum(g.indptr[c + 1] - g.indptr[c], 1))
                prop = g.indices[np.minimum(g.indptr[c] + off, m - 1)]
                w = np.full(prop.shape, 1.0 / cfg.node2vec_q)
                w[prop == prev[pending]] = 1.0 / cfg.node2vec_p
                # distance-1 check (shared neighbor) approximated as weight 1
                # for proposals adjacent to prev — exact check is O(deg); the
                # rejection bound keeps the walk distribution close (KnightKing).
                accept = rng.random(prop.shape) < (w / upper)
                nxt[pending[accept]] = prop[accept]
                pending = pending[~accept]
            if pending.size:
                c = cur[pending]
                off = rng.integers(0, np.maximum(g.indptr[c + 1] - g.indptr[c], 1))
                nxt[pending] = g.indices[np.minimum(g.indptr[c] + off, m - 1)]
        # dead ends (deg==0) stay in place
        return np.where(deg > 0, nxt, cur)

    def generate_walks(self, starts: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        """(num_walks, walk_length+1) int32 walk matrix."""
        L = self.config.walk_length
        walks = np.empty((starts.size, L + 1), dtype=np.int32)
        walks[:, 0] = starts
        prev = None
        for t in range(L):
            walks[:, t + 1] = self._step(walks[:, t], prev, rng)
            prev = walks[:, t]
        return walks

    # --------------------------------------------------------------- episodes
    def _episode_starts(self, epoch: int) -> list[np.ndarray]:
        """Degree-guided partitioning of start nodes into episodes [4]:
        sort by degree, deal round-robin so every episode gets a balanced mix."""
        g, cfg = self.graph, self.config
        rng = np.random.default_rng(cfg.seed + 1000003 * epoch)
        starts = np.repeat(np.arange(g.num_nodes, dtype=np.int32), cfg.walks_per_node)
        order = np.argsort(g.degrees().astype(np.int64)[starts % g.num_nodes], kind="stable")
        starts = starts[order[::-1]]  # high-degree first
        parts = [starts[i :: cfg.episodes] for i in range(cfg.episodes)]
        for p in parts:
            rng.shuffle(p)
        return parts

    def _chunk_pairs(self, epoch: int, episode: int, chunk: int,
                     starts: np.ndarray) -> np.ndarray:
        """Walks + augmentation for one start-node chunk. The RNG stream is
        keyed by (seed, epoch, episode, chunk) — independent of which worker
        runs it and of the worker count."""
        fault_point("walk.chunk", (epoch, episode, chunk))
        t0 = time.perf_counter()
        cfg = self.config
        rng = np.random.default_rng(
            [cfg.seed & 0x7FFFFFFF, epoch, episode, chunk])
        walks = self.generate_walks(starts, rng)
        pairs = walks_to_pairs(walks, cfg.window)
        dt = time.perf_counter() - t0
        with self._walk_s_mu:
            key = (epoch, episode)
            self.episode_walk_s[key] = self.episode_walk_s.get(key, 0.0) + dt
        counter_add("walk.chunks")
        counter_add("walk.pairs", int(pairs.shape[0]))
        observe("walk.chunk_s", dt)
        tr = _trace.tracer()
        if tr is not None:
            # one lane per worker thread: concurrent chunk spans on a shared
            # lane would render as bogus nesting in Perfetto
            end = tr.now_us()
            tr.add_span("walk_chunk",
                        "walk:" + threading.current_thread().name,
                        end - dt * 1e6, end,
                        {"epoch": epoch, "episode": episode, "chunk": chunk,
                         "pairs": int(pairs.shape[0])})
        return pairs

    def _chunk_retrying(self, epoch: int, episode: int, chunk: int,
                        starts: np.ndarray) -> np.ndarray:
        """`_chunk_pairs` under the configured retry policy. Replay is
        bitwise-identical (RNG keyed by the chunk, not the attempt)."""
        cfg = self.config
        return call_with_retry(
            self._chunk_pairs, epoch, episode, chunk, starts,
            policy=RetryPolicy(attempts=max(1, cfg.retries),
                               backoff_s=cfg.retry_backoff_s))

    def _episode_chunks(self, starts: np.ndarray) -> list[np.ndarray]:
        c = max(1, self.config.chunk_size)
        return [starts[lo: lo + c] for lo in range(0, max(starts.size, 1), c)]

    def _assemble(self, chunks: list[np.ndarray]) -> np.ndarray:
        if not chunks:
            return np.zeros((0, 2), dtype=np.int32)
        if len(chunks) == 1:
            return chunks[0]
        return np.concatenate(chunks, axis=0)

    def run_epoch(self, epoch: int) -> None:
        """Stream every episode of one epoch into the store as it completes.

        Chunks run on a ``config.workers``-thread pool (inline when 1);
        episodes are assembled and ``put`` in episode order, so a bounded
        store's backpressure paces the coordinator while workers keep
        generating up to ``lookahead`` episodes ahead.
        """
        cfg = self.config
        parts = self._episode_starts(epoch)
        if cfg.workers <= 1:
            for ep, starts in enumerate(parts):
                pairs = self._assemble(
                    [self._chunk_retrying(epoch, ep, c, s)
                     for c, s in enumerate(self._episode_chunks(starts))])
                self.store.put(epoch, ep, pairs)
            self.store.finish_epoch(epoch)
            return

        pool = ThreadPoolExecutor(max_workers=cfg.workers,
                                  thread_name_prefix="walk")
        futs: dict[int, list] = {}

        def submit(ep: int) -> None:
            futs[ep] = [pool.submit(self._chunk_retrying, epoch, ep, c, s)
                        for c, s in enumerate(self._episode_chunks(parts[ep]))]

        try:
            hi = min(len(parts), 1 + max(0, cfg.lookahead))
            for ep in range(hi):
                submit(ep)
            for ep in range(len(parts)):
                pairs = self._assemble([f.result() for f in futs.pop(ep)])
                if hi < len(parts):
                    submit(hi)
                    hi += 1
                # may block on store backpressure — workers keep running the
                # already-submitted lookahead chunks meanwhile
                self.store.put(epoch, ep, pairs)
        except BaseException:
            pool.shutdown(wait=False, cancel_futures=True)
            raise
        pool.shutdown(wait=True)
        self.store.finish_epoch(epoch)

    def num_episodes(self) -> int:
        return self.config.episodes

    def episode_chunk_stream(self, epoch: int, episode: int):
        """Yield ``(chunk_index, num_chunks, pairs)`` for one episode.

        The remote producer's unit of shipment: the SAME chunk decomposition
        and ``(seed, epoch, episode, chunk)`` RNG keys as ``run_epoch`` /
        ``episode_pairs``, so chunks shipped over the transport and
        assembled in chunk order are bitwise-identical to in-process
        production — and any producer can replay any episode."""
        starts = self._episode_starts(epoch)[episode]
        chunks = self._episode_chunks(starts)
        for c, s in enumerate(chunks):
            yield c, len(chunks), self._chunk_retrying(epoch, episode, c, s)

    def episode_pairs(self, epoch: int, episode: int) -> np.ndarray:
        """Regenerate one episode's pairs directly (no store interaction).

        Deterministic replay for corrupt-episode recovery: the chunk
        decomposition and RNG keys depend only on the config, so this is
        bitwise-identical to what the original walk produced."""
        starts = self._episode_starts(epoch)[episode]
        return self._assemble(
            [self._chunk_retrying(epoch, episode, c, s)
             for c, s in enumerate(self._episode_chunks(starts))])

    # ------------------------------------------------------------ async mode
    def start_async(self, epoch: int) -> None:
        set_producer = getattr(self.store, "set_producer", None)
        if callable(set_producer):
            set_producer(self.alive)

        def _run():
            try:
                self.run_epoch(epoch)
            except Exception as e:
                self._errors.put(e)
                # wake any blocked store.get() so consumers fail fast rather
                # than hang (they see the epoch finished with missing episodes)
                self.store.finish_epoch(epoch)
        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def finished(self) -> bool:
        """True once the async epoch (if any) has fully completed."""
        return self._thread is None or not self._thread.is_alive()

    def alive(self) -> bool:
        """Producer-liveness probe for the store watchdogs. True while the
        async walker thread is running — or before/without one (sync use:
        no thread means the caller IS the producer, which is trivially
        alive)."""
        return self._thread is None or self._thread.is_alive()

    def join(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if not self._errors.empty():
            raise self._errors.get()
