"""Decoupled random-walk engine (paper §III intro + §IV-A).

The paper decouples random-walk network augmentation from embedding training:
the walk engine runs on CPUs (Plato/KnightKing in the paper), writes episode-
partitioned walk/sample files, and the GPU training engine consumes them —
either offline (slow clusters) or pipelined one epoch ahead (fast clusters).

This module is the CPU component. It produces walks (vectorized numpy
DeepWalk / node2vec-style) and hands them to a :class:`SampleStore` partitioned
by episode, applying the degree-guided partitioning of GraphVite [4]: walk
start nodes are ordered so that high-degree nodes spread uniformly across
episode partitions, balancing per-episode work.
"""
from __future__ import annotations

import dataclasses
import threading
import queue as _queue

import numpy as np

from repro.graph.csr import CSRGraph
from repro.walk.augment import walks_to_pairs
from repro.walk.store import SampleStore


@dataclasses.dataclass
class WalkConfig:
    walk_length: int = 10          # paper's walk distance k
    window: int = 5                # paper's walk context length l
    walks_per_node: int = 1
    node2vec_p: float = 1.0        # return parameter (1.0 == DeepWalk)
    node2vec_q: float = 1.0        # in-out parameter
    episodes: int = 8              # partitions per epoch
    seed: int = 0


class WalkEngine:
    """Produces augmented edge samples, episode-partitioned.

    ``run_epoch`` is synchronous; ``start_async``/``join`` run the engine on a
    background thread so training of epoch *e* overlaps walk generation of
    epoch *e+1* — the paper's pipelined decoupling.
    """

    def __init__(self, graph: CSRGraph, config: WalkConfig, store: SampleStore):
        self.graph = graph
        self.config = config
        self.store = store
        self._thread: threading.Thread | None = None
        self._errors: _queue.Queue = _queue.Queue()

    # ------------------------------------------------------------------ walks
    def _step(self, cur: np.ndarray, prev: np.ndarray | None,
              rng: np.random.Generator) -> np.ndarray:
        """One vectorized walk step. Uniform choice for p=q=1, else 2nd-order."""
        g = self.graph
        deg = g.indptr[cur + 1] - g.indptr[cur]
        safe_deg = np.maximum(deg, 1)
        cfg = self.config
        m = g.num_edges
        if prev is None or (cfg.node2vec_p == 1.0 and cfg.node2vec_q == 1.0):
            off = rng.integers(0, safe_deg)
            # clamp: dead-end nodes produce an in-bounds dummy index that the
            # final where(deg>0) mask discards
            nxt = g.indices[np.minimum(g.indptr[cur] + off, m - 1)]
        else:
            # node2vec biased step via rejection sampling (Knightking-style):
            # proposal = uniform neighbor; accept with weight/upper_bound.
            upper = max(1.0, 1.0 / cfg.node2vec_p, 1.0 / cfg.node2vec_q)
            nxt = np.empty_like(cur)
            pending = np.arange(cur.size)
            for _ in range(16):  # bounded retries, then fall back to uniform
                if pending.size == 0:
                    break
                c = cur[pending]
                off = rng.integers(0, np.maximum(g.indptr[c + 1] - g.indptr[c], 1))
                prop = g.indices[np.minimum(g.indptr[c] + off, m - 1)]
                w = np.full(prop.shape, 1.0 / cfg.node2vec_q)
                w[prop == prev[pending]] = 1.0 / cfg.node2vec_p
                # distance-1 check (shared neighbor) approximated as weight 1
                # for proposals adjacent to prev — exact check is O(deg); the
                # rejection bound keeps the walk distribution close (KnightKing).
                accept = rng.random(prop.shape) < (w / upper)
                nxt[pending[accept]] = prop[accept]
                pending = pending[~accept]
            if pending.size:
                c = cur[pending]
                off = rng.integers(0, np.maximum(g.indptr[c + 1] - g.indptr[c], 1))
                nxt[pending] = g.indices[np.minimum(g.indptr[c] + off, m - 1)]
        # dead ends (deg==0) stay in place
        return np.where(deg > 0, nxt, cur)

    def generate_walks(self, starts: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        """(num_walks, walk_length+1) int32 walk matrix."""
        L = self.config.walk_length
        walks = np.empty((starts.size, L + 1), dtype=np.int32)
        walks[:, 0] = starts
        prev = None
        for t in range(L):
            walks[:, t + 1] = self._step(walks[:, t], prev, rng)
            prev = walks[:, t]
        return walks

    # --------------------------------------------------------------- episodes
    def _episode_starts(self, epoch: int) -> list[np.ndarray]:
        """Degree-guided partitioning of start nodes into episodes [4]:
        sort by degree, deal round-robin so every episode gets a balanced mix."""
        g, cfg = self.graph, self.config
        rng = np.random.default_rng(cfg.seed + 1000003 * epoch)
        starts = np.repeat(np.arange(g.num_nodes, dtype=np.int32), cfg.walks_per_node)
        order = np.argsort(g.degrees().astype(np.int64)[starts % g.num_nodes], kind="stable")
        starts = starts[order[::-1]]  # high-degree first
        parts = [starts[i :: cfg.episodes] for i in range(cfg.episodes)]
        for p in parts:
            rng.shuffle(p)
        return parts

    def run_epoch(self, epoch: int) -> None:
        """Generate walks + augmentation pairs for every episode of one epoch."""
        cfg = self.config
        for ep, starts in enumerate(self._episode_starts(epoch)):
            rng = np.random.default_rng(cfg.seed + 7919 * epoch + ep)
            walks = self.generate_walks(starts, rng)
            pairs = walks_to_pairs(walks, cfg.window)
            self.store.put(epoch, ep, pairs)
        self.store.finish_epoch(epoch)

    # ------------------------------------------------------------ async mode
    def start_async(self, epoch: int) -> None:
        def _run():
            try:
                self.run_epoch(epoch)
            except Exception as e:
                self._errors.put(e)
                # wake any blocked store.get() so consumers fail fast rather
                # than hang (they see the epoch finished with missing episodes)
                self.store.finish_epoch(epoch)
        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def join(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if not self._errors.empty():
            raise self._errors.get()
