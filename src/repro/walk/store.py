"""Sample store connecting the walk engine to the training engine (paper Fig. 2).

The two engines are decoupled: the walk engine `put`s episode-partitioned
sample arrays, the trainer `get`s them. Two backends mirror the paper's two
cluster modes (§IV-A): in-memory (fast clusters, samples stay resident) and
disk (slow clusters: offline files partitioned by episode, memory-mapped).

Both backends implement a bounded-capacity contract: constructed with
``depth=N``, ``put`` applies backpressure (blocks the walker) while more than
N undrained episodes are resident, and ``drop`` releases a consumed episode.
With the streaming dataflow (walk engine puts episodes as they complete, the
episode pipeline drops them once built into blocks) peak sample memory is
O(depth · episode), not O(epoch).
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np


class SampleStore:
    #: bounded-capacity knob: None = unbounded (seed behaviour); N = ``put``
    #: blocks while N undrained episodes are resident.
    depth: int | None = None

    def put(self, epoch: int, episode: int, pairs: np.ndarray) -> None:
        raise NotImplementedError

    def get(self, epoch: int, episode: int, *, block: bool = True) -> np.ndarray:
        raise NotImplementedError

    def finish_epoch(self, epoch: int) -> None:
        pass

    def episodes(self, epoch: int) -> int:
        raise NotImplementedError

    # ------------------------------------------------------------- draining
    def drop(self, epoch: int, episode: int) -> None:
        """Release one consumed episode (frees a backpressure slot)."""

    def drop_epoch(self, epoch: int) -> None:
        """Release every episode of an epoch plus its bookkeeping."""

    def abandon(self) -> None:
        """Terminal: the consumer died. Subsequent ``put``s are discarded
        without blocking, so a walker mid-epoch can run to completion (and
        ``finish_epoch``) instead of deadlocking on backpressure."""


class MemorySampleStore(SampleStore):
    """Thread-safe in-memory store; trainer blocks until the walker delivers.

    ``depth=N`` bounds resident (put-but-not-dropped) episodes: the walker's
    ``put`` blocks until the trainer ``drop``s. ``peak_resident`` records the
    high-water mark so tests can assert the bound actually held.
    """

    def __init__(self, depth: int | None = None):
        self.depth = depth
        self._data: dict[tuple[int, int], np.ndarray] = {}
        self._dropped: set[tuple[int, int]] = set()
        self._done: set[int] = set()
        self._counts: dict[int, int] = {}
        self._cv = threading.Condition()
        self._abandoned = False
        self.peak_resident = 0

    def put(self, epoch, episode, pairs):
        with self._cv:
            if self.depth is not None:
                while len(self._data) >= self.depth and not self._abandoned:
                    self._cv.wait(timeout=60.0)
            if self._abandoned:
                return
            self._data[(epoch, episode)] = pairs
            self._counts[epoch] = self._counts.get(epoch, 0) + 1
            self.peak_resident = max(self.peak_resident, len(self._data))
            self._cv.notify_all()

    def finish_epoch(self, epoch):
        with self._cv:
            self._done.add(epoch)
            self._cv.notify_all()

    def get(self, epoch, episode, *, block=True):
        with self._cv:
            while (epoch, episode) not in self._data:
                if (epoch, episode) in self._dropped:
                    raise KeyError((epoch, episode))  # consumed and released
                if not block or (epoch in self._done):
                    raise KeyError((epoch, episode))
                self._cv.wait(timeout=60.0)
            return self._data[(epoch, episode)]

    def episodes(self, epoch):
        with self._cv:
            while epoch not in self._done:
                self._cv.wait(timeout=60.0)
            return self._counts.get(epoch, 0)

    def drop(self, epoch, episode):
        with self._cv:
            if self._data.pop((epoch, episode), None) is not None:
                self._dropped.add((epoch, episode))
                self._cv.notify_all()

    def drop_epoch(self, epoch: int) -> None:
        with self._cv:
            for k in [k for k in self._data if k[0] == epoch]:
                del self._data[k]
            self._dropped = {k for k in self._dropped if k[0] != epoch}
            self._done.discard(epoch)
            self._counts.pop(epoch, None)
            self._cv.notify_all()

    def abandon(self) -> None:
        with self._cv:
            self._abandoned = True
            self._data.clear()
            self._cv.notify_all()


class DiskSampleStore(SampleStore):
    """Episode-partitioned .npy files, loaded with mmap (paper's SSD mode).

    ``get(block=True)`` polls for the episode file until it appears or the
    epoch's ``.done`` marker rules it out — the walker may still be writing
    (files are published atomically via rename). ``depth``/``drop`` give the
    same bounded contract as the memory store; ``keep=True`` (default)
    preserves the files on drop — they are the offline-mode artifact — while
    ``keep=False`` deletes them, bounding disk use for transient runs.
    ``fresh=True`` clears stale episode files and ``.done`` markers from a
    previous run at construction — REQUIRED when a walker reuses a directory,
    or consumers race the old run's markers / silently read its samples.
    """

    def __init__(self, root: str, *, depth: int | None = None,
                 keep: bool = True, poll_s: float = 0.005,
                 fresh: bool = False):
        self.root = root
        self.depth = depth
        self.keep = keep
        self.poll_s = poll_s
        os.makedirs(root, exist_ok=True)
        if fresh:
            for f in os.listdir(root):
                if (f.startswith("epoch")
                        and (f.endswith(".npy") or f.endswith(".done"))):
                    os.remove(os.path.join(root, f))
        self._cv = threading.Condition()
        self._resident: set[tuple[int, int]] = set()   # put-but-not-dropped
        self._dropped: set[tuple[int, int]] = set()
        self._produced: dict[int, int] = {}            # puts per epoch
        self._abandoned = False
        self.peak_resident = 0

    def _path(self, epoch, episode):
        return os.path.join(self.root, f"epoch{epoch:04d}_ep{episode:04d}.npy")

    def _done_path(self, epoch):
        return os.path.join(self.root, f"epoch{epoch:04d}.done")

    def put(self, epoch, episode, pairs):
        with self._cv:
            if self.depth is not None:
                while (len(self._resident) >= self.depth
                       and not self._abandoned):
                    self._cv.wait(timeout=60.0)
            if self._abandoned:
                return
            self._resident.add((epoch, episode))
            self._produced[epoch] = self._produced.get(epoch, 0) + 1
            self.peak_resident = max(self.peak_resident, len(self._resident))
        tmp = self._path(epoch, episode) + ".tmp.npy"
        np.save(tmp, pairs)
        os.replace(tmp, self._path(epoch, episode))

    def finish_epoch(self, epoch):
        with open(self._done_path(epoch), "w") as f:
            f.write("done")

    def get(self, epoch, episode, *, block=True):
        path = self._path(epoch, episode)
        while not os.path.exists(path):
            if (epoch, episode) in self._dropped:
                raise KeyError((epoch, episode))
            if not block or os.path.exists(self._done_path(epoch)):
                # the walker publishes the file BEFORE .done: re-check once so
                # a racing finish_epoch can't hide a file that just landed
                if os.path.exists(path):
                    break
                raise KeyError((epoch, episode))
            time.sleep(self.poll_s)
        return np.load(path, mmap_mode="r")

    def episodes(self, epoch):
        # like the memory store: wait for the walker to declare the epoch
        # complete, then report how many episodes were produced
        while not os.path.exists(self._done_path(epoch)):
            time.sleep(self.poll_s)
        with self._cv:
            if epoch in self._produced:      # we are the producing process
                return self._produced[epoch]
            # offline consumer: count files, adding back only episodes WE
            # dropped whose file is actually gone (keep=False)
            pre = f"epoch{epoch:04d}_ep"
            n = len([f for f in os.listdir(self.root)
                     if f.startswith(pre) and f.endswith(".npy")
                     and not f.endswith(".tmp.npy")])
            return n + len([k for k in self._dropped if k[0] == epoch
                            and not os.path.exists(self._path(*k))])

    def drop(self, epoch, episode):
        path = self._path(epoch, episode)
        with self._cv:
            if (epoch, episode) in self._dropped or not os.path.exists(path):
                return
            self._dropped.add((epoch, episode))
            if not self.keep:
                os.remove(path)
            self._resident.discard((epoch, episode))
            self._cv.notify_all()

    def drop_epoch(self, epoch: int) -> None:
        pre = f"epoch{epoch:04d}_ep"
        with self._cv:
            if not self.keep:
                for f in os.listdir(self.root):
                    if f.startswith(pre) and f.endswith(".npy"):
                        os.remove(os.path.join(self.root, f))
            self._dropped = {k for k in self._dropped if k[0] != epoch}
            self._resident = {k for k in self._resident if k[0] != epoch}
            self._produced.pop(epoch, None)
            self._cv.notify_all()

    def abandon(self) -> None:
        with self._cv:
            self._abandoned = True
            self._resident.clear()
            self._cv.notify_all()
