"""Sample store connecting the walk engine to the training engine (paper Fig. 2).

The two engines are decoupled: the walk engine `put`s episode-partitioned
sample arrays, the trainer `get`s them. Two backends mirror the paper's two
cluster modes (§IV-A): in-memory (fast clusters, samples stay resident) and
disk (slow clusters: offline files partitioned by episode, memory-mapped).
"""
from __future__ import annotations

import os
import threading

import numpy as np


class SampleStore:
    def put(self, epoch: int, episode: int, pairs: np.ndarray) -> None:
        raise NotImplementedError

    def get(self, epoch: int, episode: int, *, block: bool = True) -> np.ndarray:
        raise NotImplementedError

    def finish_epoch(self, epoch: int) -> None:
        pass

    def episodes(self, epoch: int) -> int:
        raise NotImplementedError


class MemorySampleStore(SampleStore):
    """Thread-safe in-memory store; trainer blocks until the walker delivers."""

    def __init__(self):
        self._data: dict[tuple[int, int], np.ndarray] = {}
        self._done: set[int] = set()
        self._cv = threading.Condition()

    def put(self, epoch, episode, pairs):
        with self._cv:
            self._data[(epoch, episode)] = pairs
            self._cv.notify_all()

    def finish_epoch(self, epoch):
        with self._cv:
            self._done.add(epoch)
            self._cv.notify_all()

    def get(self, epoch, episode, *, block=True):
        with self._cv:
            while (epoch, episode) not in self._data:
                if not block or (epoch in self._done):
                    raise KeyError((epoch, episode))
                self._cv.wait(timeout=60.0)
            return self._data[(epoch, episode)]

    def episodes(self, epoch):
        with self._cv:
            while epoch not in self._done:
                self._cv.wait(timeout=60.0)
            return len([k for k in self._data if k[0] == epoch])

    def drop_epoch(self, epoch: int) -> None:
        with self._cv:
            for k in [k for k in self._data if k[0] == epoch]:
                del self._data[k]
            self._done.discard(epoch)


class DiskSampleStore(SampleStore):
    """Episode-partitioned .npy files, loaded with mmap (paper's SSD mode)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, epoch, episode):
        return os.path.join(self.root, f"epoch{epoch:04d}_ep{episode:04d}.npy")

    def put(self, epoch, episode, pairs):
        tmp = self._path(epoch, episode) + ".tmp.npy"
        np.save(tmp, pairs)
        os.replace(tmp, self._path(epoch, episode))

    def finish_epoch(self, epoch):
        with open(os.path.join(self.root, f"epoch{epoch:04d}.done"), "w") as f:
            f.write("done")

    def get(self, epoch, episode, *, block=True):
        path = self._path(epoch, episode)
        if not os.path.exists(path):
            raise KeyError((epoch, episode))
        return np.load(path, mmap_mode="r")

    def episodes(self, epoch):
        pre = f"epoch{epoch:04d}_ep"
        return len([f for f in os.listdir(self.root) if f.startswith(pre) and f.endswith(".npy")])
