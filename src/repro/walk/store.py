"""Sample store connecting the walk engine to the training engine (paper Fig. 2).

The two engines are decoupled: the walk engine `put`s episode-partitioned
sample arrays, the trainer `get`s them. Two backends mirror the paper's two
cluster modes (§IV-A): in-memory (fast clusters, samples stay resident) and
disk (slow clusters: offline files partitioned by episode, memory-mapped).

Both backends implement a bounded-capacity contract: constructed with
``depth=N``, ``put`` applies backpressure (blocks the walker) while more than
N undrained episodes are resident, and ``drop`` releases a consumed episode.
With the streaming dataflow (walk engine puts episodes as they complete, the
episode pipeline drops them once built into blocks) peak sample memory is
O(depth · episode), not O(epoch).

Fault tolerance (``repro.runtime``): every wait loop runs under a watchdog
``Deadline`` — a producer that died without ``finish_epoch``/``abandon``
(liveness wired via :meth:`SampleStore.set_producer`, typically
``WalkEngine.alive``) or ``stall_timeout_s`` seconds without any store
progress raises a diagnostics-carrying ``StoreStalled`` instead of spinning
silently forever. Disk episode files are published atomically (tmp +
``os.replace``) with a CRC32 sidecar written *first*, so a reader that sees
the payload always sees its checksum; a short or corrupt payload raises
``CorruptEpisodeError``, which the episode pipeline treats as retriable
(re-walk the episode — bitwise identical by RNG keying — and
:meth:`DiskSampleStore.rewrite` the file). Fault sites: ``store.put``
(both backends), ``disk.write`` (a ``corrupt`` spec truncates the payload
after its checksum is recorded, simulating a torn write).
"""
from __future__ import annotations

import os
import threading
import time
import zlib

import numpy as np

from repro.obs import counter_add, gauge_set, observe, trace_counter
from repro.runtime import (CorruptEpisodeError, Deadline, fault_point)

#: default seconds without store progress before a blocked wait raises
#: ``StoreStalled`` (pass ``stall_timeout_s=None`` for the legacy
#: wait-forever behaviour; producer-liveness detection still applies)
DEFAULT_STALL_TIMEOUT_S = 600.0


class SampleStore:
    #: bounded-capacity knob: None = unbounded (seed behaviour); N = ``put``
    #: blocks while N undrained episodes are resident.
    depth: int | None = None

    #: producer-liveness probe (``set_producer``); None = unknown
    _producer = None
    #: optional producer description for stall diagnostics; None = unnamed
    _producer_info = None

    def set_producer(self, alive_fn, info_fn=None) -> None:
        """Wire a zero-arg producer-liveness probe (``WalkEngine.alive`` or
        ``HostHealth.any_alive`` for remote producers): a blocked
        ``get``/``episodes`` whose producer is dead fails with
        ``StoreStalled`` instead of waiting out the stall deadline.
        ``info_fn`` (e.g. ``HostHealth.describe``) renders the producer's
        state for the diagnostic, so a stall names the dead HOST."""
        self._producer = alive_fn
        self._producer_info = info_fn

    def put(self, epoch: int, episode: int, pairs: np.ndarray) -> None:
        raise NotImplementedError

    def put_unique(self, epoch: int, episode: int, pairs: np.ndarray) -> bool:
        """Idempotent ``put``: deliver the episode exactly once.

        Returns False — WITHOUT blocking or storing — when the episode is
        already resident, already consumed-and-dropped, or the store was
        abandoned; True when this call delivered it. This is the store-side
        half of the transport's exactly-once contract: a reconnecting
        producer resends everything unacked, and redelivery lands here as a
        no-op instead of a duplicate episode."""
        raise NotImplementedError

    def get(self, epoch: int, episode: int, *, block: bool = True) -> np.ndarray:
        raise NotImplementedError

    def accepted_episodes(self, epoch: int) -> list[int]:
        """Episodes of ``epoch`` this store has already accepted — resident
        OR consumed-and-dropped. This is the coordinator-failover recovery
        source: a restarted episode server re-derives its ordered-put
        cursor from the longest contiguous accepted prefix and only
        re-produces what the store never took (``repro.walk.remote``)."""
        return []

    def finish_epoch(self, epoch: int) -> None:
        pass

    def episodes(self, epoch: int) -> int:
        raise NotImplementedError

    # ------------------------------------------------------------- draining
    def drop(self, epoch: int, episode: int) -> None:
        """Release one consumed episode (frees a backpressure slot)."""

    def drop_epoch(self, epoch: int) -> None:
        """Release every episode of an epoch plus its bookkeeping."""

    def abandon(self) -> None:
        """Terminal: the consumer died. Subsequent ``put``s are discarded
        without blocking, so a walker mid-epoch can run to completion (and
        ``finish_epoch``) instead of deadlocking on backpressure."""


class MemorySampleStore(SampleStore):
    """Thread-safe in-memory store; trainer blocks until the walker delivers.

    ``depth=N`` bounds resident (put-but-not-dropped) episodes: the walker's
    ``put`` blocks until the trainer ``drop``s. ``peak_resident`` records the
    high-water mark so tests can assert the bound actually held.
    ``stall_timeout_s`` is the watchdog deadline on every wait loop,
    measured from the last store progress event (put/drop/finish), so a
    slow-but-moving pipeline never trips it.
    """

    def __init__(self, depth: int | None = None,
                 stall_timeout_s: float | None = DEFAULT_STALL_TIMEOUT_S):
        self.depth = depth
        self.stall_timeout_s = stall_timeout_s
        self._data: dict[tuple[int, int], np.ndarray] = {}
        self._dropped: set[tuple[int, int]] = set()
        self._done: set[int] = set()
        self._counts: dict[int, int] = {}
        self._cv = threading.Condition()
        self._abandoned = False
        self._version = 0              # progress counter for the watchdogs
        self.peak_resident = 0

    def _resident_keys(self):
        return list(self._data)

    def put(self, epoch, episode, pairs):
        fault_point("store.put", (epoch, episode))
        t0 = time.perf_counter()
        with self._cv:
            if self.depth is not None:
                # no producer probe here: put's stall means the CONSUMER
                # vanished without drop/abandon — only the progress
                # deadline can see that
                dl = Deadline(self.stall_timeout_s, op="put",
                              key=(epoch, episode),
                              resident=self._resident_keys)
                while len(self._data) >= self.depth and not self._abandoned:
                    dl.check(self._version)
                    self._cv.wait(timeout=dl.wait_s())
            if self._abandoned:
                return
            observe("store.put_wait_s", time.perf_counter() - t0)
            counter_add("store.puts")
            self._data[(epoch, episode)] = pairs
            self._counts[epoch] = self._counts.get(epoch, 0) + 1
            self.peak_resident = max(self.peak_resident, len(self._data))
            gauge_set("store.resident", len(self._data))
            trace_counter("store.resident", len(self._data))
            self._version += 1
            self._cv.notify_all()

    def put_unique(self, epoch, episode, pairs):
        with self._cv:
            k = (epoch, episode)
            if self._abandoned or k in self._data or k in self._dropped:
                return False
        # single delivery thread per store in the transport design, so the
        # check-then-put window is benign; a racing duplicate would merely
        # overwrite with bitwise-identical pairs
        self.put(epoch, episode, pairs)
        return True

    def accepted_episodes(self, epoch):
        with self._cv:
            return sorted({ep for (e, ep) in self._data if e == epoch}
                          | {ep for (e, ep) in self._dropped if e == epoch})

    def finish_epoch(self, epoch):
        with self._cv:
            self._done.add(epoch)
            self._version += 1
            self._cv.notify_all()

    def get(self, epoch, episode, *, block=True):
        t0 = time.perf_counter()
        with self._cv:
            dl = Deadline(self.stall_timeout_s, op="get",
                          key=(epoch, episode), producer=self._producer,
                          producer_info=self._producer_info,
                          resident=self._resident_keys)
            while (epoch, episode) not in self._data:
                if (epoch, episode) in self._dropped:
                    raise KeyError((epoch, episode))  # consumed and released
                if not block or (epoch in self._done):
                    raise KeyError((epoch, episode))
                dl.check(self._version, producer_done=epoch in self._done)
                self._cv.wait(timeout=dl.wait_s())
            observe("store.get_blocked_s", time.perf_counter() - t0)
            counter_add("store.gets")
            return self._data[(epoch, episode)]

    def episodes(self, epoch):
        with self._cv:
            dl = Deadline(self.stall_timeout_s, op="episodes", key=epoch,
                          producer=self._producer,
                          producer_info=self._producer_info,
                          resident=self._resident_keys)
            while epoch not in self._done:
                dl.check(self._version, producer_done=epoch in self._done)
                self._cv.wait(timeout=dl.wait_s())
            return self._counts.get(epoch, 0)

    def drop(self, epoch, episode):
        with self._cv:
            if self._data.pop((epoch, episode), None) is not None:
                self._dropped.add((epoch, episode))
                gauge_set("store.resident", len(self._data))
                trace_counter("store.resident", len(self._data))
                self._version += 1
                self._cv.notify_all()

    def drop_epoch(self, epoch: int) -> None:
        with self._cv:
            for k in [k for k in self._data if k[0] == epoch]:
                del self._data[k]
            self._dropped = {k for k in self._dropped if k[0] != epoch}
            self._done.discard(epoch)
            self._counts.pop(epoch, None)
            self._version += 1
            self._cv.notify_all()

    def abandon(self) -> None:
        with self._cv:
            self._abandoned = True
            self._data.clear()
            self._version += 1
            self._cv.notify_all()


class DiskSampleStore(SampleStore):
    """Episode-partitioned .npy files, loaded with mmap (paper's SSD mode).

    ``get(block=True)`` polls for the episode file until it appears, the
    epoch's ``.done`` marker rules it out, or the watchdog trips (producer
    dead / ``stall_timeout_s`` without progress → ``StoreStalled``). Files
    are published atomically: payload written to a tmp name, CRC32+length
    sidecar (``<file>.crc``) published first, then ``os.replace`` — so any
    visible payload has a visible checksum, and a torn/corrupt payload is
    detected at read time (``CorruptEpisodeError``, retriable via re-walk +
    :meth:`rewrite`). ``depth``/``drop`` give the same bounded contract as
    the memory store; ``keep=True`` (default) preserves the files on drop —
    they are the offline-mode artifact — while ``keep=False`` deletes them,
    bounding disk use for transient runs. ``fresh=True`` clears stale
    episode files, checksums and ``.done`` markers from a previous run at
    construction — REQUIRED when a walker reuses a directory, or consumers
    race the old run's markers / silently read its samples. ``verify=False``
    skips checksum verification in ``get`` (one extra sequential read of a
    page-cached file when on).
    """

    def __init__(self, root: str, *, depth: int | None = None,
                 keep: bool = True, poll_s: float = 0.005,
                 fresh: bool = False, verify: bool = True,
                 stall_timeout_s: float | None = DEFAULT_STALL_TIMEOUT_S):
        self.root = root
        self.depth = depth
        self.keep = keep
        self.poll_s = poll_s
        self.verify = verify
        self.stall_timeout_s = stall_timeout_s
        os.makedirs(root, exist_ok=True)
        if fresh:
            for f in os.listdir(root):
                if (f.startswith("epoch")
                        and f.endswith((".npy", ".done", ".crc"))):
                    os.remove(os.path.join(root, f))
        self._cv = threading.Condition()
        self._resident: set[tuple[int, int]] = set()   # put-but-not-dropped
        self._dropped: set[tuple[int, int]] = set()
        self._produced: dict[int, int] = {}            # puts per epoch
        self._abandoned = False
        self._version = 0
        self.peak_resident = 0

    def _path(self, epoch, episode):
        return os.path.join(self.root, f"epoch{epoch:04d}_ep{episode:04d}.npy")

    def _done_path(self, epoch):
        return os.path.join(self.root, f"epoch{epoch:04d}.done")

    def _resident_keys(self):
        with self._cv:
            return list(self._resident)

    # ------------------------------------------------------------ publishing
    def _publish(self, epoch, episode, pairs, *, corrupt: bool = False):
        """Atomic checksummed write: payload to tmp, sidecar first, then
        rename. ``corrupt`` (fault injection) truncates the payload AFTER
        its checksum is recorded — a torn write the reader must catch."""
        path = self._path(epoch, episode)
        tmp = path + ".tmp.npy"
        np.save(tmp, pairs)
        with open(tmp, "rb") as f:
            blob = f.read()
        crc_tmp = path + ".crc.tmp"
        with open(crc_tmp, "w") as f:
            f.write(f"{zlib.crc32(blob):08x} {len(blob)}")
        os.replace(crc_tmp, path + ".crc")
        # crash window between the two renames: a process dying RIGHT HERE
        # leaves the new sidecar visible with no (or a stale) payload — the
        # safe orientation, since a stale payload then fails its checksum
        # (CorruptEpisodeError, retriable) instead of silently passing. The
        # regression test crashes here and proves the invariant holds for
        # both put and rewrite.
        fault_point("disk.write", (epoch, episode, "publish"))
        if corrupt:
            with open(tmp, "wb") as f:
                f.write(blob[:max(0, len(blob) - 16)])
        os.replace(tmp, path)

    def put(self, epoch, episode, pairs):
        fault_point("store.put", (epoch, episode))
        t0 = time.perf_counter()
        with self._cv:
            if self.depth is not None:
                dl = Deadline(self.stall_timeout_s, op="put",
                              key=(epoch, episode),
                              resident=lambda: list(self._resident))
                while (len(self._resident) >= self.depth
                       and not self._abandoned):
                    dl.check(self._version)
                    self._cv.wait(timeout=dl.wait_s())
            if self._abandoned:
                return
            observe("store.put_wait_s", time.perf_counter() - t0)
            counter_add("store.puts")
            self._resident.add((epoch, episode))
            self._produced[epoch] = self._produced.get(epoch, 0) + 1
            self.peak_resident = max(self.peak_resident, len(self._resident))
            gauge_set("store.resident", len(self._resident))
            trace_counter("store.resident", len(self._resident))
            self._version += 1
        corrupt = fault_point("disk.write", (epoch, episode))
        self._publish(epoch, episode, pairs, corrupt=corrupt)
        with self._cv:
            self._cv.notify_all()

    def put_unique(self, epoch, episode, pairs):
        with self._cv:
            if self._abandoned or (epoch, episode) in self._dropped:
                return False
        if os.path.exists(self._path(epoch, episode)):
            return False
        self.put(epoch, episode, pairs)
        return True

    def rewrite(self, epoch, episode, pairs) -> None:
        """Re-publish one episode's payload (checksummed, atomic) without
        touching the resident/backpressure bookkeeping — the repair path
        after a ``CorruptEpisodeError`` re-walk."""
        self._publish(epoch, episode, pairs)

    def accepted_episodes(self, epoch):
        # published files survive a coordinator restart (the disk store's
        # whole point); in-process drops with keep=False deleted theirs, so
        # union the dropped set back in — after a real process death that
        # set is empty and the deleted prefix is simply re-produced
        pre = f"epoch{epoch:04d}_ep"
        eps = {int(f[len(pre):len(pre) + 4]) for f in os.listdir(self.root)
               if f.startswith(pre) and f.endswith(".npy")
               and not f.endswith(".tmp.npy")}
        with self._cv:
            eps |= {ep for (e, ep) in self._dropped if e == epoch}
        return sorted(eps)

    def finish_epoch(self, epoch):
        with open(self._done_path(epoch), "w") as f:
            f.write("done")
        with self._cv:
            self._version += 1

    # -------------------------------------------------------------- reading
    def _load_verified(self, epoch, episode):
        path = self._path(epoch, episode)
        if self.verify:
            crc_path = path + ".crc"
            try:
                with open(path, "rb") as f:
                    blob = f.read()
            except OSError as e:
                raise CorruptEpisodeError((epoch, episode), path,
                                          f"unreadable: {e}") from e
            if os.path.exists(crc_path):
                with open(crc_path) as f:
                    want_crc, want_len = f.read().split()
                if len(blob) != int(want_len):
                    raise CorruptEpisodeError(
                        (epoch, episode), path,
                        f"short file: {len(blob)} != {want_len} bytes")
                if f"{zlib.crc32(blob):08x}" != want_crc:
                    raise CorruptEpisodeError(
                        (epoch, episode), path,
                        f"checksum mismatch (want {want_crc})")
        try:
            return np.load(path, mmap_mode="r")
        except (ValueError, EOFError, OSError) as e:
            # unverifiable legacy file (no sidecar) that np.load rejects
            raise CorruptEpisodeError((epoch, episode), path,
                                      f"npy parse failed: {e}") from e

    def get(self, epoch, episode, *, block=True):
        path = self._path(epoch, episode)
        t0 = time.perf_counter()
        dl = Deadline(self.stall_timeout_s, op="get", key=(epoch, episode),
                      producer=self._producer,
                      producer_info=self._producer_info,
                      resident=self._resident_keys)
        next_check = time.monotonic()
        while not os.path.exists(path):
            if (epoch, episode) in self._dropped:
                raise KeyError((epoch, episode))
            done = os.path.exists(self._done_path(epoch))
            if not block or done:
                # the walker publishes the file BEFORE .done: re-check once so
                # a racing finish_epoch can't hide a file that just landed
                if os.path.exists(path):
                    break
                raise KeyError((epoch, episode))
            now = time.monotonic()
            if now >= next_check:
                dl.check(self._disk_version(epoch), producer_done=done)
                next_check = now + dl.wait_s()
            time.sleep(self.poll_s)
        observe("store.get_blocked_s", time.perf_counter() - t0)
        counter_add("store.gets")
        return self._load_verified(epoch, episode)

    def _disk_version(self, epoch):
        """Progress signal for cross-process waits: local bookkeeping plus
        the published-file count (an external producer writing files is
        progress even though our in-process counters never move)."""
        pre = f"epoch{epoch:04d}_ep"
        n = sum(1 for f in os.listdir(self.root)
                if f.startswith(pre) and f.endswith(".npy")
                and not f.endswith(".tmp.npy"))
        return (self._version, n)

    def episodes(self, epoch):
        # like the memory store: wait for the walker to declare the epoch
        # complete, then report how many episodes were produced
        dl = Deadline(self.stall_timeout_s, op="episodes", key=epoch,
                      producer=self._producer,
                      producer_info=self._producer_info,
                      resident=self._resident_keys)
        next_check = time.monotonic()
        while not os.path.exists(self._done_path(epoch)):
            now = time.monotonic()
            if now >= next_check:
                dl.check(self._disk_version(epoch))
                next_check = now + dl.wait_s()
            time.sleep(self.poll_s)
        with self._cv:
            if epoch in self._produced:      # we are the producing process
                return self._produced[epoch]
            # offline consumer: count files, adding back only episodes WE
            # dropped whose file is actually gone (keep=False)
            pre = f"epoch{epoch:04d}_ep"
            n = len([f for f in os.listdir(self.root)
                     if f.startswith(pre) and f.endswith(".npy")
                     and not f.endswith(".tmp.npy")])
            return n + len([k for k in self._dropped if k[0] == epoch
                            and not os.path.exists(self._path(*k))])

    def drop(self, epoch, episode):
        path = self._path(epoch, episode)
        with self._cv:
            if (epoch, episode) in self._dropped or not os.path.exists(path):
                return
            self._dropped.add((epoch, episode))
            if not self.keep:
                os.remove(path)
                if os.path.exists(path + ".crc"):
                    os.remove(path + ".crc")
            self._resident.discard((epoch, episode))
            gauge_set("store.resident", len(self._resident))
            trace_counter("store.resident", len(self._resident))
            self._version += 1
            self._cv.notify_all()

    def drop_epoch(self, epoch: int) -> None:
        pre = f"epoch{epoch:04d}_ep"
        with self._cv:
            if not self.keep:
                for f in os.listdir(self.root):
                    if f.startswith(pre) and f.endswith((".npy", ".crc")):
                        os.remove(os.path.join(self.root, f))
            self._dropped = {k for k in self._dropped if k[0] != epoch}
            self._resident = {k for k in self._resident if k[0] != epoch}
            self._produced.pop(epoch, None)
            self._version += 1
            self._cv.notify_all()

    def abandon(self) -> None:
        with self._cv:
            self._abandoned = True
            self._resident.clear()
            self._version += 1
            self._cv.notify_all()
