"""Remote walk producers: episode chunks over the fault-tolerant transport.

The paper runs walk generation on dedicated CPU machines and training on a
GPU cluster; this module crosses that boundary. Three roles:

* :class:`RemoteEpisodeServer` — trainer-side. Listens on a socket, hands
  out episode assignments from a lock-server-free work queue (the
  PyTorch-BigGraph shape: any producer can run any episode because the
  ``(seed, epoch, episode, chunk)`` RNG keying makes episodes
  location-independent), assembles arriving chunks exactly-once through a
  :class:`~repro.runtime.transport.ChunkAssembler`, and delivers completed
  episodes into the bounded :class:`~repro.walk.store.SampleStore` in
  episode order — matching the in-process ``WalkEngine.run_epoch`` put
  order exactly, so the trainer cannot tell the difference (test-gated
  bitwise). A :class:`~repro.runtime.transport.HostHealth` lease registry
  tracks producer heartbeats; an expired host's in-flight episodes are
  reclaimed and reassigned to survivors.
* :class:`RemoteProducer` — walker-side. Connects, asks for work, streams
  each assigned episode's chunks (pipelined, then drains acks), and on ANY
  transport failure — torn frame, injected ``net.disconnect``, ack timeout
  after a ``net.drop`` — reconnects and resends everything unacked.
  Redelivery is exactly-once at the server by the idempotence key, so the
  producer's recovery rule is maximally dumb: when in doubt, resend.
* :class:`RemoteWalkCoordinator` — the launcher's facade. Spawns N
  producers (subprocesses via multiprocessing ``spawn`` — real parallelism,
  sidestepping the GIL-bound in-process walker pool — or threads for
  tests), owns the server, and exposes ``epoch_walker()`` handles that
  mimic the ``WalkEngine`` async surface (``start_async``/``finished``/
  ``alive``/``join``) so ``launch.train`` swaps producers with one factory.

Fault sites: every CHUNK frame send runs the ``net.*`` sites keyed
``(epoch, episode, chunk)`` — control traffic (hello/heartbeat/work/acks)
is deliberately uninstrumented so ordinal-based specs target the
deterministic chunk stream, not timing-dependent polling.
``producer.episode`` fires at the top of each assigned episode, keyed
``(host, epoch, episode)``, so a chaos plan can kill one specific host.

Coordinator failover: the server itself is restartable. Its work-queue
state (pending/assigned episodes, the ordered-put cursor) is small and
fully reconstructible from the :class:`SampleStore` contents plus the
``(seed, epoch, episode, chunk)`` RNG keying — the same replay property
``--resume`` exploits for trainer crash-resume. A server built with
``recover=True`` scans the store at each epoch activation: the longest
contiguous prefix of already-accepted episodes becomes the put cursor
(complete episodes are never re-produced), everything after it is
re-queued for assignment (partial episodes replay bitwise via the RNG
keys; the fresh :class:`ChunkAssembler`'s dedup absorbs any chunks still
in flight from before the takeover — recovery needs no new wire state).
Producers, for their part, treat ANY server loss — connect refused, hello
timeout, dead heartbeat — as an outage to ride out: a jittered capped
exponential-backoff reconnect loop (:class:`~repro.runtime.retry.
RetryPolicy`, seeded per host so the fleet never thunders in lockstep)
resends everything unacked on reattach, and only gives up once the
outage outlives ``server_grace_s``. Killing the coordinator mid-epoch
and restarting it therefore resumes the epoch bitwise-identically to an
uninterrupted run (test- and CI-gated).
"""
from __future__ import annotations

import collections
import heapq
import multiprocessing as mp
import socket
import threading
import time
import zlib

from repro.obs import (counter_add, observe, register_source, span,
                       unregister_source)
from repro.obs import trace as _trace
from repro.runtime import FaultPlan, fault_point, install_plan
from repro.runtime.errors import InjectedFault, TransportError
from repro.runtime.retry import RetryPolicy
from repro.runtime.transport import (ChunkAssembler, FramedSocket, HostHealth,
                                     decode_pairs, encode_pairs)
from repro.walk.engine import WalkConfig, WalkEngine

#: producer poll interval while the server has no assignable episode
WAIT_POLL_S = 0.05


def _connect_once(address) -> socket.socket:
    """Single connect attempt; retry scheduling lives in the callers'
    :class:`RetryPolicy` loops (jittered, grace-bounded)."""
    s = socket.create_connection(address, timeout=5.0)
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s


class RemoteEpisodeServer:
    """Work-queue + chunk-assembly server feeding one :class:`SampleStore`.

    Epochs are produced strictly sequentially (``submit_epoch`` queues;
    the next activates when the current fully lands), mirroring the
    launcher's one-producing-epoch-at-a-time overlap. Within an epoch the
    assignment window bounds run-ahead: an episode is handed out only while
    ``episode - next_put < window``, so completed-but-unput episodes held
    for ordered delivery stay O(window), and the store's own ``depth``
    backpressure (applied in the dedicated put thread) paces everything
    upstream of it.
    """

    def __init__(self, store, num_episodes: int, seed: int, *,
                 lease_s: float = 10.0, window: int | None = None,
                 port: int = 0, recover: bool = False,
                 carry_stats: dict | None = None):
        self.store = store
        self.num_episodes = num_episodes
        self.seed = seed
        self.health = HostHealth(lease_s)
        self.assembler = ChunkAssembler()
        depth = getattr(store, "depth", None)
        self.window = window or max(2, (depth or 2) + 1)
        # Failover: a recovering successor re-derives each epoch's put
        # cursor from store.accepted_episodes() at activation instead of
        # starting from 0 — see _activate_locked. carry_stats folds a dead
        # predecessor's transport aggregates into this server's, so
        # bench/diagnostics deltas stay monotonic across a takeover.
        self.recover_mode = recover
        self.recovered_episodes = 0
        self._dup_base = 0
        self._applied_base = 0
        self._t0 = time.monotonic()
        #: wall seconds from construction to the first applied (non-dup)
        #: chunk — the bench's recovery-time-to-first-chunk metric
        self.first_chunk_s: float | None = None
        if recover:
            counter_add("failover.takeovers")
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        self._epoch: int | None = None
        self._epoch_queue: collections.deque[int] = collections.deque()
        self._pending: collections.deque[int] = collections.deque()
        self._assigned: dict[int, str] = {}
        self._ready: list = []                 # heap of (episode, pairs)
        self._next_put = 0
        self._finished_epochs: set[int] = set()
        self._error: BaseException | None = None
        self._shutdown = False
        self._stop_evt = threading.Event()
        self._conns: list[FramedSocket] = []
        self._closed_stats = {"frames_recv": 0, "bytes_recv": 0,
                              "frames_sent": 0, "bytes_sent": 0}
        if carry_stats:
            for k in self._closed_stats:
                self._closed_stats[k] += carry_stats.get(k, 0)
            self._dup_base = carry_stats.get("dup_chunks", 0)
            self._applied_base = carry_stats.get("chunks_applied", 0)
        # first-chunk arrival time per (host, epoch, episode), for the
        # per-host receive-lane trace spans; one writer thread per episode
        # (its host's connection), so no lock needed
        self._recv_t0: dict[tuple, float] = {}
        self._threads: list[threading.Thread] = []
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind(("127.0.0.1", port))
        self._lsock.listen(64)
        # timeout-polling accept: closing a listener does not reliably wake
        # a thread blocked in accept(), so poll with a short timeout and
        # check the stop event between attempts
        self._lsock.settimeout(0.25)
        self.address = self._lsock.getsockname()

    # ---------------------------------------------------------------- lifecycle
    def start(self) -> None:
        for target, name in ((self._accept_loop, "rws-accept"),
                             (self._put_loop, "rws-put"),
                             (self._reclaim_loop, "rws-reclaim")):
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def stop_work(self) -> None:
        """Stop handing out assignments: subsequent ``work`` requests get
        ``done``, so producers drain and exit cleanly while the sockets
        stay open. Call before :meth:`close`."""
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        self._stop_evt.set()

    def close(self) -> None:
        self.stop_work()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._mu:
            conns = list(self._conns)
        for c in conns:
            c.close()
        for t in self._threads:
            t.join(timeout=5.0)

    def kill(self) -> None:
        """SIGKILL-equivalent stop for failover tests and the bench: drop
        the listener and every connection WITHOUT the ``stop_work`` drain
        handshake, so producers observe a dead server (connection errors),
        never a clean ``done``. The work-queue state dies with this object;
        a successor built with ``recover=True`` on the same port
        reconstructs it from the store."""
        self._stop_evt.set()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._mu:
            conns = list(self._conns)
        for c in conns:
            c.close()
        # only after the sockets are dead: a live producer must never win a
        # race and see the shutdown "done" reply from a killed server
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        # short join: a put thread blocked on store backpressure only wakes
        # at the next consumer drop; it re-checks _shutdown then and exits
        # (its in-flight put is idempotent at the store), so don't stall
        # the takeover on it
        for t in self._threads:
            t.join(timeout=1.0)

    # ------------------------------------------------------------------ epochs
    def submit_epoch(self, epoch: int) -> None:
        finished: list[int] = []
        with self._cv:
            if self._error is not None:
                raise self._error
            if (epoch == self._epoch or epoch in self._finished_epochs
                    or epoch in self._epoch_queue):
                pass          # idempotent resubmission (coordinator takeover)
            elif self._epoch is None:
                finished = self._activate_locked(epoch)
            else:
                self._epoch_queue.append(epoch)
            self._cv.notify_all()
        for e in finished:     # store calls stay outside the lock
            self.store.finish_epoch(e)

    def _activate_locked(self, epoch: int) -> list[int]:
        """Make ``epoch`` the producing epoch. In recovery mode, scan the
        store first: the longest contiguous prefix of already-accepted
        episodes becomes the put cursor (never re-produced); the rest is
        re-queued and replayed bitwise via the RNG keys. An epoch the store
        already holds in full finishes immediately and the next queued one
        activates — returns those epochs so the caller can run their
        ``store.finish_epoch`` outside the lock."""
        done: list[int] = []
        while True:
            base = 0
            if self.recover_mode:
                accepted = set(self.store.accepted_episodes(epoch))
                while base < self.num_episodes and base in accepted:
                    base += 1
                if base:
                    self.recovered_episodes += base
                    counter_add("failover.recovered_episodes", base)
                    print(f"remote-walk: takeover of epoch {epoch}: store "
                          f"already accepted episodes [0..{base}); "
                          f"re-producing {self.num_episodes - base}")
            self._epoch = epoch
            self._pending = collections.deque(range(base, self.num_episodes))
            self._assigned = {}
            self._ready = []
            self._next_put = base
            if base < self.num_episodes:
                return done
            # the whole epoch landed before the takeover
            self._finished_epochs.add(epoch)
            self._epoch = None
            done.append(epoch)
            if not self._epoch_queue:
                return done
            epoch = self._epoch_queue.popleft()

    def epoch_finished(self, epoch: int) -> bool:
        with self._mu:
            return epoch in self._finished_epochs

    def wait_epoch(self, epoch: int, timeout_s: float | None = None) -> None:
        """Block until ``epoch`` has fully landed in the store; re-raise the
        recorded production error, if any — the facade's ``join``.

        Checks are ordered so a failed server is never mistaken for a slow
        one: the recorded error re-raises the moment it is set (even when
        the timeout happens to be due at the same wake), and a server shut
        down before the epoch landed fails fast instead of waiting out
        ``timeout_s``."""
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        with self._cv:
            while True:
                if self._error is not None:
                    raise self._error
                if epoch in self._finished_epochs:
                    return
                if self._shutdown:
                    raise TransportError(
                        f"episode server shut down before epoch {epoch} "
                        "was produced")
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(f"epoch {epoch} not produced in time")
                self._cv.wait(timeout=0.25)

    def _fail(self, err: BaseException) -> None:
        """Record a terminal production error and fail consumers fast —
        the remote mirror of ``WalkEngine.start_async``'s error path."""
        with self._cv:
            if self._error is None:
                self._error = err
            epoch = self._epoch
            self._cv.notify_all()
        if epoch is not None:
            self.store.finish_epoch(epoch)

    # --------------------------------------------------------------- put thread
    def _put_loop(self) -> None:
        try:
            while True:
                with self._cv:
                    while not (self._shutdown
                               or (self._epoch is not None and self._ready
                                   and self._ready[0][0] == self._next_put)):
                        self._cv.wait(timeout=0.25)
                    if self._shutdown:
                        return
                    epoch = self._epoch
                    ep, pairs = heapq.heappop(self._ready)
                # store.put may block on backpressure — outside the lock so
                # chunk handlers / assignment keep running meanwhile
                with span("store_put", "store",
                          {"epoch": epoch, "episode": ep}):
                    self.store.put_unique(epoch, ep, pairs)
                finished: list[int] = []
                with self._cv:
                    self._next_put += 1
                    done = self._next_put >= self.num_episodes
                    if done:
                        self._finished_epochs.add(epoch)
                        self._epoch = None
                        if self._epoch_queue:
                            finished = self._activate_locked(
                                self._epoch_queue.popleft())
                    self._cv.notify_all()
                if done:
                    self.store.finish_epoch(epoch)
                for e in finished:
                    self.store.finish_epoch(e)
        except BaseException as e:  # noqa: BLE001 — any put failure is terminal
            self._fail(e)

    # ----------------------------------------------------------- reclaim thread
    def _reclaim_loop(self) -> None:
        poll = max(0.1, self.health.lease_s / 4)
        while True:
            if self._stop_evt.wait(timeout=poll):
                return
            for host in self.health.expired():
                self.health.mark_dead(host)
                with self._cv:
                    lost = sorted(ep for ep, h in self._assigned.items()
                                  if h == host)
                    for ep in reversed(lost):
                        del self._assigned[ep]
                        self._pending.appendleft(ep)
                    self._cv.notify_all()
                if lost:
                    print(f"remote-walk: host {host!r} lease expired; "
                          f"reassigning episodes {lost} to survivors")
            with self._cv:
                epoch_active = self._epoch is not None
            if epoch_active and self.health.hosts() \
                    and not self.health.any_alive():
                self._fail(TransportError(
                    "all remote producer hosts are dead "
                    f"[{self.health.describe()}]"))
                return

    # ------------------------------------------------------------- connections
    def _accept_loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                s, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                          # listener closed: shutting down
            s.settimeout(None)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = FramedSocket(s)
            with self._mu:
                self._conns.append(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="rws-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: FramedSocket) -> None:
        try:
            while True:
                msg, body = conn.recv()
                reply = self._dispatch(msg, body)
                if reply is None:               # bye
                    break
                conn.send(reply)
        except (TransportError, ConnectionError, OSError):
            pass                                # producer will reconnect
        finally:
            with self._mu:
                if conn in self._conns:
                    self._conns.remove(conn)
                st = conn.stats()
                for k in self._closed_stats:
                    self._closed_stats[k] += st.get(k, 0)
            conn.close()

    def _dispatch(self, msg: dict, body: bytes) -> dict | None:
        t = msg.get("t")
        host = msg.get("host", "?")
        self.health.beat(host)
        if t in ("hello", "hb"):
            if t == "hb":
                counter_add("transport.heartbeats")
            return {"t": "ok", "seed": self.seed}
        if t == "bye":
            return None
        if t == "work":
            return self._assign(host)
        if t == "chunk":
            return self._chunk(msg, body)
        raise TransportError(f"unknown message type {t!r}")

    def _assign(self, host: str) -> dict:
        with self._cv:
            if self._shutdown or self._error is not None:
                return {"t": "done"}
            if (self._epoch is not None and self._pending
                    and self._pending[0] - self._next_put < self.window):
                ep = self._pending.popleft()
                self._assigned[ep] = host
                return {"t": "assign", "epoch": self._epoch, "episode": ep}
            return {"t": "wait", "poll_s": WAIT_POLL_S}

    def _chunk(self, msg: dict, body: bytes) -> dict:
        epoch, ep = msg["epoch"], msg["episode"]
        if msg["seed"] != self.seed:
            raise TransportError(
                f"producer seed {msg['seed']} != server seed {self.seed}")
        dup, assembled = self.assembler.add(
            msg["seed"], epoch, ep, msg["chunk"], msg["nchunks"],
            decode_pairs(msg, body))
        counter_add("transport.chunks_recv")
        if dup:
            counter_add("transport.dup_chunks")
        elif self.first_chunk_s is None:
            # benign write race between connection threads: both candidates
            # are within microseconds, either is a valid recovery-time mark
            self.first_chunk_s = time.monotonic() - self._t0
        complete = assembled is not None
        tr = _trace.tracer()
        if tr is not None:
            host = msg.get("host", "?")
            k = (host, epoch, ep)
            t0 = self._recv_t0.setdefault(k, tr.now_us())
            if complete:
                self._recv_t0.pop(k, None)
                tr.add_span("recv_episode", f"host:{host}", t0, tr.now_us(),
                            {"epoch": epoch, "episode": ep,
                             "nchunks": msg["nchunks"]})
        if complete:
            with self._cv:
                if epoch == self._epoch and ep >= self._next_put:
                    heapq.heappush(self._ready, (ep, assembled))
                    self._assigned.pop(ep, None)
                    self._cv.notify_all()
        return {"t": "ack", "epoch": epoch, "episode": ep,
                "chunk": msg["chunk"], "dup": dup, "complete": complete}

    # ------------------------------------------------------------------- stats
    def transport_stats(self) -> dict:
        with self._mu:
            agg = dict(self._closed_stats)
            for c in self._conns:
                st = c.stats()
                for k in agg:
                    agg[k] += st.get(k, 0)
        agg["dup_chunks"] = self.assembler.dup_chunks + self._dup_base
        agg["chunks_applied"] = (self.assembler.chunks_applied
                                 + self._applied_base)
        applied = max(1, agg["chunks_applied"])
        agg["resend_rate"] = agg["dup_chunks"] / applied
        return agg


class RemoteProducer:
    """One walk-producer host: ask for work, walk it, ship it, survive.

    Runs the store-free :class:`WalkEngine` generation surface
    (``episode_chunk_stream``) so its chunks carry exactly the RNG keys the
    in-process engine would use. All chunks of an assigned episode are
    pipelined onto the wire, then their acks drained; any transport failure
    (including an ack timeout after an injected ``net.drop``) triggers
    reconnect-and-resend of the unacked remainder.

    Server loss — connect refused, hello timeout, dead socket — is an
    outage to ride out, not a death sentence: reconnects follow a jittered
    capped exponential backoff (seeded per host, so a fleet of producers
    desynchronizes instead of thundering against a restarting coordinator)
    and only give up once one outage exceeds ``server_grace_s`` seconds.
    """

    def __init__(self, address, host: str, graph, wcfg: WalkConfig, *,
                 heartbeat_s: float = 1.0, ack_timeout_s: float = 10.0,
                 connect_timeout_s: float = 30.0,
                 server_grace_s: float = 30.0):
        self.address = tuple(address)
        self.host = host
        self.engine = WalkEngine(graph, wcfg)
        self.wcfg = wcfg
        self.heartbeat_s = heartbeat_s
        self.ack_timeout_s = ack_timeout_s
        self.connect_timeout_s = connect_timeout_s
        self.server_grace_s = server_grace_s
        self._conn: FramedSocket | None = None
        self.reconnects = 0
        self.chunks_resent = 0
        self.outage_s = 0.0             # cumulative seconds disconnected
        self._outage_t0: float | None = None
        self._ever_connected = False
        self._retry = RetryPolicy(
            attempts=None, backoff_s=0.05, mult=2.0, max_backoff_s=1.0,
            jitter=0.5, retry_on=(TransportError, ConnectionError, OSError))
        # deterministic per host, decorrelated across hosts
        self._retry_seed = zlib.crc32(host.encode())

    # -------------------------------------------------------------- connection
    def _connection(self) -> FramedSocket:
        """Current work connection, (re)established under the backoff
        policy. A dead server is tolerated for ``server_grace_s`` seconds
        per outage — measured from the moment the connection was lost, not
        from this call — then the last connection error propagates. The
        first-ever connection uses ``connect_timeout_s`` instead (that is a
        startup race against the server's listen(), not an outage)."""
        if self._conn is not None:
            return self._conn
        window = (self.server_grace_s if self._ever_connected
                  else self.connect_timeout_s)
        # _outage_t0 is set by _drop_connection when a live connection is
        # lost; None here means this is the startup connect (not an outage)
        outage = self._outage_t0 is not None
        t0 = self._outage_t0 if outage else time.monotonic()
        delays = self._retry.delays(seed=self._retry_seed + self.reconnects)
        while True:
            s = None
            try:
                s = _connect_once(self.address)
                s.settimeout(self.ack_timeout_s)
                conn = FramedSocket(s)
                conn.send({"t": "hello", "host": self.host})
                conn.recv()             # hello timeout == ack timeout
                self._conn = conn
                self._ever_connected = True
                if outage:
                    dt = time.monotonic() - t0
                    self.outage_s += dt
                    observe("producer.outage_s", dt)
                    self._outage_t0 = None
                return conn
            except (TransportError, ConnectionError, OSError) as e:
                if s is not None:
                    try:
                        s.close()
                    except OSError:
                        pass
                waited = time.monotonic() - t0
                if waited >= window:
                    raise TransportError(
                        f"host {self.host!r}: server {self.address!r} "
                        f"unreachable for {waited:.1f}s (> grace "
                        f"{window:.1f}s): {e}") from e
                time.sleep(next(delays, self._retry.backoff_s))

    def _drop_connection(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None
            self.reconnects += 1
            counter_add("transport.producer_reconnects")
        if self._outage_t0 is None:
            self._outage_t0 = time.monotonic()

    # -------------------------------------------------------------- heartbeats
    def _heartbeat_loop(self, stop: threading.Event) -> None:
        # dedicated connection: a long GIL-heavy walk on the work connection
        # must not starve the lease — heartbeats ride their own socket and
        # are never fault-injected. While the server is unreachable, retry
        # pacing comes from the jittered backoff policy (capped at the
        # heartbeat interval) instead of a bare wait(heartbeat_s), so the
        # fleet's reattach probes spread out across a takeover.
        conn = None
        delays = None
        while not stop.is_set():
            try:
                if conn is None:
                    s = _connect_once(self.address)
                    s.settimeout(self.ack_timeout_s)
                    conn = FramedSocket(s)
                conn.send({"t": "hb", "host": self.host})
                conn.recv()
                delays = None                   # healthy: reset the backoff
                wait = self.heartbeat_s
            except (TransportError, ConnectionError, OSError):
                if conn is not None:
                    conn.close()
                conn = None
                if delays is None:
                    delays = self._retry.delays(
                        seed=self._retry_seed ^ 0x5BEA7)
                wait = min(self.heartbeat_s,
                           next(delays, self.heartbeat_s))
            stop.wait(wait)
        if conn is not None:
            conn.close()

    # -------------------------------------------------------------- work loop
    def run(self) -> None:
        stop = threading.Event()
        hb = threading.Thread(target=self._heartbeat_loop, args=(stop,),
                              name=f"hb-{self.host}", daemon=True)
        hb.start()
        try:
            while True:
                # outside the retry except: a _connection() failure means
                # the outage outlived the grace window — terminal, and the
                # informative grace error must propagate, not be retried
                conn = self._connection()
                try:
                    conn.send({"t": "work", "host": self.host})
                    reply, _ = conn.recv()
                    # a duplicated final chunk can leave one stray ack in
                    # flight after the drain loop already saw the episode
                    # fully acked — skip past it
                    while reply.get("t") == "ack":
                        reply, _ = conn.recv()
                except (TransportError, ConnectionError, OSError):
                    self._drop_connection()
                    continue
                t = reply.get("t")
                if t == "done":
                    break
                if t == "wait":
                    time.sleep(reply.get("poll_s", WAIT_POLL_S))
                    continue
                epoch, episode = reply["epoch"], reply["episode"]
                fault_point("producer.episode", (self.host, epoch, episode))
                self._ship_episode(epoch, episode)
        finally:
            stop.set()
            hb.join(timeout=5.0)
            if self._conn is not None:
                try:
                    self._conn.send({"t": "bye", "host": self.host})
                except (TransportError, ConnectionError, OSError):
                    pass
                self._conn.close()
                self._conn = None

    def _ship_episode(self, epoch: int, episode: int) -> None:
        tr = _trace.tracer()
        t_ship = tr.now_us() if tr is not None else 0.0
        chunks = list(self.engine.episode_chunk_stream(epoch, episode))
        acked: set[int] = set()
        attempts = 0
        while len(acked) < len(chunks):
            attempts += 1
            if attempts > 10:
                raise TransportError(
                    f"episode ({epoch}, {episode}): gave up after "
                    f"{attempts - 1} transport attempts")
            if attempts > 1:
                self.chunks_resent += len(chunks) - len(acked)
                counter_add("transport.chunks_resent",
                            len(chunks) - len(acked))
            # grace-window exhaustion in _connection() is terminal and must
            # escape with its own error, not count as a transport attempt
            conn = self._connection()
            try:
                for c, n, pairs in chunks:
                    if c in acked:
                        continue
                    meta, body = encode_pairs(pairs)
                    conn.send({"t": "chunk", "host": self.host,
                               "seed": self.wcfg.seed, "epoch": epoch,
                               "episode": episode, "chunk": c, "nchunks": n,
                               **meta},
                              body, key=(epoch, episode, c), inject=True)
                # drain until every chunk is acked — set-idempotent, so a
                # duplicated frame's double ack is absorbed rather than
                # desynchronizing the reply stream; a dropped frame's
                # missing ack surfaces as a recv timeout below
                while len(acked) < len(chunks):
                    reply, _ = conn.recv()
                    if reply.get("t") != "ack":
                        raise TransportError(
                            f"expected ack, got {reply.get('t')!r}")
                    acked.add(reply["chunk"])
            except (TransportError, ConnectionError, OSError):
                # includes socket timeouts waiting on the ack of a dropped
                # frame: reconnect and resend whatever is unacked — the
                # server's idempotence keys discard anything that DID land
                self._drop_connection()
        counter_add("walk.episodes_shipped")
        if tr is not None:
            # walk + ship + ack-drain for one assigned episode, on this
            # producer's lane (thread-mode producers share the trainer's
            # tracer; subprocess producers run with obs disabled)
            tr.add_span("ship_episode", "producer:" + self.host, t_ship,
                        tr.now_us(), {"epoch": epoch, "episode": episode,
                                      "chunks": len(chunks),
                                      "attempts": attempts})


def _producer_main(address, host, graph, wcfg, inject_specs, heartbeat_s,
                   server_grace_s=30.0):
    """Subprocess entry (multiprocessing ``spawn``): fresh interpreter, own
    fault-plan counters, no jax import anywhere on this path."""
    if inject_specs:
        install_plan(FaultPlan(inject_specs))
    RemoteProducer(address, host, graph, wcfg, heartbeat_s=heartbeat_s,
                   server_grace_s=server_grace_s).run()


class _EpochHandle:
    """One epoch's walker, shaped like the ``WalkEngine`` async surface."""

    def __init__(self, coord: "RemoteWalkCoordinator"):
        self._coord = coord
        self._epoch: int | None = None

    def start_async(self, epoch: int) -> None:
        self._epoch = epoch
        self._coord.server.submit_epoch(epoch)

    def finished(self) -> bool:
        return (self._epoch is None
                or self._coord.server.epoch_finished(self._epoch)
                or self._coord.server._error is not None)

    def alive(self) -> bool:
        return self._coord.alive()

    def join(self) -> None:
        if self._epoch is not None:
            self._coord.server.wait_epoch(self._epoch)


class RemoteWalkCoordinator:
    """Owns the server plus N producers; hands ``launch.train`` walker
    handles indistinguishable from ``WalkEngine``.

    ``mode="process"`` spawns real subprocess producers (the GIL-free
    path); ``mode="thread"`` runs them as in-process threads — same
    protocol, same sockets, cheap enough for tests.
    """

    def __init__(self, graph, wcfg: WalkConfig, store, *,
                 num_producers: int = 2, heartbeat_s: float = 1.0,
                 lease_s: float = 10.0, mode: str = "process",
                 ack_timeout_s: float = 10.0, inject_specs=(),
                 port: int = 0, recover: bool = False,
                 server_grace_s: float = 30.0):
        self.graph = graph
        self.wcfg = wcfg
        self.store = store
        self.num_producers = max(1, num_producers)
        self.heartbeat_s = heartbeat_s
        self.ack_timeout_s = ack_timeout_s
        self.mode = mode
        self.inject_specs = list(inject_specs)
        self.lease_s = lease_s
        self.server_grace_s = server_grace_s
        self.server = RemoteEpisodeServer(store, wcfg.episodes, wcfg.seed,
                                          lease_s=lease_s, port=port,
                                          recover=recover)
        self.takeovers = 1 if recover else 0
        self._recovered_base = 0
        self._procs: list = []
        self._producers: list[RemoteProducer] = []   # thread mode only

    def start(self) -> None:
        self.server.start()
        # one source of truth for the wire + lease surfaces: the registry
        # snapshot (metrics.jsonl, diagnostics.json) reads the live
        # aggregation instead of anyone keeping a parallel copy
        register_source("transport", self.transport_stats)
        # read through self.server dynamically — a restart_server() swap
        # must not leave the registry or the store watchdog holding bound
        # methods of a dead server's health registry
        register_source("host_health", lambda: self.server.health.snapshot())
        set_producer = getattr(self.store, "set_producer", None)
        if callable(set_producer):
            set_producer(self.alive, lambda: self.server.health.describe())
        for i in range(self.num_producers):
            host = f"walker-{i}"
            if self.mode == "process":
                ctx = mp.get_context("spawn")
                p = ctx.Process(
                    target=_producer_main,
                    args=(self.server.address, host, self.graph, self.wcfg,
                          self.inject_specs, self.heartbeat_s,
                          self.server_grace_s),
                    name=host, daemon=True)
                p.start()
            else:
                prod = RemoteProducer(self.server.address, host, self.graph,
                                      self.wcfg, heartbeat_s=self.heartbeat_s,
                                      ack_timeout_s=self.ack_timeout_s,
                                      server_grace_s=self.server_grace_s)
                self._producers.append(prod)

                def _run(prod=prod):
                    # An injected crash simulates a SIGKILL'd producer
                    # process, and a grace-window TransportError a producer
                    # that gave up on a dead server: either way the thread
                    # must die silently (liveness is detected via the
                    # lease, not the exception) — exactly like the
                    # subprocess path, where the process just exits. Any
                    # other exception still escapes to the caller.
                    try:
                        prod.run()
                    except (InjectedFault, TransportError):
                        pass

                p = threading.Thread(target=_run, name=host, daemon=True)
                p.start()
            self._procs.append(p)

    def epoch_walker(self) -> _EpochHandle:
        return _EpochHandle(self)

    def alive(self) -> bool:
        """Producer-liveness probe for the store watchdog: healthy while
        any host's lease is live (or none has registered yet) and the
        server hasn't recorded a terminal error."""
        return self.server._error is None and self.server.health.any_alive()

    def transport_stats(self) -> dict:
        return self.server.transport_stats()

    # -------------------------------------------------------------- failover
    def restart_server(self) -> float:
        """Simulated coordinator failover inside one process: a
        SIGKILL-equivalent drop of the current episode server, then a
        successor on the SAME port that reconstructs the work queue from
        the store and re-submits the epochs the trainer had handed the
        predecessor. Producers are untouched — they ride out the outage in
        their reconnect backoff and reattach to the successor. Returns the
        takeover wall seconds (kill → successor accepting).

        The full-process-death path is ``--coordinator-resume``: there the
        launcher itself builds a ``recover=True`` coordinator and
        re-submits epochs from the resume cursor instead."""
        old = self.server
        t0 = time.monotonic()
        old.kill()
        # trainer-side knowledge that survives in this process: which
        # epochs were submitted and which already finished. The successor
        # re-derives everything else (put cursor, pending set) from the
        # store at activation.
        with old._cv:
            finished = set(old._finished_epochs)
            epochs = ([old._epoch] if old._epoch is not None else [])
            epochs += list(old._epoch_queue)
        srv = RemoteEpisodeServer(
            self.store, self.wcfg.episodes, self.wcfg.seed,
            lease_s=self.lease_s, port=old.address[1], recover=True,
            carry_stats=old.transport_stats())
        srv._finished_epochs |= finished
        self.server = srv
        self.takeovers += 1
        self._recovered_base += old.recovered_episodes
        srv.start()
        for e in epochs:
            srv.submit_epoch(e)
        return time.monotonic() - t0

    def failover_stats(self) -> dict:
        """Takeover counters for diagnostics.json and the bench row.
        ``producer_outage_s`` only aggregates thread-mode producers —
        subprocess producers keep their clocks in their own interpreter."""
        out = {"takeovers": self.takeovers,
               "recovered_episodes": (self._recovered_base
                                      + self.server.recovered_episodes),
               "producer_reconnects": sum(p.reconnects
                                          for p in self._producers),
               "producer_outage_s": round(sum(p.outage_s
                                              for p in self._producers), 3)}
        if self.server.first_chunk_s is not None:
            out["first_chunk_s"] = round(self.server.first_chunk_s, 3)
        return out

    def close(self) -> None:
        # drain first: producers see "done" on their next work request and
        # exit on their own; only then tear the sockets down
        self.server.stop_work()
        for p in self._procs:
            p.join(timeout=10.0)
        self.server.close()
        for p in self._procs:
            if hasattr(p, "terminate") and p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
        self._procs = []
        unregister_source("transport")
        unregister_source("host_health")
