"""Remote walk producers: episode chunks over the fault-tolerant transport.

The paper runs walk generation on dedicated CPU machines and training on a
GPU cluster; this module crosses that boundary. Three roles:

* :class:`RemoteEpisodeServer` — trainer-side. Listens on a socket, hands
  out episode assignments from a lock-server-free work queue (the
  PyTorch-BigGraph shape: any producer can run any episode because the
  ``(seed, epoch, episode, chunk)`` RNG keying makes episodes
  location-independent), assembles arriving chunks exactly-once through a
  :class:`~repro.runtime.transport.ChunkAssembler`, and delivers completed
  episodes into the bounded :class:`~repro.walk.store.SampleStore` in
  episode order — matching the in-process ``WalkEngine.run_epoch`` put
  order exactly, so the trainer cannot tell the difference (test-gated
  bitwise). A :class:`~repro.runtime.transport.HostHealth` lease registry
  tracks producer heartbeats; an expired host's in-flight episodes are
  reclaimed and reassigned to survivors.
* :class:`RemoteProducer` — walker-side. Connects, asks for work, streams
  each assigned episode's chunks (pipelined, then drains acks), and on ANY
  transport failure — torn frame, injected ``net.disconnect``, ack timeout
  after a ``net.drop`` — reconnects and resends everything unacked.
  Redelivery is exactly-once at the server by the idempotence key, so the
  producer's recovery rule is maximally dumb: when in doubt, resend.
* :class:`RemoteWalkCoordinator` — the launcher's facade. Spawns N
  producers (subprocesses via multiprocessing ``spawn`` — real parallelism,
  sidestepping the GIL-bound in-process walker pool — or threads for
  tests), owns the server, and exposes ``epoch_walker()`` handles that
  mimic the ``WalkEngine`` async surface (``start_async``/``finished``/
  ``alive``/``join``) so ``launch.train`` swaps producers with one factory.

Fault sites: every CHUNK frame send runs the ``net.*`` sites keyed
``(epoch, episode, chunk)`` — control traffic (hello/heartbeat/work/acks)
is deliberately uninstrumented so ordinal-based specs target the
deterministic chunk stream, not timing-dependent polling.
``producer.episode`` fires at the top of each assigned episode, keyed
``(host, epoch, episode)``, so a chaos plan can kill one specific host.
"""
from __future__ import annotations

import collections
import heapq
import multiprocessing as mp
import socket
import threading
import time

from repro.obs import (counter_add, register_source, span,
                       unregister_source)
from repro.obs import trace as _trace
from repro.runtime import FaultPlan, fault_point, install_plan
from repro.runtime.errors import InjectedFault, TransportError
from repro.runtime.transport import (ChunkAssembler, FramedSocket, HostHealth,
                                     decode_pairs, encode_pairs)
from repro.walk.engine import WalkConfig, WalkEngine

#: producer poll interval while the server has no assignable episode
WAIT_POLL_S = 0.05


def _connect(address, *, timeout_s: float = 30.0) -> socket.socket:
    """Connect with retry: the producers race the server's listen()."""
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            s = socket.create_connection(address, timeout=5.0)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return s
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(0.05)


class RemoteEpisodeServer:
    """Work-queue + chunk-assembly server feeding one :class:`SampleStore`.

    Epochs are produced strictly sequentially (``submit_epoch`` queues;
    the next activates when the current fully lands), mirroring the
    launcher's one-producing-epoch-at-a-time overlap. Within an epoch the
    assignment window bounds run-ahead: an episode is handed out only while
    ``episode - next_put < window``, so completed-but-unput episodes held
    for ordered delivery stay O(window), and the store's own ``depth``
    backpressure (applied in the dedicated put thread) paces everything
    upstream of it.
    """

    def __init__(self, store, num_episodes: int, seed: int, *,
                 lease_s: float = 10.0, window: int | None = None):
        self.store = store
        self.num_episodes = num_episodes
        self.seed = seed
        self.health = HostHealth(lease_s)
        self.assembler = ChunkAssembler()
        depth = getattr(store, "depth", None)
        self.window = window or max(2, (depth or 2) + 1)
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        self._epoch: int | None = None
        self._epoch_queue: collections.deque[int] = collections.deque()
        self._pending: collections.deque[int] = collections.deque()
        self._assigned: dict[int, str] = {}
        self._ready: list = []                 # heap of (episode, pairs)
        self._next_put = 0
        self._finished_epochs: set[int] = set()
        self._error: BaseException | None = None
        self._shutdown = False
        self._stop_evt = threading.Event()
        self._conns: list[FramedSocket] = []
        self._closed_stats = {"frames_recv": 0, "bytes_recv": 0,
                              "frames_sent": 0, "bytes_sent": 0}
        # first-chunk arrival time per (host, epoch, episode), for the
        # per-host receive-lane trace spans; one writer thread per episode
        # (its host's connection), so no lock needed
        self._recv_t0: dict[tuple, float] = {}
        self._threads: list[threading.Thread] = []
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind(("127.0.0.1", 0))
        self._lsock.listen(64)
        # timeout-polling accept: closing a listener does not reliably wake
        # a thread blocked in accept(), so poll with a short timeout and
        # check the stop event between attempts
        self._lsock.settimeout(0.25)
        self.address = self._lsock.getsockname()

    # ---------------------------------------------------------------- lifecycle
    def start(self) -> None:
        for target, name in ((self._accept_loop, "rws-accept"),
                             (self._put_loop, "rws-put"),
                             (self._reclaim_loop, "rws-reclaim")):
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)

    def stop_work(self) -> None:
        """Stop handing out assignments: subsequent ``work`` requests get
        ``done``, so producers drain and exit cleanly while the sockets
        stay open. Call before :meth:`close`."""
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        self._stop_evt.set()

    def close(self) -> None:
        self.stop_work()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._mu:
            conns = list(self._conns)
        for c in conns:
            c.close()
        for t in self._threads:
            t.join(timeout=5.0)

    # ------------------------------------------------------------------ epochs
    def submit_epoch(self, epoch: int) -> None:
        with self._cv:
            if self._error is not None:
                raise self._error
            if self._epoch is None:
                self._activate_locked(epoch)
            else:
                self._epoch_queue.append(epoch)
            self._cv.notify_all()

    def _activate_locked(self, epoch: int) -> None:
        self._epoch = epoch
        self._pending = collections.deque(range(self.num_episodes))
        self._assigned = {}
        self._ready = []
        self._next_put = 0

    def epoch_finished(self, epoch: int) -> bool:
        with self._mu:
            return epoch in self._finished_epochs

    def wait_epoch(self, epoch: int, timeout_s: float | None = None) -> None:
        """Block until ``epoch`` has fully landed in the store; re-raise the
        recorded production error, if any — the facade's ``join``."""
        deadline = (None if timeout_s is None
                    else time.monotonic() + timeout_s)
        with self._cv:
            while (epoch not in self._finished_epochs
                   and self._error is None):
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError(f"epoch {epoch} not produced in time")
                self._cv.wait(timeout=0.25)
            if self._error is not None:
                raise self._error

    def _fail(self, err: BaseException) -> None:
        """Record a terminal production error and fail consumers fast —
        the remote mirror of ``WalkEngine.start_async``'s error path."""
        with self._cv:
            if self._error is None:
                self._error = err
            epoch = self._epoch
            self._cv.notify_all()
        if epoch is not None:
            self.store.finish_epoch(epoch)

    # --------------------------------------------------------------- put thread
    def _put_loop(self) -> None:
        try:
            while True:
                with self._cv:
                    while not (self._shutdown
                               or (self._epoch is not None and self._ready
                                   and self._ready[0][0] == self._next_put)):
                        self._cv.wait(timeout=0.25)
                    if self._shutdown:
                        return
                    epoch = self._epoch
                    ep, pairs = heapq.heappop(self._ready)
                # store.put may block on backpressure — outside the lock so
                # chunk handlers / assignment keep running meanwhile
                with span("store_put", "store",
                          {"epoch": epoch, "episode": ep}):
                    self.store.put_unique(epoch, ep, pairs)
                with self._cv:
                    self._next_put += 1
                    done = self._next_put >= self.num_episodes
                    if done:
                        self._finished_epochs.add(epoch)
                        self._epoch = None
                        if self._epoch_queue:
                            self._activate_locked(self._epoch_queue.popleft())
                    self._cv.notify_all()
                if done:
                    self.store.finish_epoch(epoch)
        except BaseException as e:  # noqa: BLE001 — any put failure is terminal
            self._fail(e)

    # ----------------------------------------------------------- reclaim thread
    def _reclaim_loop(self) -> None:
        poll = max(0.1, self.health.lease_s / 4)
        while True:
            if self._stop_evt.wait(timeout=poll):
                return
            for host in self.health.expired():
                self.health.mark_dead(host)
                with self._cv:
                    lost = sorted(ep for ep, h in self._assigned.items()
                                  if h == host)
                    for ep in reversed(lost):
                        del self._assigned[ep]
                        self._pending.appendleft(ep)
                    self._cv.notify_all()
                if lost:
                    print(f"remote-walk: host {host!r} lease expired; "
                          f"reassigning episodes {lost} to survivors")
            with self._cv:
                epoch_active = self._epoch is not None
            if epoch_active and self.health.hosts() \
                    and not self.health.any_alive():
                self._fail(TransportError(
                    "all remote producer hosts are dead "
                    f"[{self.health.describe()}]"))
                return

    # ------------------------------------------------------------- connections
    def _accept_loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                s, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                          # listener closed: shutting down
            s.settimeout(None)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = FramedSocket(s)
            with self._mu:
                self._conns.append(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="rws-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, conn: FramedSocket) -> None:
        try:
            while True:
                msg, body = conn.recv()
                reply = self._dispatch(msg, body)
                if reply is None:               # bye
                    break
                conn.send(reply)
        except (TransportError, ConnectionError, OSError):
            pass                                # producer will reconnect
        finally:
            with self._mu:
                if conn in self._conns:
                    self._conns.remove(conn)
                st = conn.stats()
                for k in self._closed_stats:
                    self._closed_stats[k] += st.get(k, 0)
            conn.close()

    def _dispatch(self, msg: dict, body: bytes) -> dict | None:
        t = msg.get("t")
        host = msg.get("host", "?")
        self.health.beat(host)
        if t in ("hello", "hb"):
            if t == "hb":
                counter_add("transport.heartbeats")
            return {"t": "ok", "seed": self.seed}
        if t == "bye":
            return None
        if t == "work":
            return self._assign(host)
        if t == "chunk":
            return self._chunk(msg, body)
        raise TransportError(f"unknown message type {t!r}")

    def _assign(self, host: str) -> dict:
        with self._cv:
            if self._shutdown or self._error is not None:
                return {"t": "done"}
            if (self._epoch is not None and self._pending
                    and self._pending[0] - self._next_put < self.window):
                ep = self._pending.popleft()
                self._assigned[ep] = host
                return {"t": "assign", "epoch": self._epoch, "episode": ep}
            return {"t": "wait", "poll_s": WAIT_POLL_S}

    def _chunk(self, msg: dict, body: bytes) -> dict:
        epoch, ep = msg["epoch"], msg["episode"]
        if msg["seed"] != self.seed:
            raise TransportError(
                f"producer seed {msg['seed']} != server seed {self.seed}")
        dup, assembled = self.assembler.add(
            msg["seed"], epoch, ep, msg["chunk"], msg["nchunks"],
            decode_pairs(msg, body))
        counter_add("transport.chunks_recv")
        if dup:
            counter_add("transport.dup_chunks")
        complete = assembled is not None
        tr = _trace.tracer()
        if tr is not None:
            host = msg.get("host", "?")
            k = (host, epoch, ep)
            t0 = self._recv_t0.setdefault(k, tr.now_us())
            if complete:
                self._recv_t0.pop(k, None)
                tr.add_span("recv_episode", f"host:{host}", t0, tr.now_us(),
                            {"epoch": epoch, "episode": ep,
                             "nchunks": msg["nchunks"]})
        if complete:
            with self._cv:
                if epoch == self._epoch and ep >= self._next_put:
                    heapq.heappush(self._ready, (ep, assembled))
                    self._assigned.pop(ep, None)
                    self._cv.notify_all()
        return {"t": "ack", "epoch": epoch, "episode": ep,
                "chunk": msg["chunk"], "dup": dup, "complete": complete}

    # ------------------------------------------------------------------- stats
    def transport_stats(self) -> dict:
        with self._mu:
            agg = dict(self._closed_stats)
            for c in self._conns:
                st = c.stats()
                for k in agg:
                    agg[k] += st.get(k, 0)
        agg["dup_chunks"] = self.assembler.dup_chunks
        agg["chunks_applied"] = self.assembler.chunks_applied
        applied = max(1, agg["chunks_applied"])
        agg["resend_rate"] = agg["dup_chunks"] / applied
        return agg


class RemoteProducer:
    """One walk-producer host: ask for work, walk it, ship it, survive.

    Runs the store-free :class:`WalkEngine` generation surface
    (``episode_chunk_stream``) so its chunks carry exactly the RNG keys the
    in-process engine would use. All chunks of an assigned episode are
    pipelined onto the wire, then their acks drained; any transport failure
    (including an ack timeout after an injected ``net.drop``) triggers
    reconnect-and-resend of the unacked remainder.
    """

    def __init__(self, address, host: str, graph, wcfg: WalkConfig, *,
                 heartbeat_s: float = 1.0, ack_timeout_s: float = 10.0,
                 connect_timeout_s: float = 30.0):
        self.address = tuple(address)
        self.host = host
        self.engine = WalkEngine(graph, wcfg)
        self.wcfg = wcfg
        self.heartbeat_s = heartbeat_s
        self.ack_timeout_s = ack_timeout_s
        self.connect_timeout_s = connect_timeout_s
        self._conn: FramedSocket | None = None
        self.reconnects = 0
        self.chunks_resent = 0

    # -------------------------------------------------------------- connection
    def _connection(self) -> FramedSocket:
        if self._conn is None:
            s = _connect(self.address, timeout_s=self.connect_timeout_s)
            s.settimeout(self.ack_timeout_s)
            conn = FramedSocket(s)
            conn.send({"t": "hello", "host": self.host})
            conn.recv()
            self._conn = conn
        return self._conn

    def _drop_connection(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None
            self.reconnects += 1

    # -------------------------------------------------------------- heartbeats
    def _heartbeat_loop(self, stop: threading.Event) -> None:
        # dedicated connection: a long GIL-heavy walk on the work connection
        # must not starve the lease — heartbeats ride their own socket and
        # are never fault-injected
        conn = None
        while not stop.is_set():
            try:
                if conn is None:
                    s = _connect(self.address,
                                 timeout_s=self.connect_timeout_s)
                    s.settimeout(self.ack_timeout_s)
                    conn = FramedSocket(s)
                conn.send({"t": "hb", "host": self.host})
                conn.recv()
            except (TransportError, ConnectionError, OSError):
                if conn is not None:
                    conn.close()
                conn = None
            stop.wait(self.heartbeat_s)
        if conn is not None:
            conn.close()

    # -------------------------------------------------------------- work loop
    def run(self) -> None:
        stop = threading.Event()
        hb = threading.Thread(target=self._heartbeat_loop, args=(stop,),
                              name=f"hb-{self.host}", daemon=True)
        hb.start()
        try:
            failures = 0
            while True:
                try:
                    conn = self._connection()
                    conn.send({"t": "work", "host": self.host})
                    reply, _ = conn.recv()
                    # a duplicated final chunk can leave one stray ack in
                    # flight after the drain loop already saw the episode
                    # fully acked — skip past it
                    while reply.get("t") == "ack":
                        reply, _ = conn.recv()
                    failures = 0
                except (TransportError, ConnectionError, OSError):
                    self._drop_connection()
                    failures += 1
                    if failures >= 3:
                        break      # server is gone: nothing left to produce
                    time.sleep(WAIT_POLL_S)
                    continue
                t = reply.get("t")
                if t == "done":
                    break
                if t == "wait":
                    time.sleep(reply.get("poll_s", WAIT_POLL_S))
                    continue
                epoch, episode = reply["epoch"], reply["episode"]
                fault_point("producer.episode", (self.host, epoch, episode))
                self._ship_episode(epoch, episode)
        finally:
            stop.set()
            hb.join(timeout=5.0)
            if self._conn is not None:
                try:
                    self._conn.send({"t": "bye", "host": self.host})
                except (TransportError, ConnectionError, OSError):
                    pass
                self._conn.close()
                self._conn = None

    def _ship_episode(self, epoch: int, episode: int) -> None:
        tr = _trace.tracer()
        t_ship = tr.now_us() if tr is not None else 0.0
        chunks = list(self.engine.episode_chunk_stream(epoch, episode))
        acked: set[int] = set()
        attempts = 0
        while len(acked) < len(chunks):
            attempts += 1
            if attempts > 10:
                raise TransportError(
                    f"episode ({epoch}, {episode}): gave up after "
                    f"{attempts - 1} transport attempts")
            if attempts > 1:
                self.chunks_resent += len(chunks) - len(acked)
                counter_add("transport.chunks_resent",
                            len(chunks) - len(acked))
            try:
                conn = self._connection()
                for c, n, pairs in chunks:
                    if c in acked:
                        continue
                    meta, body = encode_pairs(pairs)
                    conn.send({"t": "chunk", "host": self.host,
                               "seed": self.wcfg.seed, "epoch": epoch,
                               "episode": episode, "chunk": c, "nchunks": n,
                               **meta},
                              body, key=(epoch, episode, c), inject=True)
                # drain until every chunk is acked — set-idempotent, so a
                # duplicated frame's double ack is absorbed rather than
                # desynchronizing the reply stream; a dropped frame's
                # missing ack surfaces as a recv timeout below
                while len(acked) < len(chunks):
                    reply, _ = conn.recv()
                    if reply.get("t") != "ack":
                        raise TransportError(
                            f"expected ack, got {reply.get('t')!r}")
                    acked.add(reply["chunk"])
            except (TransportError, ConnectionError, OSError):
                # includes socket timeouts waiting on the ack of a dropped
                # frame: reconnect and resend whatever is unacked — the
                # server's idempotence keys discard anything that DID land
                self._drop_connection()
        counter_add("walk.episodes_shipped")
        if tr is not None:
            # walk + ship + ack-drain for one assigned episode, on this
            # producer's lane (thread-mode producers share the trainer's
            # tracer; subprocess producers run with obs disabled)
            tr.add_span("ship_episode", "producer:" + self.host, t_ship,
                        tr.now_us(), {"epoch": epoch, "episode": episode,
                                      "chunks": len(chunks),
                                      "attempts": attempts})


def _producer_main(address, host, graph, wcfg, inject_specs, heartbeat_s):
    """Subprocess entry (multiprocessing ``spawn``): fresh interpreter, own
    fault-plan counters, no jax import anywhere on this path."""
    if inject_specs:
        install_plan(FaultPlan(inject_specs))
    RemoteProducer(address, host, graph, wcfg,
                   heartbeat_s=heartbeat_s).run()


class _EpochHandle:
    """One epoch's walker, shaped like the ``WalkEngine`` async surface."""

    def __init__(self, coord: "RemoteWalkCoordinator"):
        self._coord = coord
        self._epoch: int | None = None

    def start_async(self, epoch: int) -> None:
        self._epoch = epoch
        self._coord.server.submit_epoch(epoch)

    def finished(self) -> bool:
        return (self._epoch is None
                or self._coord.server.epoch_finished(self._epoch)
                or self._coord.server._error is not None)

    def alive(self) -> bool:
        return self._coord.alive()

    def join(self) -> None:
        if self._epoch is not None:
            self._coord.server.wait_epoch(self._epoch)


class RemoteWalkCoordinator:
    """Owns the server plus N producers; hands ``launch.train`` walker
    handles indistinguishable from ``WalkEngine``.

    ``mode="process"`` spawns real subprocess producers (the GIL-free
    path); ``mode="thread"`` runs them as in-process threads — same
    protocol, same sockets, cheap enough for tests.
    """

    def __init__(self, graph, wcfg: WalkConfig, store, *,
                 num_producers: int = 2, heartbeat_s: float = 1.0,
                 lease_s: float = 10.0, mode: str = "process",
                 ack_timeout_s: float = 10.0, inject_specs=()):
        self.graph = graph
        self.wcfg = wcfg
        self.store = store
        self.num_producers = max(1, num_producers)
        self.heartbeat_s = heartbeat_s
        self.ack_timeout_s = ack_timeout_s
        self.mode = mode
        self.inject_specs = list(inject_specs)
        self.server = RemoteEpisodeServer(store, wcfg.episodes, wcfg.seed,
                                          lease_s=lease_s)
        self._procs: list = []

    def start(self) -> None:
        self.server.start()
        # one source of truth for the wire + lease surfaces: the registry
        # snapshot (metrics.jsonl, diagnostics.json) reads the live
        # aggregation instead of anyone keeping a parallel copy
        register_source("transport", self.transport_stats)
        register_source("host_health", self.server.health.snapshot)
        set_producer = getattr(self.store, "set_producer", None)
        if callable(set_producer):
            set_producer(self.alive, self.server.health.describe)
        for i in range(self.num_producers):
            host = f"walker-{i}"
            if self.mode == "process":
                ctx = mp.get_context("spawn")
                p = ctx.Process(
                    target=_producer_main,
                    args=(self.server.address, host, self.graph, self.wcfg,
                          self.inject_specs, self.heartbeat_s),
                    name=host, daemon=True)
                p.start()
            else:
                prod = RemoteProducer(self.server.address, host, self.graph,
                                      self.wcfg, heartbeat_s=self.heartbeat_s,
                                      ack_timeout_s=self.ack_timeout_s)

                def _run(prod=prod):
                    # An injected crash simulates a SIGKILL'd producer
                    # process: the thread must die silently (liveness is
                    # detected via the lease, not the exception). Any
                    # other exception still escapes to the caller.
                    try:
                        prod.run()
                    except InjectedFault:
                        pass

                p = threading.Thread(target=_run, name=host, daemon=True)
                p.start()
            self._procs.append(p)

    def epoch_walker(self) -> _EpochHandle:
        return _EpochHandle(self)

    def alive(self) -> bool:
        """Producer-liveness probe for the store watchdog: healthy while
        any host's lease is live (or none has registered yet) and the
        server hasn't recorded a terminal error."""
        return self.server._error is None and self.server.health.any_alive()

    def transport_stats(self) -> dict:
        return self.server.transport_stats()

    def close(self) -> None:
        # drain first: producers see "done" on their next work request and
        # exit on their own; only then tear the sockets down
        self.server.stop_work()
        for p in self._procs:
            p.join(timeout=10.0)
        self.server.close()
        for p in self._procs:
            if hasattr(p, "terminate") and p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
        self._procs = []
        unregister_source("transport")
        unregister_source("host_health")
