"""Network augmentation: walks -> (src, dst) context pairs (paper Alg. 1).

One edge of the original network yields up to k*l augmented samples: every
pair of nodes within `window` hops on a walk becomes a positive edge.
"""
from __future__ import annotations

import numpy as np


def walks_to_pairs(walks: np.ndarray, window: int) -> np.ndarray:
    """(W, L+1) walks -> (P, 2) int32 (center, context) pairs.

    Pairs are emitted in both directions implicitly by emitting (w[t], w[t+d])
    for d in 1..window — matching Alg. 1's E_aug := E_aug ∪ (v, u).
    """
    W, L1 = walks.shape
    out = []
    for d in range(1, window + 1):
        if d >= L1:
            break
        src = walks[:, : L1 - d].ravel()
        dst = walks[:, d:].ravel()
        out.append(np.stack([src, dst], axis=1))
    if not out:
        return np.zeros((0, 2), dtype=np.int32)
    pairs = np.concatenate(out, axis=0).astype(np.int32)
    # drop self-pairs created by dead-end walks stalling in place
    return pairs[pairs[:, 0] != pairs[:, 1]]
