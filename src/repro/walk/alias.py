"""Alias-method sampler (Walker 1977), vectorized.

Used for degree^0.75 negative sampling — the standard word2vec/SGNS noise
distribution the paper inherits from [15]/[16].
"""
from __future__ import annotations

import numpy as np


class AliasTable:
    """O(1)-per-draw sampling from an arbitrary discrete distribution."""

    def __init__(self, weights: np.ndarray):
        w = np.asarray(weights, dtype=np.float64)
        if w.ndim != 1 or w.size == 0:
            raise ValueError("weights must be a non-empty 1-D array")
        if np.any(w < 0) or w.sum() <= 0:
            raise ValueError("weights must be non-negative with positive sum")
        n = w.size
        p = w * (n / w.sum())
        self.prob = np.ones(n, dtype=np.float64)
        self.alias = np.arange(n, dtype=np.int64)
        small = list(np.nonzero(p < 1.0)[0])
        large = list(np.nonzero(p >= 1.0)[0])
        while small and large:
            s = small.pop()
            l = large.pop()
            self.prob[s] = p[s]
            self.alias[s] = l
            p[l] = p[l] - (1.0 - p[s])
            (small if p[l] < 1.0 else large).append(l)
        for rest in (small, large):
            for i in rest:
                self.prob[i] = 1.0

    def sample(self, size: int | tuple, rng: np.random.Generator) -> np.ndarray:
        idx = rng.integers(0, self.prob.size, size=size)
        accept = rng.random(size=idx.shape) < self.prob[idx]
        return np.where(accept, idx, self.alias[idx])


def negative_sampling_table(degrees: np.ndarray, power: float = 0.75) -> AliasTable:
    """The word2vec noise distribution: P(v) ∝ deg(v)^0.75."""
    w = np.asarray(degrees, dtype=np.float64) ** power
    w = np.maximum(w, 1e-12)  # keep isolated nodes sampleable
    return AliasTable(w)
