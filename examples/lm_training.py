"""Train a ~100M-param LM from the assigned-architecture pool for a few
hundred steps on synthetic-but-structured data (Markov documents), using the
same config system, sharding rules, optimizer and train step as the
production dry-run.

    PYTHONPATH=src python examples/lm_training.py --arch qwen1.5-0.5b \
        --steps 200 [--d-model 384 --layers 8]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import data_axes_of, make_host_mesh
from repro.models import transformer as tfm
from repro.models.common import count_params
from repro.sharding.specs import param_shardings
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    choices=cfgs.list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    base = cfgs.get_config(args.arch)
    cfg = base.reduced(layers=args.layers, d_model=args.d_model, experts=4)
    cfg = dataclasses.replace(cfg, vocab_size=min(base.vocab_size, 8192),
                              train_microbatches=1)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    print(f"{args.arch} (reduced): {count_params(params)/1e6:.1f}M params, "
          f"{cfg.num_layers}L d={cfg.d_model}")

    mesh = make_host_mesh()
    data_axes = data_axes_of(mesh)
    params = jax.device_put(params, param_shardings(params, mesh))
    step_fn, opt = make_train_step(cfg, mesh=mesh, data_axes=data_axes,
                                   lr=args.lr)
    opt_state = jax.device_put(
        opt.init(params),
        param_shardings(jax.eval_shape(opt.init, params), mesh))
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=1)
    t0, tok_count = time.perf_counter(), 0
    with mesh:
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            params, opt_state, metrics = jit_step(params, opt_state,
                                                  jnp.int32(step), batch)
            tok_count += args.batch * args.seq
            if step % 20 == 0 or step == args.steps - 1:
                dt = time.perf_counter() - t0
                print(f"step {step:4d}  loss {float(metrics['loss']):7.4f}  "
                      f"grad_norm {float(metrics['grad_norm']):8.3f}  "
                      f"{tok_count/max(dt,1e-9):7.0f} tok/s")
    pipe.close()


if __name__ == "__main__":
    main()
