"""Quickstart: train node embeddings on a synthetic social graph with the
paper's hybrid model-data parallel trainer, then evaluate link prediction.

    PYTHONPATH=src python examples/quickstart.py [--epochs 15]

Runs on however many devices exist (CPU: 1); to emulate a multi-GPU node:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import argparse

import jax
import numpy as np

from repro.core import (HybridConfig, HybridEmbeddingTrainer,
                        build_episode_blocks)
from repro.core import eval as ev
from repro.graph.csr import build_csr
from repro.graph.generators import powerlaw_graph
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--nodes", type=int, default=3000)
    ap.add_argument("--dim", type=int, default=64)
    args = ap.parse_args()

    # --- a graph with community structure (stands in for youtube) ---------
    rng = np.random.default_rng(0)
    comm = rng.integers(0, 20, args.nodes)
    src, dst = [], []
    for _ in range(40):
        a = rng.integers(0, args.nodes, 40000)
        b = rng.integers(0, args.nodes, 40000)
        keep = rng.random(40000) < np.where(comm[a] == comm[b], 0.05, 0.0008)
        src.append(a[keep]); dst.append(b[keep])
    g_full = build_csr(np.stack([np.concatenate(src), np.concatenate(dst)], 1),
                       args.nodes)
    train_e, test_e = ev.split_edges(g_full, 0.05, seed=1)
    g = build_csr(train_e, args.nodes, symmetrize=False, dedup=False)
    neg_e = ev.sample_negative_pairs(g_full, len(test_e), seed=3)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges "
          f"({len(test_e)} held out)")

    # --- the paper's system ------------------------------------------------
    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    cfg = HybridConfig(dim=args.dim, minibatch=32, negatives=8, subparts=2,
                       neg_pool=2048, lr=0.025)
    trainer = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                     degrees=g.degrees())
    trainer.init_embeddings()
    store = MemorySampleStore()

    for epoch in range(args.epochs):
        # decoupled walk engine (async: walks for the NEXT epoch overlap
        # training of this one in examples/billion_scale.py; here sync)
        WalkEngine(g, WalkConfig(walk_length=10, window=5, episodes=1,
                                 seed=epoch), store).run_epoch(epoch)
        eb = build_episode_blocks(np.asarray(store.get(epoch, 0)),
                                  trainer.part, pad_multiple=cfg.minibatch)
        loss = trainer.train_episode(
            eb, lr=cfg.lr * max(1 - epoch / args.epochs, 0.05))
        store.drop_epoch(epoch)
        V = trainer.embeddings()
        Vn = V / (np.linalg.norm(V, axis=1, keepdims=True) + 1e-9)
        auc = ev.auc_score(
            np.einsum("ij,ij->i", Vn[test_e[:, 0]], Vn[test_e[:, 1]]),
            np.einsum("ij,ij->i", Vn[neg_e[:, 0]], Vn[neg_e[:, 1]]))
        print(f"epoch {epoch:3d}  loss {loss:.4f}  link-pred AUC {auc:.4f}")


if __name__ == "__main__":
    main()
