"""Serve a reduced model with batched requests: chunked prefill + decode
loop with ring-buffer KV caches (the decode_32k / long_500k production path
at laptop scale). Works for every assigned arch, including SSM (state
caches) and enc-dec (cross-attention memory).

    PYTHONPATH=src python examples/serving.py --arch mamba2-1.3b --tokens 32
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.models import transformer as tfm
from repro.train.train_step import synthetic_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=cfgs.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding window (0=full attention)")
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch).reduced(layers=2, d_model=256, experts=4)
    if args.window:
        cfg = dataclasses.replace(cfg, sliding_window=args.window)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, args.batch, args.prompt_len).items()}
    cache_len = args.prompt_len + args.tokens + 8
    if cfg.sliding_window:
        cache_len = min(cache_len, cfg.sliding_window)

    prefill = jax.jit(lambda p, b: tfm.prefill(p, b, cfg, cache_len))
    decode = jax.jit(lambda p, t, c: tfm.decode_step(p, t, c, cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
    outs = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
        outs.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(outs, 1)
    print(f"{args.arch}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.1f}ms (incl. compile); "
          f"{args.tokens} tokens decoded at "
          f"{(args.tokens-1)*args.batch/max(t_decode,1e-9):.1f} tok/s")
    print("generated ids (req 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
