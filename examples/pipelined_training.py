"""End-to-end driver with the full pipeline (paper Fig. 2+3): the decoupled
walk engine produces epoch e+1 on a worker thread WHILE the trainer consumes
epoch e, episode blocks are prefetched one step ahead, and checkpoints are
written periodically.

    PYTHONPATH=src python examples/pipelined_training.py --epochs 10
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.core import (EpisodePipeline, HybridConfig, HybridEmbeddingTrainer,
                        build_episode_blocks)
from repro.graph.generators import powerlaw_graph
from repro.train.checkpoint import save_checkpoint
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=5000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    g = powerlaw_graph(args.nodes, 5, seed=7)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges")

    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    cfg = HybridConfig(dim=96, minibatch=64, negatives=5, subparts=4,
                       neg_pool=4096, lr=0.025)
    trainer = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                     degrees=g.degrees())
    trainer.init_embeddings()

    store = MemorySampleStore()
    wcfg = WalkConfig(walk_length=10, window=5, episodes=args.episodes)
    pipe = EpisodePipeline(store, trainer.part, pad_multiple=cfg.minibatch)
    os.makedirs(args.ckpt_dir, exist_ok=True)

    # prime the pipeline: walks for epoch 0
    engine = WalkEngine(g, wcfg, store)
    engine.start_async(0)

    for epoch in range(args.epochs):
        # (stage 7 analogue) kick off NEXT epoch's walks while training
        engine.join()
        if epoch + 1 < args.epochs:
            next_engine = WalkEngine(g, wcfg, store)
            next_engine.start_async(epoch + 1)
        t0 = time.perf_counter()
        pipe.prefetch(epoch, 0)
        losses = []
        for ep in range(args.episodes):
            eb = pipe.get(epoch, ep)             # (stage 5: prefetched)
            if ep + 1 < args.episodes:
                pipe.prefetch(epoch, ep + 1)
            losses.append(trainer.train_episode(
                eb, lr=cfg.lr * max(1 - epoch / args.epochs, 0.05)))
        store.drop_epoch(epoch)
        print(f"epoch {epoch:3d}  loss {np.mean(losses):.4f}  "
              f"{time.perf_counter() - t0:.2f}s (walks overlapped)")
        if epoch + 1 < args.epochs:
            engine = next_engine
        if (epoch + 1) % 5 == 0:
            path = os.path.join(args.ckpt_dir, f"emb_{epoch+1}.npz")
            save_checkpoint(path, {"vertex": trainer.embeddings(),
                                   "context": trainer.context_embeddings()},
                            step=epoch + 1)
            print(f"  checkpoint -> {path}")
    pipe.close()


if __name__ == "__main__":
    main()
