"""End-to-end driver with the full streaming pipeline (paper Fig. 2+3): the
decoupled walk engine shards each episode's walks over a worker pool and
streams episodes into a BOUNDED sample store as they complete, the
multi-stage episode pipeline (walk-wait -> block-build -> device staging)
keeps `--pipeline-depth` episodes in flight, and the trainer consumes staged
blocks — so episode e's training overlaps episode e+1's walks, and peak
sample memory is O(depth · episode) rather than O(epoch). Walks for epoch
e+1 start the moment epoch e's walker finishes, just like the paper's
one-epoch-ahead pipelining.

    PYTHONPATH=src python examples/pipelined_training.py --epochs 10
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.core import EpisodePipeline, HybridConfig, HybridEmbeddingTrainer
from repro.graph.generators import powerlaw_graph
from repro.train.checkpoint import save_checkpoint
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=5000)
    ap.add_argument("--walk-workers", type=int, default=2)
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    g = powerlaw_graph(args.nodes, 5, seed=7)
    print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges")

    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    cfg = HybridConfig(dim=96, minibatch=64, negatives=5, subparts=4,
                       neg_pool=4096, lr=0.025)
    trainer = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                     degrees=g.degrees())
    trainer.init_embeddings()

    # bounded store: the walker can run at most depth+1 episodes ahead of
    # the pipeline's drops
    store = MemorySampleStore(depth=args.pipeline_depth + 1)
    wcfg = WalkConfig(walk_length=10, window=5, episodes=args.episodes,
                      workers=args.walk_workers)
    # three stages, each on its own worker: store.get (walk-wait), 2D block
    # build, device_put staging; drop_consumed frees the walker's slots
    pipe = EpisodePipeline(store, trainer.part, pad_multiple=cfg.minibatch,
                           depth=args.pipeline_depth,
                           stage_fn=trainer.stage_blocks, drop_consumed=True)
    os.makedirs(args.ckpt_dir, exist_ok=True)

    # prime the pipeline: walks for epoch 0 stream in episode by episode
    engine = WalkEngine(g, wcfg, store)
    engine.start_async(0)

    for epoch in range(args.epochs):
        t0 = time.perf_counter()
        nxt = None
        losses = []
        for ep in range(args.episodes):
            pipe.prefetch_window(epoch, ep, args.episodes)  # keep depth full
            staged = pipe.get(epoch, ep)     # stage 5: prefetched + staged
            losses.append(trainer.train_episode(
                staged, lr=cfg.lr * max(1 - epoch / args.epochs, 0.05)))
            # stage 7 analogue: next epoch's walks launch as soon as this
            # epoch's walker is done (the bounded store paces it)
            if nxt is None and epoch + 1 < args.epochs and engine.finished():
                engine.join()
                nxt = WalkEngine(g, wcfg, store)
                nxt.start_async(epoch + 1)
        engine.join()
        if nxt is None and epoch + 1 < args.epochs:
            nxt = WalkEngine(g, wcfg, store)
            nxt.start_async(epoch + 1)
        store.drop_epoch(epoch)
        print(f"epoch {epoch:3d}  loss {np.mean(losses):.4f}  "
              f"{time.perf_counter() - t0:.2f}s "
              f"(walks streamed, peak resident episodes "
              f"{store.peak_resident})")
        if epoch + 1 < args.epochs:
            engine = nxt
        if (epoch + 1) % 5 == 0:
            path = os.path.join(args.ckpt_dir, f"emb_{epoch+1}.npz")
            save_checkpoint(path, {"vertex": trainer.embeddings(),
                                   "context": trainer.context_embeddings()},
                            step=epoch + 1)
            print(f"  checkpoint -> {path}")
    pipe.close()


if __name__ == "__main__":
    main()
