"""Invariants of the hierarchical partitioning + rotation schedule —
the correctness core of the paper's hybrid parallel training."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import rotation
from repro.core.partition import NodePartition, build_episode_blocks


@pytest.mark.parametrize("dims", [(1, 1), (2, 2), (1, 4), (4, 2), (2, 3, 4)])
def test_schedule_bijections(dims):
    rotation.check_schedule(dims)


@settings(max_examples=15, deadline=None)
@given(st.tuples(st.integers(1, 4), st.integers(1, 4)),
       st.tuples(st.integers(0, 3), st.integers(0, 3)))
def test_round_of_pair_inverts_schedule(dims, dev):
    dev = tuple(d % n for d, n in zip(dev, dims))
    for v_flat in range(int(np.prod(dims))):
        vc = []
        rem = v_flat
        for n in dims[::-1]:
            vc.append(rem % n)
            rem //= n
        vc = tuple(vc[::-1])
        rnd = rotation.round_of_pair(dev, vc, dims)
        assert rotation.vertex_shard_at(dev, rnd, dims) == v_flat


@settings(max_examples=10, deadline=None)
@given(n_nodes=st.integers(50, 400), n_pairs=st.integers(1, 800),
       dims=st.sampled_from([(1, 1), (2, 2), (1, 4), (2, 4)]),
       k=st.sampled_from([1, 2, 4]))
def test_episode_blocks_place_every_pair_exactly_once(n_nodes, n_pairs,
                                                      dims, k):
    """Every sample lands in exactly one cell; its cell is consistent with
    the rotation schedule; local indices invert to the original node ids."""
    rng = np.random.default_rng(42)
    pairs = rng.integers(0, n_nodes, size=(n_pairs, 2)).astype(np.int32)
    part = NodePartition(n_nodes, dims=dims, subparts=k)
    eb = build_episode_blocks(pairs, part, pad_multiple=8)
    assert eb.dropped == 0
    assert int(eb.counts.sum()) == n_pairs

    rows = part.padded_rows_per_shard
    rows_sub = part.rows_per_subpart
    P = part.num_shards
    recovered = []
    for dev in range(P):
        dev_c = part.shard_coord(np.array([dev]))
        dev_c = tuple(int(c[0]) for c in dev_c)
        it = np.ndindex(*dims)
        for rnd in it:
            for j in range(k):
                cnt = eb.counts[(dev, *rnd, j)]
                blk = eb.blocks[(dev, *rnd, j)][:cnt]
                v_shard = rotation.vertex_shard_at(dev_c, rnd, dims)
                u = v_shard * rows + j * rows_sub + blk[:, 0]
                v = dev * rows + blk[:, 1]
                recovered.append(np.stack([u, v], 1))
    recovered = np.concatenate(recovered, 0)
    # same multiset of pairs
    key = lambda a: np.sort(a[:, 0].astype(np.int64) * (10 ** 9) + a[:, 1])
    np.testing.assert_array_equal(key(recovered), key(pairs))


def test_chunked_build_bitwise_parity():
    """The two-pass streaming builder must be bitwise identical to a
    single-pass build for any chunk size (a pair's slot is its occurrence
    index within its cell in pair order)."""
    rng = np.random.default_rng(5)
    pairs = rng.integers(0, 300, size=(4000, 2)).astype(np.int32)
    part = NodePartition(300, dims=(2, 2), subparts=2)
    ref = build_episode_blocks(pairs, part, pad_multiple=8, chunk=10**9)
    for chunk in (1, 7, 129, 4000):
        got = build_episode_blocks(pairs, part, pad_multiple=8, chunk=chunk)
        np.testing.assert_array_equal(got.blocks, ref.blocks)
        np.testing.assert_array_equal(got.counts, ref.counts)
        assert got.dropped == ref.dropped == 0
    # with a cap that actually drops, the drop set must also be identical
    capped_ref = build_episode_blocks(pairs, part, block_cap=16,
                                      pad_multiple=8, chunk=10**9)
    capped = build_episode_blocks(pairs, part, block_cap=16,
                                  pad_multiple=8, chunk=61)
    np.testing.assert_array_equal(capped.blocks, capped_ref.blocks)
    assert capped.dropped == capped_ref.dropped > 0


def test_block_cap_pins_block_shape():
    """block_cap fixes the Bmax dimension even when every cell is emptier,
    so a streaming consumer compiles the episode step once."""
    rng = np.random.default_rng(1)
    pairs = rng.integers(0, 100, size=(40, 2)).astype(np.int32)
    part = NodePartition(100, dims=(1, 1), subparts=1)
    eb = build_episode_blocks(pairs, part, block_cap=512, pad_multiple=64)
    assert eb.blocks.shape[-2] == 512
    assert eb.dropped == 0
    assert int(eb.counts.sum()) == 40


def test_block_cap_drops_overflow():
    rng = np.random.default_rng(0)
    pairs = np.zeros((500, 2), np.int32)  # all in one cell
    part = NodePartition(100, dims=(1, 1), subparts=1)
    eb = build_episode_blocks(pairs, part, block_cap=64, pad_multiple=64)
    assert eb.dropped == 500 - 64
    assert eb.counts.max() == 64


def test_padding_roundtrip():
    part = NodePartition(103, dims=(2, 2), subparts=4)
    t = np.arange(103 * 3, dtype=np.float32).reshape(103, 3)
    padded = part.pad_table(t)
    assert padded.shape[0] == part.padded_num_nodes
    assert padded.shape[0] % (part.num_shards * part.subparts) == 0
    np.testing.assert_array_equal(part.unpad_table(padded), t)
