"""Model-family correctness: train forward, prefill/decode consistency
(serve path must reproduce the training forward's logits)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.models.common import rms_norm
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)


def full_logits(params, batch, cfg):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.modality == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], 1)
    enc_out = None
    if cfg.is_encdec:
        enc_out, _ = tfm._encoder_forward(params,
                                          batch["frames"].astype(x.dtype), cfg)
    x, _ = tfm._run_segments(params["segments"], tfm.segments_of(cfg), x, cfg,
                             enc_out=enc_out, cross=cfg.is_encdec)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return tfm._lm_logits(params, x, cfg)


CASES = {
    "dense": ModelConfig(name="dense", arch_type="dense", num_layers=2,
                         d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=256, qkv_bias=True),
    "mla_moe": ModelConfig(name="mla", arch_type="moe", num_layers=3,
                           d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                           vocab_size=256, mla=True, q_lora_rank=32,
                           kv_lora_rank=32, qk_nope_head_dim=16,
                           qk_rope_head_dim=8, v_head_dim=16,
                           moe_num_experts=4, moe_top_k=2, moe_d_ff=64,
                           moe_layer_start=1, moe_num_shared=1,
                           moe_capacity_factor=8.0, mtp=True),
    "ssm": ModelConfig(name="ssm", arch_type="ssm", num_layers=2, d_model=64,
                       num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=256,
                       ssm_state=16, ssm_head_dim=16, ssm_chunk=8),
    "hybrid": ModelConfig(name="hybrid", arch_type="hybrid", num_layers=4,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=256, layer_pattern="AM", ssm_state=16,
                          ssm_head_dim=16, ssm_chunk=8),
    "encdec": ModelConfig(name="audio", arch_type="audio", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=256, encoder_layers=2, modality="audio"),
    "vlm": ModelConfig(name="vlm", arch_type="vlm", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                       modality="vision"),
    "chunked_prefill": ModelConfig(name="chunked", arch_type="dense",
                                   num_layers=2, d_model=64, num_heads=4,
                                   num_kv_heads=2, d_ff=128, vocab_size=256,
                                   prefill_chunk=8),
    "sliding": ModelConfig(name="sliding", arch_type="dense", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                           vocab_size=256, sliding_window=64),
}


def make_batch(cfg, B=2, S=24):
    batch = {"tokens": jax.random.randint(jax.random.fold_in(KEY, 1), (B, S),
                                          0, cfg.vocab_size)}
    if cfg.modality == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 2), (B, 8, cfg.d_model))
    if cfg.modality == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, 3), (B, 16, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", sorted(CASES))
def test_train_forward_finite(name):
    cfg = CASES[name]
    params = tfm.init_params(jax.random.fold_in(KEY, 7), cfg)
    loss, metrics = tfm.forward_train(params, make_batch(cfg), cfg)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", sorted(CASES))
def test_prefill_and_decode_match_forward(name):
    cfg = CASES[name]
    B, S = 2, 24
    batch = make_batch(cfg, B, S)
    params = tfm.init_params(jax.random.fold_in(KEY, 8), cfg)
    lf = full_logits(params, batch, cfg)
    lp, caches = tfm.prefill(params, batch, cfg, cache_len=S + 16)
    np.testing.assert_allclose(np.asarray(lf[:, -1]), np.asarray(lp[:, 0]),
                               rtol=2e-3, atol=2e-3)
    nxt = jnp.argmax(lp[:, 0], -1).astype(jnp.int32)[:, None]
    ld, _ = tfm.decode_step(params, nxt, caches, cfg)
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    lf2 = full_logits(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(lf2[:, -1]), np.asarray(ld[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_segments_of_deepseek_pattern():
    cfg = ModelConfig(name="ds", arch_type="moe", num_layers=7, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      moe_num_experts=4, moe_top_k=2, moe_d_ff=32,
                      moe_layer_start=3)
    segs = tfm.segments_of(cfg)
    assert [(s.groups, s.sig) for s in segs] == [
        (3, (("A", False),)), (4, (("A", True),))]


def test_segments_of_jamba_pattern():
    cfg = ModelConfig(name="j", arch_type="hybrid", num_layers=16, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      layer_pattern="MMMMAMMM", ssm_state=8, ssm_head_dim=8,
                      moe_num_experts=4, moe_top_k=2, moe_d_ff=32,
                      moe_layer_start=1, moe_layer_period=2)
    segs = tfm.segments_of(cfg)
    assert len(segs) == 1 and segs[0].groups == 2
    assert len(segs[0].sig) == 8
    assert segs[0].sig[4][0] == "A"
    assert segs[0].sig[1] == ("M", True)


def test_sliding_window_limits_attention():
    """A token far outside the window must not influence the last logit."""
    cfg = CASES["sliding"]
    cfg = cfg.__class__(**{**cfg.__dict__, "sliding_window": 4})
    params = tfm.init_params(jax.random.fold_in(KEY, 9), cfg)
    toks = jax.random.randint(jax.random.fold_in(KEY, 10), (1, 16), 0, 256)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % 256)  # outside window of last
    l1 = full_logits(params, {"tokens": toks}, cfg)
    l2 = full_logits(params, {"tokens": toks2}, cfg)
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-6)
