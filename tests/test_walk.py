"""Walk engine, augmentation, alias sampler, sample store."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import mesh_graph, powerlaw_graph, rmat_graph
from repro.walk import (AliasTable, MemorySampleStore, WalkConfig, WalkEngine,
                        walks_to_pairs)
from repro.walk.alias import negative_sampling_table
from repro.walk.store import DiskSampleStore


def test_walks_stay_on_graph():
    g = powerlaw_graph(500, 4, seed=1)
    eng = WalkEngine(g, WalkConfig(walk_length=12, window=4), MemorySampleStore())
    rng = np.random.default_rng(0)
    walks = eng.generate_walks(np.arange(200, dtype=np.int32), rng)
    adj = {v: set(g.neighbors(v)) for v in range(g.num_nodes)}
    for w in walks[:50]:
        for a, b in zip(w[:-1], w[1:]):
            assert b in adj[a] or (a == b and len(adj[a]) == 0)


def test_walks_to_pairs_window():
    walks = np.array([[0, 1, 2, 3, 4]], dtype=np.int32)
    pairs = walks_to_pairs(walks, window=2)
    got = set(map(tuple, pairs.tolist()))
    want = {(0, 1), (1, 2), (2, 3), (3, 4), (0, 2), (1, 3), (2, 4)}
    assert got == want


def test_pairs_drop_self_loops_from_stalls():
    walks = np.array([[5, 5, 5]], dtype=np.int32)  # dead-end stall
    assert walks_to_pairs(walks, window=2).shape[0] == 0


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=50))
def test_alias_table_distribution(weights):
    w = np.asarray(weights)
    tab = AliasTable(w)
    rng = np.random.default_rng(0)
    s = tab.sample(20000, rng)
    emp = np.bincount(s, minlength=len(w)) / 20000.0
    np.testing.assert_allclose(emp, w / w.sum(), atol=0.05)


def test_negative_sampling_power():
    deg = np.array([1, 16, 81, 0])
    tab = negative_sampling_table(deg, power=0.75)
    rng = np.random.default_rng(1)
    s = tab.sample(40000, rng)
    emp = np.bincount(s, minlength=4) / 40000.0
    w = np.maximum(deg.astype(float) ** 0.75, 1e-12)
    np.testing.assert_allclose(emp, w / w.sum(), atol=0.02)


def test_engine_epoch_and_degree_guided_balance():
    g = powerlaw_graph(800, 4, seed=3)
    store = MemorySampleStore()
    eng = WalkEngine(g, WalkConfig(walk_length=8, window=3, episodes=4), store)
    eng.run_epoch(0)
    sizes = [store.get(0, e).shape[0] for e in range(4)]
    assert min(sizes) > 0
    # degree-guided round-robin keeps episodes balanced within ~25%
    assert max(sizes) / min(sizes) < 1.25


def test_async_engine_overlap():
    g = rmat_graph(8, 4, seed=2)
    store = MemorySampleStore()
    eng = WalkEngine(g, WalkConfig(walk_length=6, window=3, episodes=2), store)
    eng.start_async(0)
    pairs = store.get(0, 0)  # blocks until the walker delivers
    eng.join()
    assert pairs.shape[0] > 0


def test_disk_store_roundtrip(tmp_path):
    store = DiskSampleStore(str(tmp_path))
    pairs = np.array([[1, 2], [3, 4]], np.int32)
    store.put(0, 0, pairs)
    store.finish_epoch(0)
    np.testing.assert_array_equal(np.asarray(store.get(0, 0)), pairs)
    assert store.episodes(0) == 1


def test_disk_store_get_blocks_for_inflight_episode(tmp_path):
    """Regression: get(block=True) used to raise KeyError immediately while
    the walker was still writing; it must poll until the file (or the epoch
    .done marker) appears. episodes() likewise waits on .done."""
    import threading
    import time

    store = DiskSampleStore(str(tmp_path))
    pairs = np.array([[7, 8], [9, 10]], np.int32)

    with pytest.raises(KeyError):
        store.get(0, 0, block=False)     # non-blocking stays immediate

    def writer():
        time.sleep(0.15)
        store.put(0, 0, pairs)
        store.finish_epoch(0)

    t = threading.Thread(target=writer)
    t.start()
    got = store.get(0, 0)                # must wait for the writer
    np.testing.assert_array_equal(np.asarray(got), pairs)
    assert store.episodes(0) == 1        # waited on .done
    t.join()
    # epoch is done and episode 1 never arrived -> immediate KeyError
    with pytest.raises(KeyError):
        store.get(0, 1)


@pytest.mark.parametrize("make_store", [
    lambda tmp: MemorySampleStore(depth=2),
    lambda tmp: DiskSampleStore(str(tmp), depth=2, keep=False),
])
def test_bounded_store_backpressure(tmp_path, make_store):
    """put blocks while `depth` undrained episodes are resident; drop frees
    a slot; peak_resident proves the bound held."""
    g = powerlaw_graph(300, 4, seed=3)
    store = make_store(tmp_path)
    eng = WalkEngine(g, WalkConfig(walk_length=6, window=2, episodes=5,
                                   workers=2, chunk_size=64), store)
    eng.start_async(0)
    sizes = []
    for ep in range(5):
        sizes.append(np.asarray(store.get(0, ep)).shape[0])
        store.drop(0, ep)
    eng.join()
    assert min(sizes) > 0
    assert store.peak_resident <= 2
    # dropped episodes are gone for good, not silently regenerated
    with pytest.raises(KeyError):
        store.get(0, 0)


def test_streamed_multiworker_bitwise_parity():
    """Walk sharding must not change the sample stream: any worker count
    yields bitwise-identical per-episode pairs for a fixed seed."""
    g = powerlaw_graph(400, 4, seed=7)
    streams = {}
    for workers in (1, 3):
        store = MemorySampleStore()
        cfg = WalkConfig(walk_length=7, window=3, episodes=3, seed=11,
                         workers=workers, chunk_size=100)
        WalkEngine(g, cfg, store).run_epoch(0)
        streams[workers] = [np.asarray(store.get(0, e)) for e in range(3)]
    for e in range(3):
        np.testing.assert_array_equal(streams[1][e], streams[3][e])


def test_abandoned_store_unblocks_walker(tmp_path):
    """If the consumer dies, abandon() must let a walker blocked on
    backpressure run to completion instead of deadlocking join()."""
    import threading

    for store in (MemorySampleStore(depth=1),
                  DiskSampleStore(str(tmp_path), depth=1, keep=False)):
        g = powerlaw_graph(200, 3, seed=0)
        eng = WalkEngine(g, WalkConfig(walk_length=4, window=2, episodes=4,
                                       workers=2, chunk_size=64), store)
        eng.start_async(0)
        # wait until the walker has filled the single slot and is blocked
        store.get(0, 0)
        t = threading.Timer(0.1, store.abandon)
        t.start()
        eng.join()                 # must return (and not raise) promptly
        t.join()
        assert store.peak_resident <= 1


def test_disk_store_fresh_clears_stale_run(tmp_path):
    import os

    old = DiskSampleStore(str(tmp_path))
    old.put(0, 0, np.array([[1, 2]], np.int32))
    old.finish_epoch(0)
    store = DiskSampleStore(str(tmp_path), fresh=True)
    # stale files and the .done marker are gone: a non-blocking get sees an
    # empty epoch instead of the previous run's samples
    with pytest.raises(KeyError):
        store.get(0, 0, block=False)
    assert not any(f.endswith((".npy", ".done"))
                   for f in os.listdir(str(tmp_path)))


def test_disk_store_episodes_counts_once_with_keep(tmp_path):
    """Regression: episodes() must not double-count a dropped episode whose
    file was kept (keep=True)."""
    store = DiskSampleStore(str(tmp_path), keep=True)
    store.put(0, 0, np.array([[1, 2]], np.int32))
    store.put(0, 1, np.array([[3, 4]], np.int32))
    store.finish_epoch(0)
    store.drop(0, 0)               # file stays on disk
    assert store.episodes(0) == 2
    # offline-consumer view (separate store object, no produce bookkeeping)
    reader = DiskSampleStore(str(tmp_path))
    assert reader.episodes(0) == 2
    reader_del = DiskSampleStore(str(tmp_path), keep=False)
    reader_del.drop(0, 0)          # file deleted, still counts as produced
    assert reader_del.episodes(0) == 2


def test_worker_error_propagates_through_join():
    g = powerlaw_graph(100, 3, seed=1)
    store = MemorySampleStore()
    eng = WalkEngine(g, WalkConfig(episodes=2, workers=2), store)

    def boom(*a, **k):
        raise RuntimeError("chunk worker died")

    eng._chunk_pairs = boom
    eng.start_async(0)
    with pytest.raises(KeyError):
        store.get(0, 0)       # woken by the error path's finish_epoch
    with pytest.raises(RuntimeError, match="chunk worker died"):
        eng.join()


# ---------------------------------------------------------------------------
# property-test helpers (shared by the hypothesis tests below and the
# deterministic spot-checks, so the invariant logic is exercised even on the
# no-hypothesis container where @given tests skip)
# ---------------------------------------------------------------------------
def _check_episode_starts_balance(g, episodes, walks_per_node, seed):
    cfg = WalkConfig(episodes=episodes, walks_per_node=walks_per_node,
                     seed=seed)
    eng = WalkEngine(g, cfg, MemorySampleStore())
    parts = eng._episode_starts(0)
    assert len(parts) == episodes
    # union of episodes == every node, walks_per_node times
    allstarts = np.sort(np.concatenate(parts))
    want = np.sort(np.repeat(np.arange(g.num_nodes, dtype=np.int32),
                             walks_per_node))
    np.testing.assert_array_equal(allstarts, want)
    # degree-guided deal: per-episode degree mass within one round's spread
    # (sorted round-robin ⇒ episode mass gaps telescope to ≤ ~max degree)
    deg = g.degrees().astype(np.int64)
    masses = np.array([deg[p].sum() for p in parts], dtype=np.float64)
    tol = 2.0 * deg.max() * walks_per_node + 1
    assert masses.max() - masses.min() <= tol, (masses, tol)


def _check_pairs_match_bruteforce(walks, window):
    pairs = walks_to_pairs(walks, window)
    brute = []
    W, L1 = walks.shape
    for w in walks:
        for t in range(L1):
            for d in range(1, window + 1):
                if t + d < L1 and w[t] != w[t + d]:
                    brute.append((w[t], w[t + d]))
    got = sorted(map(tuple, pairs.tolist()))
    assert got == sorted(brute)


def test_episode_starts_balance_spotcheck():
    _check_episode_starts_balance(powerlaw_graph(700, 4, seed=2), 4, 2, 5)


def test_pairs_bruteforce_spotcheck():
    rng = np.random.default_rng(3)
    walks = rng.integers(0, 50, size=(20, 6)).astype(np.int32)
    _check_pairs_match_bruteforce(walks, 3)
    _check_pairs_match_bruteforce(walks[:, :2], 5)   # window > walk length


@settings(max_examples=15, deadline=None)
@given(nodes=st.integers(50, 500), episodes=st.integers(1, 8),
       walks_per_node=st.integers(1, 3), seed=st.integers(0, 10))
def test_episode_starts_degree_balance_property(nodes, episodes,
                                                walks_per_node, seed):
    """Degree-guided partitioning: every start appears exactly
    walks_per_node times and per-episode degree mass is balanced."""
    g = powerlaw_graph(nodes, 4, seed=seed)
    _check_episode_starts_balance(g, episodes, walks_per_node, seed)


@settings(max_examples=25, deadline=None)
@given(walk_len=st.integers(1, 12), window=st.integers(1, 8),
       n_walks=st.integers(1, 30), seed=st.integers(0, 10))
def test_walks_to_pairs_window_property(walk_len, window, n_walks, seed):
    """walks_to_pairs == brute-force window enumeration (minus self-pairs)
    on ragged walk lengths, including walks shorter than the window."""
    rng = np.random.default_rng(seed)
    walks = rng.integers(0, 40, size=(n_walks, walk_len + 1)).astype(np.int32)
    # simulate dead-end stalls: some walks freeze at a random position
    stall_from = rng.integers(1, walk_len + 1, size=n_walks)
    for i in range(n_walks):
        if rng.random() < 0.3:
            walks[i, stall_from[i]:] = walks[i, stall_from[i] - 1]
    _check_pairs_match_bruteforce(walks, window)


def test_node2vec_biased_step_runs():
    g = mesh_graph(12)
    cfg = WalkConfig(walk_length=6, window=2, node2vec_p=0.5, node2vec_q=2.0)
    eng = WalkEngine(g, cfg, MemorySampleStore())
    rng = np.random.default_rng(0)
    walks = eng.generate_walks(np.arange(50, dtype=np.int32), rng)
    adj = {v: set(g.neighbors(v)) for v in range(g.num_nodes)}
    for w in walks[:20]:
        for a, b in zip(w[:-1], w[1:]):
            assert b in adj[a] or a == b
