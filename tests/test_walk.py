"""Walk engine, augmentation, alias sampler, sample store."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import mesh_graph, powerlaw_graph, rmat_graph
from repro.walk import (AliasTable, MemorySampleStore, WalkConfig, WalkEngine,
                        walks_to_pairs)
from repro.walk.alias import negative_sampling_table
from repro.walk.store import DiskSampleStore


def test_walks_stay_on_graph():
    g = powerlaw_graph(500, 4, seed=1)
    eng = WalkEngine(g, WalkConfig(walk_length=12, window=4), MemorySampleStore())
    rng = np.random.default_rng(0)
    walks = eng.generate_walks(np.arange(200, dtype=np.int32), rng)
    adj = {v: set(g.neighbors(v)) for v in range(g.num_nodes)}
    for w in walks[:50]:
        for a, b in zip(w[:-1], w[1:]):
            assert b in adj[a] or (a == b and len(adj[a]) == 0)


def test_walks_to_pairs_window():
    walks = np.array([[0, 1, 2, 3, 4]], dtype=np.int32)
    pairs = walks_to_pairs(walks, window=2)
    got = set(map(tuple, pairs.tolist()))
    want = {(0, 1), (1, 2), (2, 3), (3, 4), (0, 2), (1, 3), (2, 4)}
    assert got == want


def test_pairs_drop_self_loops_from_stalls():
    walks = np.array([[5, 5, 5]], dtype=np.int32)  # dead-end stall
    assert walks_to_pairs(walks, window=2).shape[0] == 0


@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=50))
def test_alias_table_distribution(weights):
    w = np.asarray(weights)
    tab = AliasTable(w)
    rng = np.random.default_rng(0)
    s = tab.sample(20000, rng)
    emp = np.bincount(s, minlength=len(w)) / 20000.0
    np.testing.assert_allclose(emp, w / w.sum(), atol=0.05)


def test_negative_sampling_power():
    deg = np.array([1, 16, 81, 0])
    tab = negative_sampling_table(deg, power=0.75)
    rng = np.random.default_rng(1)
    s = tab.sample(40000, rng)
    emp = np.bincount(s, minlength=4) / 40000.0
    w = np.maximum(deg.astype(float) ** 0.75, 1e-12)
    np.testing.assert_allclose(emp, w / w.sum(), atol=0.02)


def test_engine_epoch_and_degree_guided_balance():
    g = powerlaw_graph(800, 4, seed=3)
    store = MemorySampleStore()
    eng = WalkEngine(g, WalkConfig(walk_length=8, window=3, episodes=4), store)
    eng.run_epoch(0)
    sizes = [store.get(0, e).shape[0] for e in range(4)]
    assert min(sizes) > 0
    # degree-guided round-robin keeps episodes balanced within ~25%
    assert max(sizes) / min(sizes) < 1.25


def test_async_engine_overlap():
    g = rmat_graph(8, 4, seed=2)
    store = MemorySampleStore()
    eng = WalkEngine(g, WalkConfig(walk_length=6, window=3, episodes=2), store)
    eng.start_async(0)
    pairs = store.get(0, 0)  # blocks until the walker delivers
    eng.join()
    assert pairs.shape[0] > 0


def test_disk_store_roundtrip(tmp_path):
    store = DiskSampleStore(str(tmp_path))
    pairs = np.array([[1, 2], [3, 4]], np.int32)
    store.put(0, 0, pairs)
    store.finish_epoch(0)
    np.testing.assert_array_equal(np.asarray(store.get(0, 0)), pairs)
    assert store.episodes(0) == 1


def test_node2vec_biased_step_runs():
    g = mesh_graph(12)
    cfg = WalkConfig(walk_length=6, window=2, node2vec_p=0.5, node2vec_q=2.0)
    eng = WalkEngine(g, cfg, MemorySampleStore())
    rng = np.random.default_rng(0)
    walks = eng.generate_walks(np.arange(50, dtype=np.int32), rng)
    adj = {v: set(g.neighbors(v)) for v in range(g.num_nodes)}
    for w in walks[:20]:
        for a, b in zip(w[:-1], w[1:]):
            assert b in adj[a] or a == b
