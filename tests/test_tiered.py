"""Tiered embedding tables (host master + HBM hot-row cache).

The load-bearing gate is bitwise equality: training through the tiered
table — ANY cache budget, including 0 (pure streaming) and all rows
(fully cached) — must produce embeddings bitwise identical to the
resident-shard trainer on the same seed and episode schedule. The compact
working-set remap is monotone, so every duplicate-combine path sees the
identical sort/equality structure; these tests are the proof the
implementation keeps that property.
"""
import jax
import numpy as np
import pytest

from repro.core import HybridConfig, HybridEmbeddingTrainer
from repro.core import build_episode_blocks
from repro.core.tiered import (CacheStats, TieredEmbeddingTrainer,
                               TieredTable)
from repro.graph.csr import build_csr
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


@pytest.fixture(scope="module")
def small_graph():
    rng = np.random.default_rng(7)
    n = 300
    a = rng.integers(0, n, 6000)
    b = (a + rng.zipf(1.8, 6000)) % n     # skewed targets
    return build_csr(np.stack([a, b], 1), n)


def _cfg(**kw):
    base = dict(dim=16, minibatch=32, negatives=4, subparts=2,
                neg_pool=256, lr=0.05)
    base.update(kw)
    return HybridConfig(**base)


def _episodes(g, part, cfg, epochs):
    store = MemorySampleStore()
    out = []
    for epoch in range(epochs):
        eng = WalkEngine(g, WalkConfig(walk_length=8, window=4, episodes=1,
                                       seed=epoch), store)
        eng.run_epoch(epoch)
        out.append(build_episode_blocks(np.asarray(store.get(epoch, 0)),
                                        part, pad_multiple=cfg.minibatch))
        store.drop_epoch(epoch)
    return out


def _train_pair(g, cfg, budget, epochs=3, policy="freq"):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    res = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    tie = TieredEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees(),
                                 hbm_rows=budget, policy=policy)
    res.init_embeddings()
    tie.init_embeddings()
    ebs = _episodes(g, res.part, cfg, epochs)
    losses = []
    for i, eb in enumerate(ebs):
        lr = cfg.lr * max(1 - i / epochs, 0.05)
        lr_res = res.train_episode(eb, lr=lr)
        lr_tie = tie.train_episode(eb, lr=lr)
        losses.append((lr_res, lr_tie))
    return res, tie, losses


@pytest.mark.parametrize("budget", [0, 48, 10**9])
def test_tiered_bitwise_matches_resident(small_graph, budget):
    g = small_graph
    cfg = _cfg()
    res, tie, losses = _train_pair(g, cfg, budget)
    v_res, v_tie = res.embeddings(), tie.embeddings()
    c_res, c_tie = res.context_embeddings(), tie.context_embeddings()
    assert v_res.dtype == v_tie.dtype
    assert np.array_equal(
        v_res.view(np.uint8), v_tie.view(np.uint8)), (
        "vertex tables diverge at budget %r" % budget)
    assert np.array_equal(c_res.view(np.uint8), c_tie.view(np.uint8))
    for lr_res, lr_tie in losses:
        assert lr_res == pytest.approx(lr_tie, rel=1e-6)


def test_tiered_bitwise_lru_policy(small_graph):
    g = small_graph
    res, tie, _ = _train_pair(g, _cfg(), 32, epochs=2, policy="lru")
    assert np.array_equal(res.embeddings().view(np.uint8),
                          tie.embeddings().view(np.uint8))


def test_tiered_rejects_multi_shard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tr = TieredEmbeddingTrainer(64, mesh, _cfg(subparts=1), hbm_rows=8)
    assert tr.part.num_shards == 1  # single shard accepted
    if jax.device_count() >= 2:
        mesh2 = jax.make_mesh((1, 2), ("data", "model"))
        with pytest.raises(ValueError, match="single-shard"):
            TieredEmbeddingTrainer(64, mesh2, _cfg(subparts=1), hbm_rows=8)


def test_tiered_set_embeddings_resume_bitwise(small_graph):
    """Crash-resume through the tiered path: install a snapshot, keep
    training, match the resident trainer doing the same."""
    g = small_graph
    cfg = _cfg()
    res, tie, _ = _train_pair(g, cfg, 64, epochs=2)
    v, c = res.embeddings(), res.context_embeddings()
    res.set_embeddings(v, c)
    tie.set_embeddings(v, c)
    eb = _episodes(g, res.part, cfg, 3)[-1]
    res.train_episode(eb, lr=0.03)
    tie.train_episode(eb, lr=0.03)
    assert np.array_equal(res.embeddings().view(np.uint8),
                          tie.embeddings().view(np.uint8))


# ----------------------------------------------------------------- policy
def _mk_table(rows=32, dim=4, budget=8, policy="freq"):
    return TieredTable(rows, dim, np.float32, budget, policy=policy,
                       name="t")


def test_promotion_deterministic():
    """Same access history -> identical residency, bit for bit."""
    ids = np.array([3, 3, 3, 7, 7, 1, 9, 9, 9, 9])
    tabs = [_mk_table() for _ in range(2)]
    for t in tabs:
        t.master[:] = np.arange(32, dtype=np.float32)[:, None]
        t.note_access(ids, np.ones_like(ids))
        t.promote()
    assert np.array_equal(tabs[0].slot_of, tabs[1].slot_of)
    assert np.array_equal(tabs[0].row_of, tabs[1].row_of)
    assert np.array_equal(np.asarray(tabs[0].cache),
                          np.asarray(tabs[1].cache))


def test_freq_promotes_hottest_and_evicts():
    t = _mk_table(budget=2)
    t.note_access(np.array([1, 2, 3]), np.array([5.0, 3.0, 1.0]))
    t.promote()
    assert set(t.row_of) == {1, 2}
    # row 3 overtakes row 2 -> 2 evicted, 3 promoted, 1 stays
    t.note_access(np.array([3]), np.array([10.0]))
    n_promoted, n_evicted = t.promote()
    assert (n_promoted, n_evicted) == (1, 1)
    assert set(t.row_of) == {1, 3}
    assert t.stats.evictions == 1


def test_lru_promotes_most_recent():
    t = _mk_table(budget=2, policy="lru")
    t.note_access(np.array([1]), np.array([1.0]))
    t.note_access(np.array([2]), np.array([1.0]))
    t.note_access(np.array([3]), np.array([1.0]))
    t.promote()
    assert set(t.row_of) == {2, 3}


def test_eviction_writes_back_updated_rows():
    t = _mk_table(budget=1)
    t.master[:] = 1.0
    t.note_access(np.array([5]), np.array([2.0]))
    t.promote()
    t.cache = t.cache.at[t.slot_of[5]].set(42.0)   # simulate an update
    t.note_access(np.array([6]), np.array([9.0]))
    t.promote()                                    # 5 evicted for 6
    assert t.slot_of[5] == -1
    assert np.all(t.master[5] == 42.0)


def test_hit_rate_oracle_powerlaw():
    """Known powerlaw stream: after one promotion, a 25%-of-rows cache must
    catch >= the oracle mass of the hot set (here the stream is Zipf-like
    over row ids, so the top-quarter rows carry >80% of accesses)."""
    rows, budget = 256, 64
    rng = np.random.default_rng(0)
    ranks = rng.zipf(1.3, 200_000)
    stream = (ranks[ranks <= rows] - 1).astype(np.int64)
    hot = np.argsort(-np.bincount(stream, minlength=rows),
                     kind="stable")[:budget]
    oracle = np.bincount(stream, minlength=rows)[hot].sum() / stream.size
    assert oracle >= 0.8, oracle

    t = _mk_table(rows=rows, dim=4, budget=budget)
    ids, counts = np.unique(stream, return_counts=True)
    t.note_access(ids, counts)
    t.promote()
    # replay the stream as traffic through plan(): measured == oracle
    uids = np.unique(stream)
    t.plan(uids, uids.size, stream)
    assert t.stats.hit_rate == pytest.approx(oracle)
    assert set(t.row_of) == set(hot)


def test_cache_stats_byte_model():
    t = _mk_table(rows=16, dim=4, budget=2)
    t.note_access(np.array([0, 1]), np.array([3.0, 2.0]))
    t.promote()
    host0 = t.stats.host_bytes_moved
    assert host0 == 2 * 4 * 4                     # 2 promoted rows up
    uids = np.array([0, 1, 5])
    t.plan(uids, 4, np.array([0, 0, 1, 5]))
    s = t.stats
    assert (s.hits, s.misses) == (3, 1)           # traffic-weighted
    assert (s.row_hits, s.row_misses) == (2, 1)   # unique-row gathers
    assert s.hbm_bytes_moved == 2 * 2 * 4 * 4     # 2 hot rows x (in + out)
    assert s.host_bytes_moved == host0 + 2 * 1 * 4 * 4


def test_tiered_trainer_reports_hit_rate(small_graph):
    g = small_graph
    _, tie, _ = _train_pair(g, _cfg(), 10**9, epochs=2)
    st = tie.cache_stats()
    # budget covers everything: after the first episode's promotion the
    # second episode is all hits, so the overall rate is far above chance
    assert st["hit_rate"] > 0.3
    assert st["hbm_bytes_moved"] > 0
    assert st["vertex"]["promotions"] > 0


def test_disk_spill_master(tmp_path, small_graph):
    """Optional memmap master tier trains identically to the RAM master."""
    g = small_graph
    cfg = _cfg()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ram = TieredEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees(),
                                 hbm_rows=32)
    disk = TieredEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                  degrees=g.degrees(), hbm_rows=32,
                                  spill_dir=str(tmp_path))
    ram.init_embeddings()
    disk.init_embeddings()
    assert isinstance(disk.vert_t.master, np.memmap)
    for eb in _episodes(g, ram.part, cfg, 2):
        ram.train_episode(eb, lr=0.05)
        disk.train_episode(eb, lr=0.05)
    assert np.array_equal(ram.embeddings().view(np.uint8),
                          disk.embeddings().view(np.uint8))


# ----------------------------------------------------------- serving tier
def _serve_store(n=200, d=32, seed=0, **kw):
    from repro.embed_serve import ShardedEmbeddingStore
    rng = np.random.default_rng(seed)
    tbl = rng.integers(-4, 5, size=(n, d)).astype(np.float32)
    return ShardedEmbeddingStore.from_array(tbl, **kw), tbl


def _int_queries(d, q=16, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(-3, 4, size=(q, d)).astype(np.float32)


@pytest.mark.parametrize("budget", [0, 50, 200])
def test_tiered_serving_exact_recall(budget):
    """Integer tables make every dot exact, so the tiered scan must equal
    the numpy oracle array-for-array at any hot budget (0 = all-cold int8
    + rescore, 200 = all-exact hot tier)."""
    store, _ = _serve_store()
    counts = np.zeros(200)
    counts[:120] = np.arange(120, 0, -1)    # hottest rows = smallest ids
    got = store.enable_hot_tier(budget, counts=counts)
    assert got == min(budget, 120)
    q = _int_queries(32)
    v, i = store.topk(q, 10, impl="tiered")
    rv, ri = store.oracle_topk(q, 10)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6)


def test_tiered_serving_multi_shard():
    dev = jax.devices()[0]
    store, _ = _serve_store(n=150, devices=[dev, dev, dev])
    counts = np.zeros(150)
    hot_ids = np.arange(0, 150, 4)          # hot rows on every shard
    counts[hot_ids] = 5
    store.enable_hot_tier(64, counts=counts)
    q = _int_queries(32, q=8, seed=2)
    v, i = store.topk(q, 7, impl="tiered")
    rv, ri = store.oracle_topk(q, 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


def test_tiered_serving_requires_hot_tier():
    store, _ = _serve_store(n=64)
    with pytest.raises(RuntimeError, match="hot tier"):
        store.topk(_int_queries(32, q=2), 5, impl="tiered")


def test_tiered_serving_stats_and_byte_model():
    store, _ = _serve_store()
    counts = np.zeros(200)
    counts[:40] = 10.0
    store.enable_hot_tier(40, counts=counts)
    q = _int_queries(32, q=8, seed=3)
    _, i = store.topk(q, 5, impl="tiered")
    st = store.hot_tier_stats()
    assert st["queries"] == 8
    assert st["returned"] == 40
    hot_frac = np.isin(np.asarray(i), np.arange(40)).mean()
    assert st["returned_hot_frac"] == pytest.approx(hot_frac)
    assert st["hot_rows"] == 40 and st["cold_rows"] == 160
    # tiered cold scan covers 160 rows instead of 200: fewer int8 bytes
    assert st["scan_bytes_tiered"] == 40 * 32 * 4 + 160 * (32 + 4)
    assert st["scan_bytes_quant"] == 200 * (32 + 4)


def test_tiered_serving_explicit_ids_and_degraded():
    """Explicit hot ids; degraded path (per-shard timeout executor) still
    answers exactly through the tiered dispatch."""
    dev = jax.devices()[0]
    store, _ = _serve_store(n=120, devices=[dev, dev])
    store.enable_hot_tier(16, ids=np.arange(0, 120, 8))
    q = _int_queries(32, q=4, seed=4)
    v, i, meta = store.topk(q, 6, impl="tiered", shard_timeout_s=60.0,
                            return_meta=True)
    assert not meta.degraded
    rv, ri = store.oracle_topk(q, 6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))


# ------------------------------------------------ segsum write-back dedup
def test_unique_write_plan():
    import jax.numpy as jnp
    from repro.kernels.sgns import _unique_write_plan
    sorted_idx = jnp.asarray(np.array([2, 2, 2, 5, 7, 7], np.int32))
    upos, n = jax.jit(_unique_write_plan)(sorted_idx)
    assert int(n[0]) == 3
    # each run's LAST sorted position (any position holds the final bytes)
    assert list(np.asarray(upos)[:3]) == [2, 3, 5]
    upos1, n1 = jax.jit(_unique_write_plan)(
        jnp.asarray(np.full(8, 4, np.int32)))
    assert int(n1[0]) == 1 and int(np.asarray(upos1)[0]) == 7


def test_segsum_dedup_parity_skewed_batch():
    """Hub-dominated batch (few distinct rows, long runs) through the
    deduplicated write-back still matches the reference scatter-add."""
    import jax.numpy as jnp
    from repro.kernels import ref, sgns
    rng = np.random.default_rng(3)
    Nv, Nc, d, B, S = 40, 50, 32, 64, 8
    vert = jnp.asarray(rng.standard_normal((Nv, d)).astype(np.float32))
    ctx = jnp.asarray(rng.standard_normal((Nc, d)).astype(np.float32))
    iv = jnp.asarray(rng.zipf(1.5, B).clip(max=Nv).astype(np.int32) - 1)
    ic = jnp.asarray(rng.zipf(1.5, B).clip(max=Nc).astype(np.int32) - 1)
    inn = jnp.asarray(rng.integers(0, 4, S).astype(np.int32))
    mask = jnp.ones(B)
    lr = jnp.float32(0.05)
    v0, c0, l0 = ref.sgns_step_ref(vert, ctx, iv, ic, inn, mask, lr)
    v2, c2, l2 = sgns.sgns_fused_update(vert, ctx, iv, ic, inn, mask, lr,
                                        block_b=32, combine="segsum",
                                        interpret=True)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v2), rtol=1e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c2), rtol=1e-4,
                               atol=1e-6)


# ------------------------------------------------------------- VMEM models
def test_fused_vmem_model_staging_rows():
    from repro.kernels import ops
    base = ops.fused_update_vmem_bytes(256, 64, 8, np.float32, "segsum")
    ext = ops.fused_update_vmem_bytes(256, 64, 8, np.float32, "segsum",
                                      staging_rows=512)
    assert ext == base + 512 * 64 * 4
    # default keeps the pre-tiering plan byte-identical
    p0 = ops.plan_fused_update(256, 64, 8, np.float32)
    p1 = ops.plan_fused_update(256, 64, 8, np.float32, staging_rows=0)
    assert p0 == p1
    # a huge staging block must shrink (never grow) the tile/chunk choice
    p2 = ops.plan_fused_update(4096, 512, 8, np.float32,
                               staging_rows=20_000)
    assert p2.block_b <= p0.block_b or p2.chunk_rows <= 4096


def test_topk_vmem_model_hot_rows():
    from repro.embed_serve import topk as tk
    base = tk.topk_scan_vmem_bytes(256, 64, np.int8)
    ext = tk.topk_scan_vmem_bytes(256, 64, np.int8, hot_rows=128)
    assert ext == base + 128 * 64 * 4
    # hot tile caps at the scan tile size
    cap = tk.topk_scan_vmem_bytes(256, 64, np.int8, hot_rows=10**6)
    assert cap == base + 256 * 64 * 4
    assert tk.choose_block_n(64, np.int8) == tk.choose_block_n(
        64, np.int8, hot_rows=0)
    # enough hot-tier pressure pushes the cold-scan tile down
    assert tk.choose_block_n(4096, np.float32, hot_rows=4096) <= \
        tk.choose_block_n(4096, np.float32)


def test_cache_stats_dataclass():
    s = CacheStats()
    assert s.hit_rate == 0.0
    s.hits, s.misses = 3, 1
    assert s.hit_rate == 0.75
    assert s.as_dict()["hit_rate"] == 0.75
