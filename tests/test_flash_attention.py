"""Flash-attention Pallas kernel vs oracle: shape/dtype sweep + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention, mha_ref

KEY = jax.random.PRNGKey(0)


def _qkv(B, H, Hkv, Sq, Skv, hd, dtype=jnp.float32):
    q = (jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, Sq, hd)) * 0.5
         ).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, Skv, hd)) * 0.5
         ).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(KEY, 3), (B, Hkv, Skv, hd)) * 0.5
         ).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,Hkv,Sq,Skv,hd,causal,window,tq,tk", [
    (2, 4, 4, 64, 64, 32, True, 0, 32, 32),
    (1, 4, 2, 64, 128, 32, True, 0, 32, 64),      # GQA
    (2, 2, 2, 96, 96, 16, True, 24, 32, 32),      # sliding window
    (1, 2, 1, 64, 64, 64, False, 0, 64, 32),      # cross-attn style
    (1, 8, 8, 128, 128, 8, True, 0, 128, 32),
])
def test_flash_matches_ref(B, H, Hkv, Sq, Skv, hd, causal, window, tq, tk):
    q, k, v = _qkv(B, H, Hkv, Sq, Skv, hd)
    o0 = mha_ref(q, k, v, causal=causal, window=window)
    o1 = flash_attention(q, k, v, causal=causal, window=window,
                         tile_q=tq, tile_k=tk, interpret=True)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1),
                               rtol=2e-4, atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 2, 2, 64, 64, 32, jnp.bfloat16)
    o0 = mha_ref(q, k, v)
    o1 = flash_attention(q, k, v, tile_q=32, tile_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o0, np.float32),
                               np.asarray(o1, np.float32),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=6, deadline=None)
@given(tq=st.sampled_from([16, 32, 64]), tk=st.sampled_from([16, 32, 64]))
def test_flash_tile_invariance(tq, tk):
    """Property: output must not depend on the VMEM tiling."""
    q, k, v = _qkv(1, 2, 2, 64, 64, 16)
    base = flash_attention(q, k, v, tile_q=64, tile_k=64, interpret=True)
    out = flash_attention(q, k, v, tile_q=tq, tile_k=tk, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out),
                               rtol=2e-4, atol=2e-5)


def test_flash_rows_are_convex_combinations():
    """Property: each output row lies in the convex hull of v rows (softmax
    weights sum to 1) — catches denominator/accumulator bugs."""
    q, k, v = _qkv(1, 1, 1, 32, 32, 8)
    v = jnp.ones_like(v)  # all-ones values => output must be exactly ones
    out = flash_attention(q, k, v, tile_q=16, tile_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)
