"""Sharding rule engine invariants (no devices needed beyond 1 — we only
build PartitionSpecs against an abstract mesh via mock shapes)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sharding.specs import batch_spec, spec_for


class FakeMesh:
    """Duck-typed mesh: .shape mapping + .axis_names (spec_for needs only
    these)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisible(shape, spec, mesh):
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        assert shape[dim] % n == 0, (shape, spec)


@settings(max_examples=60, deadline=None)
@given(
    name=st.sampled_from(["embed", "wq", "wk", "wo", "w_gate", "w_down",
                          "in_proj", "router", "unknown_leaf"]),
    shape=st.lists(st.sampled_from([1, 3, 8, 16, 20, 64, 128, 151936, 7168]),
                   min_size=1, max_size=4),
    mesh=st.sampled_from([MESH1, MESH2]),
    offset=st.sampled_from([0, 1]),
)
def test_spec_always_divides(name, shape, mesh, offset):
    spec = spec_for(tuple(shape), name, mesh, offset=offset)
    assert len(spec) <= len(shape)
    _check_divisible(shape, tuple(spec) + (None,) * (len(shape) - len(spec)),
                     mesh)


def test_stacked_offset_protects_group_dim():
    # stacked expert weights (G, E, d, ff): G must stay unsharded; 256
    # experts on a 256-chip mesh get 2-D EP over (data x model)
    spec = spec_for((58, 256, 7168, 2048), "w_gate", MESH1, offset=1)
    assert spec[0] is None
    assert _norm(spec[1]) == ("data", "model")
    # 16 experts (phi/jamba) fall back to model-axis EP
    spec16 = spec_for((32, 16, 4096, 6400), "w_gate", MESH1, offset=1)
    assert spec16[0] is None
    assert spec16[1] == "model"


def test_vocab_sharded_over_model():
    spec = spec_for((151936, 2560), "embed", MESH1)
    assert spec[0] == "model"


def test_nondivisible_heads_fall_back():
    # qwen1.5-4b: 20 heads on a 16-wide model axis -> not head-sharded
    spec = spec_for((24, 2560, 20, 128), "wq", MESH1, offset=1)
    assert spec[2] is None or spec[2] != "model" or 20 % 16 == 0
    _check_divisible((24, 2560, 20, 128), tuple(spec) + (None,) * 4, MESH1)


def test_small_params_not_fsdp_sharded():
    spec = spec_for((64,), "ln1", MESH1)
    assert all(s is None for s in spec)


def _norm(entry):
    """PartitionSpec normalizes 1-tuples to plain strings."""
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


@pytest.mark.parametrize("B,expect_axes", [
    (256, ("data",)), (16, ("data",)), (8, ()), (1, ()),
])
def test_batch_spec_single_pod(B, expect_axes):
    m = FakeMesh({"data": 16, "model": 16})
    bs = batch_spec(B, m)
    got = _norm(bs[0]) if len(bs) else ()
    assert got == expect_axes


def test_batch_spec_multipod():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert _norm(batch_spec(256, m)[0]) == ("pod", "data")
    assert _norm(batch_spec(32, m)[0]) == ("pod", "data")
    assert _norm(batch_spec(16, m)[0]) == ("pod",)
