"""True multi-device correctness, via subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (the main pytest process
deliberately stays single-device; see conftest.py)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 420):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_moe_ep_matches_oracle_4dev():
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.config import ModelConfig
from repro.models import mlp
cfg = ModelConfig(name='t', arch_type='moe', num_layers=1, d_model=64,
                  num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
                  moe_num_experts=8, moe_top_k=2, moe_d_ff=96,
                  moe_capacity_factor=8.0)
key = jax.random.PRNGKey(0)
params = mlp.init_moe_params(key, cfg)
mesh = jax.make_mesh((2, 2), ('data', 'model'))
x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 64))
y0, _ = mlp.moe_ref(params, x, cfg)
xs = jax.device_put(x, NamedSharding(mesh, P('data', 'model', None)))
y1, _ = jax.jit(lambda p, xx: mlp.moe_forward(p, xx, cfg, mesh=mesh))(params, xs)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4, atol=1e-4)
# decode/quota path
x1 = jax.random.normal(jax.random.fold_in(key, 2), (4, 1, 64))
y0, _ = mlp.moe_ref(params, x1, cfg)
xs1 = jax.device_put(x1, NamedSharding(mesh, P('data', None, None)))
y1, _ = jax.jit(lambda p, xx: mlp.moe_forward(p, xx, cfg, mesh=mesh))(params, xs1)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4, atol=1e-4)
print('OK')
""")


@pytest.mark.slow
def test_hybrid_multidevice_quality_parity():
    """The rotation schedule on a 2x2 mesh with k=2 sub-parts must reach the
    same quality as single-device training (the paper's Fig. 5 claim)."""
    run_py(r"""
import jax, numpy as np
from repro.core import HybridConfig, HybridEmbeddingTrainer, build_episode_blocks
from repro.core import eval as ev
from repro.graph.csr import build_csr
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine
rng = np.random.default_rng(0)
n = 1200
comm = rng.integers(0, 12, n)
src, dst = [], []
for _ in range(30):
    a = rng.integers(0, n, 20000); b = rng.integers(0, n, 20000)
    keep = rng.random(20000) < np.where(comm[a]==comm[b], 0.08, 0.001)
    src.append(a[keep]); dst.append(b[keep])
g_full = build_csr(np.stack([np.concatenate(src), np.concatenate(dst)],1), n)
train_e, test_e = ev.split_edges(g_full, 0.05, seed=1)
g = build_csr(train_e, n, symmetrize=False, dedup=False)
neg_e = ev.sample_negative_pairs(g_full, len(test_e), seed=3)

def run(mesh_shape, k):
    mesh = jax.make_mesh(mesh_shape, ('data','model'))
    cfg = HybridConfig(dim=64, minibatch=32, negatives=8, subparts=k,
                       neg_pool=2048, lr=0.025)
    tr = HybridEmbeddingTrainer(n, mesh, cfg, degrees=g.degrees())
    tr.init_embeddings()
    store = MemorySampleStore()
    E = 10
    for epoch in range(E):
        WalkEngine(g, WalkConfig(walk_length=10, window=5, episodes=1,
                                 seed=epoch), store).run_epoch(epoch)
        eb = build_episode_blocks(np.asarray(store.get(epoch,0)), tr.part,
                                  pad_multiple=32)
        assert eb.dropped == 0
        tr.train_episode(eb, lr=0.025*max(1-epoch/E, 0.05))
        store.drop_epoch(epoch)
    V = tr.embeddings()
    Vn = V/(np.linalg.norm(V,axis=1,keepdims=True)+1e-9)
    return ev.auc_score(np.einsum('ij,ij->i', Vn[test_e[:,0]], Vn[test_e[:,1]]),
                        np.einsum('ij,ij->i', Vn[neg_e[:,0]], Vn[neg_e[:,1]]))

a1 = run((1,1), 1)
a4 = run((2,2), 2)
print('auc1', a1, 'auc4', a4)
assert a4 > a1 - 0.04, (a1, a4)
""")


@pytest.mark.slow
def test_lm_train_step_sharded_4dev():
    """One sharded LM train step on a 2x2 mesh (GSPMD path end-to-end)."""
    run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro import configs as cfgs
from repro.models import transformer as tfm
from repro.sharding.specs import param_shardings
from repro.train.train_step import make_train_step, synthetic_batch
import dataclasses
cfg = cfgs.get_config('phi3.5-moe-42b-a6.6b').reduced(layers=2, d_model=256,
                                                      experts=4)
cfg = dataclasses.replace(cfg, train_microbatches=2)
mesh = jax.make_mesh((2, 2), ('data', 'model'))
params = tfm.init_params(jax.random.PRNGKey(0), cfg)
p_sh = param_shardings(params, mesh)
params = jax.device_put(params, p_sh)
step_fn, opt = make_train_step(cfg, mesh=mesh, data_axes=('data',))
opt_state = jax.device_put(opt.init(params), param_shardings(
    jax.eval_shape(opt.init, params), mesh))
batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, 4, 32).items()}
with mesh:
    p2, o2, m = jax.jit(step_fn)(params, opt_state, jnp.int32(0), batch)
assert np.isfinite(float(m['loss']))
print('loss', float(m['loss']))
""")
