"""Optimizers + checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.optimizer import make_optimizer


@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = make_optimizer(name, lr=0.1)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5),
              "mat": jnp.ones((4, 8))}
    state = opt.init(params)

    def loss(p):
        return (jnp.sum(p["w"] ** 2) + p["b"] ** 2
                + jnp.sum(p["mat"] ** 2))

    l0 = float(loss(params))
    for step in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, jnp.int32(step))
    assert float(loss(params)) < 0.5 * l0


def test_adafactor_state_is_factored():
    opt = make_optimizer("adafactor", lr=1e-2)
    params = {"big": jnp.ones((64, 128)), "vec": jnp.ones((7,))}
    st = opt.init(params)
    assert st["f"]["big"]["vr"].shape == (64,)
    assert st["f"]["big"]["vc"].shape == (128,)
    assert st["f"]["vec"]["v"].shape == (7,)


def test_adamw_dtype_preserved():
    opt = make_optimizer("adamw", lr=1e-2)
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = opt.init(params)
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new, st = opt.update(grads, st, params, jnp.int32(0))
    assert new["w"].dtype == jnp.bfloat16
    assert st["m"]["w"].dtype == jnp.float32  # master stats in f32


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"x": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "b": [np.ones(4, np.int32), np.zeros((2, 2), np.float32)]}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, step=7)
    tpl = jax.tree.map(jnp.asarray, tree)
    restored, step = restore_checkpoint(path, tpl)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.npz")
    save_checkpoint(path, {"w": np.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(path, {"w": jnp.ones((3, 3))})
