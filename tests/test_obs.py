"""Telemetry layer (repro.obs): registry math, tracer, and integration.

Covers the observability PR's acceptance points: histogram percentiles
against the numpy inverted-CDF oracle (including past the reservoir cap),
thread-safety of counters and the tracer under a hammer, the disabled-mode
zero-allocation guarantee (the fault_point design rule), the pipeline
timing fixes (sync builds record, out-of-prefetch-order consumption no
longer loses timings), the metrics sink, and diagnostics.json on ANY
fatal launcher exception — metrics snapshot included.
"""
import json
import math
import os
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.obs import Histogram, Registry, Tracer
from repro.obs import trace as obs_trace


@pytest.fixture(autouse=True)
def _reset_obs():
    """Telemetry is process-global state; never leak it across tests."""
    obs.disable()
    obs.set_tracer(None)
    yield
    obs.disable()
    obs.set_tracer(None)


# ---------------------------------------------------------------------------
# histogram: exact percentiles vs the numpy oracle, reservoir behaviour
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 3, 17, 100, 999, 4096])
def test_histogram_percentiles_match_numpy_inverted_cdf(n):
    rng = np.random.default_rng(n)
    vals = rng.normal(size=n)
    h = Histogram(cap=4096)
    for v in vals:
        h.observe(v)
    for q in (0, 1, 50, 95, 99, 100):
        assert h.percentile(q) == np.percentile(vals, q,
                                                method="inverted_cdf")
    s = h.summary()
    assert s["count"] == n and s["exact"]
    assert s["min"] == vals.min() and s["max"] == vals.max()
    assert s["sum"] == pytest.approx(float(vals.sum()), rel=1e-12)
    assert s["mean"] == pytest.approx(float(vals.mean()), rel=1e-12)
    assert (s["p50"], s["p95"], s["p99"]) == tuple(
        np.percentile(vals, q, method="inverted_cdf") for q in (50, 95, 99))


def test_histogram_reservoir_bounded_and_deterministic():
    n, cap = 20_000, 256
    rng = np.random.default_rng(7)
    vals = rng.random(n)
    h1, h2 = Histogram(cap=cap), Histogram(cap=cap)
    for v in vals:
        h1.observe(v)
        h2.observe(v)
    # bounded memory, exact moments, sampled percentiles
    assert len(h1._values) == cap
    s = h1.summary()
    assert s["count"] == n and not s["exact"]
    assert s["min"] == vals.min() and s["max"] == vals.max()
    assert s["sum"] == pytest.approx(float(vals.sum()), rel=1e-9)
    assert abs(s["p50"] - 0.5) < 0.12       # uniform(0,1) median via sample
    # deterministic per-histogram RNG: identical streams, identical summary
    assert s == h2.summary()


def test_histogram_empty_summary():
    h = Histogram()
    s = h.summary()
    assert s["count"] == 0 and s["p50"] is None and s["min"] is None
    assert math.isnan(h.percentile(50))


def test_registry_kind_collision_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.histogram("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


# ---------------------------------------------------------------------------
# thread hammer: counters and spans under contention stay exact
# ---------------------------------------------------------------------------
def test_counter_hammer_multithreaded_is_exact():
    reg = obs.enable()
    threads, per = 8, 5_000

    def work():
        for _ in range(per):
            obs.counter_add("hammer")
            obs.counter_add("hammer.by3", 3)
            obs.observe("hammer.hist", 1.0)

    ts = [threading.Thread(target=work) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter("hammer").value == threads * per
    assert reg.counter("hammer.by3").value == 3 * threads * per
    assert reg.histogram("hammer.hist").count == threads * per


def test_tracer_span_hammer_multithreaded():
    tr = Tracer()
    obs.set_tracer(tr)
    threads, per = 8, 250

    def work():
        for i in range(per):
            with obs_trace.span("unit", "train", {"i": i}):
                pass

    ts = [threading.Thread(target=work) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert tr.event_count() == threads * per
    assert tr.dropped == 0


def test_tracer_bounded_past_cap():
    tr = Tracer(max_events=10)
    for _ in range(50):
        tr.instant("tick", "train")
    assert tr.event_count() == 10
    assert tr.dropped == 40
    assert tr.to_json()["otherData"]["dropped_events"] == 40


# ---------------------------------------------------------------------------
# disabled mode: the fault_point rule — no allocation on the hot path
# ---------------------------------------------------------------------------
def test_disabled_span_is_shared_noop_singleton():
    assert obs_trace.span("a", "walk") is obs_trace.span("b", "serve")


def test_disabled_helpers_allocate_nothing():
    """With no registry/tracer installed, every helper must be one
    module-level None check: zero allocations attributed to repro.obs."""
    obs_dir = os.path.dirname(obs.__file__)

    def hot_loop():
        for _ in range(200):
            obs.counter_add("c")
            obs.counter_add("c", 5)
            obs.gauge_set("g", 1.0)
            obs.observe("h", 0.5)
            obs.trace_counter("tc", 3)
            obs.instant("i", "walk")
            with obs_trace.span("s", "train"):
                pass

    hot_loop()                      # warm caches before measuring
    tracemalloc.start()
    try:
        before = tracemalloc.take_snapshot()
        hot_loop()
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    stats = after.compare_to(before, "lineno")
    leaked = [s for s in stats
              if s.traceback[0].filename.startswith(obs_dir)
              and s.size_diff > 0]
    assert not leaked, [str(s) for s in leaked]


# ---------------------------------------------------------------------------
# trace JSON shape: Perfetto-loadable, named ordered tracks
# ---------------------------------------------------------------------------
def test_trace_json_shape_and_roundtrip(tmp_path):
    tr = Tracer()
    obs.set_tracer(tr)
    with obs_trace.span("build", "build", {"episode": 0}):
        time.sleep(0.001)
    tr.add_span("recv_episode", "host:w1", 10.0, 250.0, {"chunks": 3})
    obs_trace.trace_counter("store.resident", 2)
    obs.set_tracer(None)

    j = tr.to_json()
    path = str(tmp_path / "trace.json")
    tr.save(path)
    with open(path) as f:
        assert json.load(f) == j

    evs = j["traceEvents"]
    names = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    # canonical lanes pinned in fixed order, dynamic lane appended after
    for i, track in enumerate(obs_trace.PIPELINE_TRACKS):
        assert names[track] == i + 1
    assert names["host:w1"] > len(obs_trace.PIPELINE_TRACKS)
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"build", "recv_episode"}
    for e in xs:
        assert e["pid"] == 1 and e["ts"] >= 0 and e["dur"] >= 0
    counters = [e for e in evs if e["ph"] == "C"]
    assert counters and counters[0]["args"]["value"] == 2


# ---------------------------------------------------------------------------
# registry sources: collector surfaces fold into one snapshot
# ---------------------------------------------------------------------------
def test_snapshot_sources_poll_and_capture_errors():
    reg = obs.enable()
    obs.counter_add("a.frames", 4)
    obs.gauge_set("a.depth", 7)
    obs.register_source("good", lambda: {"leases": 2})
    obs.register_source("bad", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["counters"]["a.frames"] == 4
    assert snap["gauges"]["a.depth"] == 7
    assert snap["sources"]["good"] == {"leases": 2}
    assert "ZeroDivisionError" in snap["sources"]["bad"]["error"]
    obs.unregister_source("bad")
    assert "bad" not in reg.snapshot()["sources"]
    json.dumps(snap, default=str)       # the whole snapshot serializes


# ---------------------------------------------------------------------------
# pipeline timing fixes (satellite: sync builds + out-of-order retention)
# ---------------------------------------------------------------------------
def _mk_pipe(store_depth=None, **kw):
    from repro.core import EpisodePipeline
    from repro.core.partition import NodePartition
    from repro.walk import MemorySampleStore

    rng = np.random.default_rng(0)
    store = MemorySampleStore() if store_depth is None else \
        MemorySampleStore(depth=store_depth)
    for ep in range(4):
        store.put(0, ep, rng.integers(0, 100, size=(60, 2)).astype(np.int32))
    part = NodePartition(100, dims=(1,), subparts=1)
    return EpisodePipeline(store, part, pad_multiple=8, **kw)


def test_pipeline_sync_build_records_stage_timings():
    """An episode built on the prefetch-miss path (no prefetch() call) must
    record the same per-stage timings as a prefetched one — and the registry
    histograms must see them too."""
    reg = obs.enable()
    pipe = _mk_pipe(stage_fn=lambda eb: eb)
    try:
        pipe.get(0, 0)                        # never prefetched: sync build
        times = pipe.pop_times(0, 0)
        assert set(times) == {"walk_wait_s", "build_s", "stage_s"}
        assert all(v >= 0 for v in times.values())
        hists = reg.snapshot()["histograms"]
        for name in ("pipeline.walk_wait_s", "pipeline.build_s",
                     "pipeline.stage_s"):
            assert hists[name]["count"] == 1
    finally:
        pipe.close()


def test_pipeline_out_of_order_consumption_keeps_timings():
    """Consuming prefetched episodes out of order used to sweep the timings
    of every not-yet-popped episode; now they survive until popped (or
    until the bounded-cap eviction, far away)."""
    pipe = _mk_pipe(depth=4)
    try:
        pipe.prefetch_window(0, 0, 3)
        for ep in (0, 1, 2):
            pipe.get(0, ep)
        # pop AFTER all gets — the old liveness sweep deleted these
        for ep in (0, 1, 2):
            times = pipe.pop_times(0, ep)
            assert set(times) == {"walk_wait_s", "build_s"}, (ep, times)
        assert pipe.pop_times(0, 2) == {}     # pop is consume-once
    finally:
        pipe.close()


def test_pipeline_times_dict_is_bounded():
    pipe = _mk_pipe()
    try:
        for i in range(pipe._times_cap * 3):
            pipe._record((0, i), "build_s", 0.001)
        assert len(pipe._times) == pipe._times_cap
        assert pipe.pop_times(0, 0) == {}                      # oldest gone
        assert pipe.pop_times(0, pipe._times_cap * 3 - 1)      # newest kept
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# store + batcher integration: counters/gauges/histograms flow when enabled
# ---------------------------------------------------------------------------
def test_store_metrics_flow():
    from repro.walk import MemorySampleStore

    reg = obs.enable()
    store = MemorySampleStore()
    pairs = np.zeros((5, 2), np.int32)
    store.put(0, 0, pairs)
    store.get(0, 0)
    store.drop(0, 0)
    snap = reg.snapshot()
    assert snap["counters"]["store.puts"] == 1
    assert snap["counters"]["store.gets"] == 1
    assert snap["gauges"]["store.resident"] == 0
    assert snap["histograms"]["store.put_wait_s"]["count"] == 1
    assert snap["histograms"]["store.get_blocked_s"]["count"] == 1


def test_batcher_metrics_and_source_lifecycle():
    from repro.embed_serve import MicroBatcher

    reg = obs.enable()

    def serve_fn(q):
        return q.sum(axis=1, keepdims=True), \
            np.zeros((q.shape[0], 1), np.int64)

    b = MicroBatcher(serve_fn, dim=4, max_batch=8, window_ms=1.0)
    try:
        assert "serve.batcher" in reg.snapshot()["sources"]
        futs = [b.submit(np.ones(4, np.float32)) for _ in range(5)]
        for f in futs:
            f.result(timeout=30)
    finally:
        b.close()
    snap = reg.snapshot()
    assert "serve.batcher" not in snap["sources"]   # unregistered at close
    assert snap["histograms"]["serve.request_s"]["count"] == 5
    assert "serve.queue_depth" in snap["gauges"]


# ---------------------------------------------------------------------------
# metrics sink: periodic jsonl + final summary
# ---------------------------------------------------------------------------
def test_metrics_writer_jsonl_and_summary(tmp_path):
    reg = obs.enable()
    obs.counter_add("sink.test", 42)
    w = obs.MetricsWriter(reg, str(tmp_path), interval_s=0.05)
    time.sleep(0.25)
    w.close()
    assert w.last_error is None
    with open(w.path) as f:
        lines = [json.loads(line) for line in f if line.strip()]
    assert len(lines) >= 2                  # periodic lines + the close line
    for snap in lines:
        assert {"ts", "elapsed_s", "counters", "gauges", "histograms",
                "sources"} <= set(snap)
        assert snap["counters"]["sink.test"] == 42
    with open(w.summary_path) as f:
        summary = json.load(f)
    assert summary["lines_written"] == len(lines)
    assert summary["counters"]["sink.test"] == 42


# ---------------------------------------------------------------------------
# launcher: ANY fatal exception dumps diagnostics.json with the metrics snap
# ---------------------------------------------------------------------------
_TRAIN_ARGS = ["--arch", "tencent-embedding", "--nodes", "240", "--dim", "16",
               "--epochs", "2", "--episodes", "3", "--subparts", "2",
               "--minibatch", "32", "--negatives", "4", "--neg-pool", "256",
               "--walk-workers", "2", "--seed", "3"]


def test_train_dumps_diagnostics_with_metrics_on_any_fatal(tmp_path):
    """A crash that is neither StoreStalled nor TransportError (here an
    InjectedFault) must still leave OUT_DIR/diagnostics.json — with the
    telemetry registry folded in when --metrics-dir enabled it."""
    from repro.launch.train import main as train_main
    from repro.runtime import InjectedFault

    out = str(tmp_path / "run")
    mdir = str(tmp_path / "metrics")
    with pytest.raises(InjectedFault):
        train_main(_TRAIN_ARGS + [
            "--out-dir", out, "--metrics-dir", mdir,
            "--metrics-interval-s", "0.2",
            "--inject", "train.episode:crash:key=0/1"])
    with open(os.path.join(out, "diagnostics.json")) as f:
        diag = json.load(f)
    assert diag["error"] == "InjectedFault"
    m = diag["metrics"]
    assert m["counters"]["walk.chunks"] >= 1
    assert m["counters"]["train.episodes"] == 1      # died before (0, 1)
    assert m["histograms"]["pipeline.build_s"]["count"] >= 1
    # the sink closed cleanly on the failure path too
    assert os.path.exists(os.path.join(mdir, "metrics_summary.json"))
    # the launcher's finally tore the global registry down
    assert obs.active() is None
