"""MoE: dense-oracle equivalence on a 1x1 mesh (full shard_map path),
capacity semantics, router invariants. True multi-device equivalence is in
test_distributed.py (subprocess with 4 host devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mlp
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)


def make_cfg(E=4, k=2, cap=8.0, shared=0):
    return ModelConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                       moe_num_experts=E, moe_top_k=k, moe_d_ff=48,
                       moe_num_shared=shared, moe_capacity_factor=cap)


def test_ep_matches_oracle_single_device():
    cfg = make_cfg()
    params = mlp.init_moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8, 32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y0, _ = mlp.moe_ref(params, x, cfg)
    y1, _ = jax.jit(lambda p, xx: mlp.moe_forward(p, xx, cfg, mesh=mesh))(
        params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


def test_ep_quota_path_single_device():
    cfg = make_cfg()
    params = mlp.init_moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 1, 32))  # decode
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y0, _ = mlp.moe_ref(params, x, cfg)
    y1, _ = jax.jit(lambda p, xx: mlp.moe_forward(p, xx, cfg, mesh=mesh))(
        params, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


def test_shared_expert_added():
    cfg = make_cfg(shared=1)
    params = mlp.init_moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 8, 32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y1, _ = jax.jit(lambda p, xx: mlp.moe_forward(p, xx, cfg, mesh=mesh))(
        params, x)
    y0, _ = mlp.moe_ref(params, x, cfg)
    y0 = y0 + mlp.ffn_forward(params["shared"], x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


def test_topk_weights_normalized():
    cfg = make_cfg()
    params = mlp.init_moe_params(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 8, 32))
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, -1)
    topw, _ = jax.lax.top_k(probs, cfg.moe_top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, rtol=1e-5)


def test_aux_loss_uniform_is_one():
    """Perfectly balanced routing gives aux = E * k * (1/E) ... = k for
    top-k one-hot gates with uniform probs; sanity-bound the scale."""
    cfg = make_cfg(E=8, k=2)
    T, E = 128, 8
    probs = jnp.full((1, T, E), 1.0 / E)
    gates = jnp.zeros((1, T, E)).at[:, :, :2].set(0.5)  # all to experts 0,1
    aux_skew = mlp._aux_loss(probs, gates, cfg)
    gates_u = jnp.full((1, T, E), 0.25)  # spread evenly
    aux_uni = mlp._aux_loss(probs, gates_u, cfg)
    assert float(aux_skew) < float(aux_uni)  # frac counts nonzero gates


def test_capacity_drop_under_skew():
    """With capacity_factor ~1 and all tokens routed to one expert, the EP
    output loses most tokens (drop semantics) — it must differ from the
    oracle and stay finite."""
    cfg = make_cfg(E=4, k=1, cap=1.0)
    params = mlp.init_moe_params(KEY, cfg)
    params = dict(params, router=jnp.zeros((32, 4)).at[:, 0].set(10.0))
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 8, 32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y1, _ = jax.jit(lambda p, xx: mlp.moe_forward(p, xx, cfg, mesh=mesh))(
        params, x)
    assert np.isfinite(np.asarray(y1)).all()
