# NOTE: deliberately no XLA_FLAGS here — smoke tests must see the real
# (single) device. Multi-device behaviour is tested via subprocesses in
# test_distributed.py, and the 512-device production mesh only ever exists
# inside `python -m repro.launch.dryrun` (which sets the flag first-thing).
import numpy as np
import pytest


@pytest.fixture(scope="session")
def sbm_graph():
    """Small stochastic-block-model graph with real community structure."""
    from repro.graph.csr import build_csr
    rng = np.random.default_rng(0)
    n, k = 1200, 12
    comm = rng.integers(0, k, n)
    src, dst = [], []
    for _ in range(30):
        a = rng.integers(0, n, 20000)
        b = rng.integers(0, n, 20000)
        p = np.where(comm[a] == comm[b], 0.08, 0.001)
        keep = rng.random(20000) < p
        src.append(a[keep])
        dst.append(b[keep])
    edges = np.stack([np.concatenate(src), np.concatenate(dst)], 1)
    return build_csr(edges, n)
