# NOTE: deliberately no XLA_FLAGS here — smoke tests must see the real
# (single) device. Multi-device behaviour is tested via subprocesses in
# test_distributed.py, and the 512-device production mesh only ever exists
# inside `python -m repro.launch.dryrun` (which sets the flag first-thing).
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis is optional: several test modules use it for property tests, but
# the training container doesn't ship it. Install a stub that lets those
# modules import (so the rest of their tests run) and turns @given tests into
# skips. Strategy constructors only need to be call-able at decoration time.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import sys
    import types

    def _strategy(*args, **kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "text", "composite", "data"):
        setattr(_st, _name, _strategy)

    def _given(*args, **kwargs):
        def deco(fn):
            # a bare no-arg function — NOT functools.wraps(fn): preserving
            # fn's signature would make pytest treat the @given kwargs as
            # missing fixtures and error the test instead of skipping it
            def wrapper():
                pytest.skip("hypothesis not installed (stubbed in conftest)")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def sbm_graph():
    """Small stochastic-block-model graph with real community structure."""
    from repro.graph.csr import build_csr
    rng = np.random.default_rng(0)
    n, k = 1200, 12
    comm = rng.integers(0, k, n)
    src, dst = [], []
    for _ in range(30):
        a = rng.integers(0, n, 20000)
        b = rng.integers(0, n, 20000)
        p = np.where(comm[a] == comm[b], 0.08, 0.001)
        keep = rng.random(20000) < p
        src.append(a[keep])
        dst.append(b[keep])
    edges = np.stack([np.concatenate(src), np.concatenate(dst)], 1)
    return build_csr(edges, n)
