"""End-to-end behaviour of the paper's system (integration tests).

The quality bar mirrors the paper's Table IV claim shape: after training,
link-prediction AUC on held-out edges of a community-structured graph is
(a) far above chance and (b) at least as good as the GraphVite-style
parameter-server baseline trained with the identical schedule.
"""
import jax
import numpy as np
import pytest

from repro.core import (EpisodePipeline, HybridConfig, HybridEmbeddingTrainer,
                        ParameterServerTrainer, build_episode_blocks)
from repro.core import eval as ev
from repro.graph.csr import build_csr
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


def _train(trainer, g, epochs, cfg, seed0=0):
    store = MemorySampleStore()
    losses = []
    for epoch in range(epochs):
        eng = WalkEngine(g, WalkConfig(walk_length=10, window=5, episodes=1,
                                       seed=seed0 + epoch), store)
        eng.run_epoch(epoch)
        eb = build_episode_blocks(np.asarray(store.get(epoch, 0)),
                                  trainer.part, pad_multiple=cfg.minibatch)
        lr = cfg.lr * max(1 - epoch / epochs, 0.05)
        losses.append(trainer.train_episode(eb, lr=lr))
        store.drop_epoch(epoch)
    return losses


def _vv_auc(V, test_e, neg_e):
    Vn = V / (np.linalg.norm(V, axis=1, keepdims=True) + 1e-9)
    return ev.auc_score(
        np.einsum("ij,ij->i", Vn[test_e[:, 0]], Vn[test_e[:, 1]]),
        np.einsum("ij,ij->i", Vn[neg_e[:, 0]], Vn[neg_e[:, 1]]))


@pytest.fixture(scope="module")
def lp_setup():
    rng = np.random.default_rng(0)
    n, k = 1200, 12
    comm = rng.integers(0, k, n)
    src, dst = [], []
    for _ in range(30):
        a = rng.integers(0, n, 20000)
        b = rng.integers(0, n, 20000)
        keep = rng.random(20000) < np.where(comm[a] == comm[b], 0.08, 0.001)
        src.append(a[keep]); dst.append(b[keep])
    g_full = build_csr(np.stack([np.concatenate(src), np.concatenate(dst)], 1), n)
    train_e, test_e = ev.split_edges(g_full, 0.05, seed=1)
    g = build_csr(train_e, n, symmetrize=False, dedup=False)
    neg_e = ev.sample_negative_pairs(g_full, len(test_e), seed=3)
    return g, test_e, neg_e


def test_hybrid_learns_link_prediction(lp_setup):
    g, test_e, neg_e = lp_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = HybridConfig(dim=64, minibatch=32, negatives=8, subparts=2,
                       neg_pool=2048, lr=0.025)
    tr = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    tr.init_embeddings()
    losses = _train(tr, g, 12, cfg)
    assert losses[-1] < losses[0] * 0.6, losses
    auc = _vv_auc(tr.embeddings(), test_e, neg_e)
    assert auc > 0.72, auc


def test_hybrid_accuracy_not_worse_than_ps_baseline(lp_setup):
    """Paper claim: 'competitive or better accuracy' vs GraphVite."""
    g, test_e, neg_e = lp_setup
    cfg = HybridConfig(dim=64, minibatch=32, negatives=8, subparts=2,
                       neg_pool=2048, lr=0.025)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    hy = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    hy.init_embeddings()
    _train(hy, g, 8, cfg)
    auc_h = _vv_auc(hy.embeddings(), test_e, neg_e)

    ps = ParameterServerTrainer(g.num_nodes, 1, cfg, degrees=g.degrees())
    _train(ps, g, 8, cfg)
    auc_p = _vv_auc(ps.embeddings(), test_e, neg_e)
    assert auc_h > auc_p - 0.03, (auc_h, auc_p)


def test_subpart_pipelining_is_semantics_preserving(lp_setup):
    """fuse_subpart_permute only changes overlap structure, not math: the
    paper's k-sub-part ping-pong must give identical embeddings to the
    bulk-transfer variant on the same schedule."""
    g, _, _ = lp_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = []
    for fuse in (True, False):
        cfg = HybridConfig(dim=32, minibatch=64, negatives=4, subparts=2,
                           neg_pool=512, lr=0.05,
                           fuse_subpart_permute=fuse)
        tr = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                    degrees=g.degrees())
        tr.init_embeddings()
        _train(tr, g, 2, cfg)
        out.append(tr.embeddings())
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6, atol=1e-7)


def test_episode_pipeline_prefetch(lp_setup):
    g, _, _ = lp_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = HybridConfig(dim=32, minibatch=64, negatives=4, subparts=1,
                       neg_pool=512)
    tr = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    tr.init_embeddings()
    store = MemorySampleStore()
    eng = WalkEngine(g, WalkConfig(walk_length=6, window=3, episodes=3),
                     store)
    eng.start_async(0)
    pipe = EpisodePipeline(store, tr.part, pad_multiple=cfg.minibatch)
    pipe.prefetch(0, 0)
    for ep in range(3):
        eb = pipe.get(0, ep)
        if ep + 1 < 3:
            pipe.prefetch(0, ep + 1)
        loss = tr.train_episode(eb)
        assert np.isfinite(loss)
    eng.join()
    pipe.close()


def test_multistage_pipeline_staged_training(lp_setup):
    """Full streaming dataflow: multi-worker walks -> bounded store ->
    fetch/build/stage pipeline -> staged train. The store's resident bound
    must hold and the staged path must train identically to handing
    train_episode raw EpisodeBlocks."""
    from repro.core import StagedEpisodeBlocks

    g, _, _ = lp_setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = HybridConfig(dim=32, minibatch=64, negatives=4, subparts=2,
                       neg_pool=512)
    out = []
    for staged_mode in (True, False):
        tr = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg,
                                    degrees=g.degrees())
        tr.init_embeddings()
        store = MemorySampleStore(depth=2)
        eng = WalkEngine(g, WalkConfig(walk_length=6, window=3, episodes=3,
                                       workers=2, chunk_size=256), store)
        eng.start_async(0)
        pipe = EpisodePipeline(
            store, tr.part, pad_multiple=cfg.minibatch, depth=2,
            stage_fn=tr.stage_blocks if staged_mode else None,
            drop_consumed=True)
        try:
            for ep in range(3):
                pipe.prefetch_window(0, ep, 3)
                eb = pipe.get(0, ep)
                assert isinstance(eb, StagedEpisodeBlocks) == staged_mode
                loss = tr.train_episode(eb)
                assert np.isfinite(loss)
                times = pipe.pop_times(0, ep)
                assert set(times) >= ({"walk_wait_s", "build_s", "stage_s"}
                                      if staged_mode
                                      else {"walk_wait_s", "build_s"})
            eng.join()
        finally:
            pipe.close()
        assert store.peak_resident <= 2
        out.append(tr.embeddings())
    np.testing.assert_array_equal(out[0], out[1])


def test_streamed_blocks_bitwise_match_synchronous(lp_setup):
    """End-to-end parity gate: the streamed multi-worker dataflow must
    produce bitwise-identical episode blocks to the synchronous path for a
    fixed seed — walk sharding must not change the sample stream."""
    from repro.core.partition import NodePartition

    g, _, _ = lp_setup
    part = NodePartition(g.num_nodes, dims=(1, 2), subparts=2)
    wkw = dict(walk_length=8, window=4, episodes=3, seed=21, chunk_size=200)

    # synchronous reference: serial walker, direct builds
    store = MemorySampleStore()
    WalkEngine(g, WalkConfig(workers=1, **wkw), store).run_epoch(0)
    ref = [build_episode_blocks(np.asarray(store.get(0, ep)), part,
                                pad_multiple=32) for ep in range(3)]

    # streamed: 3 walk workers, bounded store, multi-stage pipeline
    store = MemorySampleStore(depth=2)
    eng = WalkEngine(g, WalkConfig(workers=3, **wkw), store)
    eng.start_async(0)
    pipe = EpisodePipeline(store, part, pad_multiple=32, depth=2,
                           drop_consumed=True)
    try:
        for ep in range(3):
            pipe.prefetch_window(0, ep, 3)
            got = pipe.get(0, ep)
            np.testing.assert_array_equal(got.blocks, ref[ep].blocks)
            np.testing.assert_array_equal(got.counts, ref[ep].counts)
            assert got.dropped == ref[ep].dropped
        eng.join()
    finally:
        pipe.close()
    assert store.peak_resident <= 2


class _EpisodeKeyedStore:
    """Fake sample store whose pairs encode (epoch, episode), so a stale
    prefetch is detectable in the built blocks."""

    def get(self, epoch, episode):
        rng = np.random.default_rng(1000 * epoch + episode)
        return rng.integers(0, 64, size=(128, 2), dtype=np.int64)


def test_episode_pipeline_prefetch_key_mismatch():
    """get(e2, ep2) after prefetch(e1, ep1) must NOT hand back (e1, ep1)'s
    blocks: the prefetch is keyed, and a miss falls back to a synchronous
    build of the requested episode."""
    from repro.core.partition import NodePartition

    part = NodePartition(64, dims=(1,), subparts=1)
    store = _EpisodeKeyedStore()
    pipe = EpisodePipeline(store, part, pad_multiple=16)
    try:
        want = build_episode_blocks(store.get(0, 1), part, pad_multiple=16)

        pipe.prefetch(0, 0)                      # stale: a different episode
        got = pipe.get(0, 1)
        np.testing.assert_array_equal(got.blocks, want.blocks)
        np.testing.assert_array_equal(got.counts, want.counts)

        pipe.prefetch(0, 1)                      # matching key: served as-is
        got = pipe.get(0, 1)
        np.testing.assert_array_equal(got.blocks, want.blocks)

        assert pipe.get(0, 1) is not None        # no prefetch: sync build
    finally:
        pipe.close()
