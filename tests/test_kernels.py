"""Per-kernel allclose vs the pure-jnp oracle (interpret mode on CPU),
swept over shapes/dtypes + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref, sgns


KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype=jnp.float32, k=0, scale=0.1):
    return (jax.random.normal(jax.random.fold_in(KEY, k), shape) * scale
            ).astype(dtype)


@pytest.mark.parametrize("B,d,S,block_b", [
    (128, 128, 16, 64),
    (256, 64, 8, 256),
    (512, 256, 32, 128),
    (64, 32, 4, 64),
])
def test_sgns_grads_matches_ref(B, d, S, block_b):
    v, c, n = _rand((B, d), k=1), _rand((B, d), k=2), _rand((S, d), k=3)
    mask = (jax.random.uniform(jax.random.fold_in(KEY, 4), (B,)) > 0.2
            ).astype(jnp.float32)
    l0, dv0, dc0, dn0 = ref.sgns_grads_ref(v, c, n, mask)
    l1, dv1, dc1, dn1 = sgns.sgns_grads(v, c, n, mask, block_b=block_b,
                                        interpret=True)
    np.testing.assert_allclose(l0, l1, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(dv0, dv1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dc0, dc1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dn0, dn1, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("N,d,B", [(50, 128, 20), (200, 64, 64), (7, 32, 9)])
def test_gather_rows(N, d, B):
    tbl = _rand((N, d), k=5)
    idx = jax.random.randint(jax.random.fold_in(KEY, 6), (B,), 0, N)
    out = sgns.gather_rows(tbl, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.gather_rows_ref(tbl, idx)))


@pytest.mark.parametrize("dup", [False, True])
def test_scatter_add_rows(dup):
    N, d, B = 40, 64, 32
    tbl = _rand((N, d), k=7)
    if dup:
        idx = jnp.zeros(B, jnp.int32).at[B // 2:].set(3)
    else:
        idx = jnp.asarray(np.random.default_rng(0).permutation(N)[:B])
    upd = _rand((B, d), k=8)
    out = sgns.scatter_add_rows(tbl, idx, upd, interpret=True)
    expect = ref.scatter_add_rows_ref(tbl, idx, upd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


def test_sgns_step_paths_agree():
    Nv, Nc, d, B, S = 64, 80, 128, 96, 16
    vert, ctx = _rand((Nv, d), k=9), _rand((Nc, d), k=10)
    iv = jax.random.randint(jax.random.fold_in(KEY, 11), (B,), 0, Nv)
    ic = jax.random.randint(jax.random.fold_in(KEY, 12), (B,), 0, Nc)
    inn = jax.random.randint(jax.random.fold_in(KEY, 13), (S,), 0, Nc)
    mask = jnp.ones(B)
    lr = jnp.float32(0.05)
    v0, c0, l0 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr, impl="ref")
    v1, c1, l1 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr, impl="pallas")
    np.testing.assert_allclose(l0, l1, rtol=3e-5)
    np.testing.assert_allclose(v0, v1, rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(c0, c1, rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("Bt", [16, 64])
def test_sgns_fused_grads_matches_ref(Bt):
    """The fused DMA-gather+grads kernel (the paper's CUDA hot loop,
    TPU-native) against the compose-of-oracles reference."""
    Nv, Nc, d, B, S = 70, 90, 64, 64, 8
    vert, ctx = _rand((Nv, d), k=40), _rand((Nc, d), k=41)
    iv = jax.random.randint(jax.random.fold_in(KEY, 42), (B,), 0, Nv)
    ic = jax.random.randint(jax.random.fold_in(KEY, 43), (B,), 0, Nc)
    inn = jax.random.randint(jax.random.fold_in(KEY, 44), (S,), 0, Nc)
    mask = (jax.random.uniform(jax.random.fold_in(KEY, 45), (B,)) > 0.2
            ).astype(jnp.float32)
    v, c, n = (ref.gather_rows_ref(vert, iv), ref.gather_rows_ref(ctx, ic),
               ref.gather_rows_ref(ctx, inn))
    l0, dv0, dc0, dn0 = ref.sgns_grads_ref(v, c, n, mask)
    l1, dv1, dc1, dn1 = sgns.sgns_fused_grads(vert, ctx, iv, ic, inn, mask,
                                              block_b=Bt, interpret=True)
    np.testing.assert_allclose(l0, l1, rtol=3e-5)
    np.testing.assert_allclose(dv0, dv1, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(dc0, dc1, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(dn0, dn1, rtol=1e-4, atol=1e-6)


def test_sgns_step_fused_path():
    Nv, Nc, d, B, S = 40, 50, 32, 32, 4
    vert, ctx = _rand((Nv, d), k=50), _rand((Nc, d), k=51)
    iv = jax.random.randint(jax.random.fold_in(KEY, 52), (B,), 0, Nv)
    ic = jax.random.randint(jax.random.fold_in(KEY, 53), (B,), 0, Nc)
    inn = jax.random.randint(jax.random.fold_in(KEY, 54), (S,), 0, Nc)
    mask = jnp.ones(B)
    lr = jnp.float32(0.05)
    v0, c0, l0 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr, impl="ref")
    v1, c1, l1 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr,
                               impl="pallas_fused")
    np.testing.assert_allclose(l0, l1, rtol=3e-5)
    np.testing.assert_allclose(v0, v1, rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(c0, c1, rtol=2e-4, atol=1e-6)


def _step_inputs(Nv, Nc, B, S, d, dtype=jnp.float32, kbase=60, dup=False):
    vert, ctx = _rand((Nv, d), k=kbase, dtype=dtype), _rand((Nc, d),
                                                            k=kbase + 1,
                                                            dtype=dtype)
    iv = jax.random.randint(jax.random.fold_in(KEY, kbase + 2), (B,), 0, Nv)
    ic = jax.random.randint(jax.random.fold_in(KEY, kbase + 3), (B,), 0, Nc)
    inn = jax.random.randint(jax.random.fold_in(KEY, kbase + 4), (S,), 0, Nc)
    if dup:
        # force heavy duplication: vertex 3 and context 5 repeat across the
        # batch, and a negative collides with a positive context row
        iv = iv.at[::3].set(3)
        ic = ic.at[::4].set(5)
        inn = inn.at[0].set(5)
    mask = (jax.random.uniform(jax.random.fold_in(KEY, kbase + 5), (B,))
            > 0.15).astype(jnp.float32)
    return vert, ctx, iv, ic, inn, mask


@pytest.mark.parametrize("dtype,rtol,atol", [
    (jnp.float32, 2e-4, 1e-6),
    # ref applies updates in bf16 sequentially; the fused kernel combines
    # duplicates in f32 then applies once — bf16 rounding differs
    (jnp.bfloat16, 3e-2, 3e-3),
])
@pytest.mark.parametrize("dup", [False, True])
def test_sgns_fused_update_matches_step_ref(dtype, rtol, atol, dup):
    """The fully-fused pipelined update kernel (gather + grads + in-kernel
    SGD apply) against the full sgns_step oracle: loss AND updated tables."""
    Nv, Nc, d, B, S = 70, 90, 64, 64, 8
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, dtype,
                                                dup=dup)
    lr = jnp.float32(0.05)
    v0, c0, l0 = ref.sgns_step_ref(vert, ctx, iv, ic, inn, mask, lr)
    v1, c1, l1 = sgns.sgns_fused_update(vert, ctx, iv, ic, inn, mask, lr,
                                        block_b=16, interpret=True)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v0, np.float32),
                               np.asarray(v1, np.float32), rtol=rtol,
                               atol=atol)
    np.testing.assert_allclose(np.asarray(c0, np.float32),
                               np.asarray(c1, np.float32), rtol=rtol,
                               atol=atol)


@pytest.mark.parametrize("impl", ["pallas_fused", "pallas_fused2"])
@pytest.mark.parametrize("B,block_b", [(37, 8), (97, 32), (64, 64), (5, 256)])
def test_sgns_step_fused_odd_batch(impl, B, block_b):
    """Both fused branches pad odd B to the block size; the padded (index 0,
    mask 0) rows must not corrupt row 0."""
    Nv, Nc, d, S = 40, 50, 32, 4
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, kbase=70)
    iv = iv.at[0].set(0)   # make row 0 a real update target too
    lr = jnp.float32(0.05)
    v0, c0, l0 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr, impl="ref")
    v1, c1, l1 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr,
                               impl=impl, block_b=block_b)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=2e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1), rtol=2e-4,
                               atol=1e-6)


def test_sgns_step_fused2_duplicate_scatter_accumulate():
    """Duplicate idx_v / idx_c (and idx_c∩idx_n collisions) must accumulate
    like the oracle's scatter-add — this is what verifies the fused branch
    needs no standalone scatter passes."""
    Nv, Nc, d, B, S = 30, 35, 32, 48, 8
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, kbase=80,
                                                dup=True)
    lr = jnp.float32(0.1)
    v0, c0, l0 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr, impl="ref")
    v1, c1, l1 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr,
                               impl="pallas_fused2", block_b=16)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=3e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1), rtol=3e-4,
                               atol=1e-6)


# --------------------------------------------------------------------------
# segment-sum duplicate-combine: parity vs the equality-matrix reference
# path (and the sgns_step oracle) across dtypes, odd B, heavy duplicates,
# and batch sizes past the old (B, B) wall.
# --------------------------------------------------------------------------
def _fused_both_combines(vert, ctx, iv, ic, inn, mask, lr, block_b):
    out = {}
    for combine in ("eq", "segsum"):
        out[combine] = sgns.sgns_fused_update(
            vert, ctx, iv, ic, inn, mask, lr, block_b=block_b,
            combine=combine, interpret=True)
    return out["eq"], out["segsum"]


@pytest.mark.parametrize("dtype,rtol,atol", [
    # both combines sum duplicate grads in f32 and apply one table-dtype
    # add; only the f32 summation ORDER differs, so f32 parity is tight and
    # bf16 can differ by at most the final-cast ulp
    (jnp.float32, 2e-6, 1e-7),
    (jnp.bfloat16, 1e-2, 1e-3),
])
@pytest.mark.parametrize("B,block_b", [(48, 16), (64, 64), (96, 32)])
def test_fused_update_segsum_matches_eq(dtype, rtol, atol, B, block_b):
    """segsum vs eq on heavy duplicates (incl. an idx_c/idx_n collision)."""
    Nv, Nc, d, S = 70, 90, 64, 8
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, dtype,
                                                kbase=100, dup=True)
    lr = jnp.float32(0.07)
    (v1, c1, l1), (v2, c2, l2) = _fused_both_combines(
        vert, ctx, iv, ic, inn, mask, lr, block_b)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v1, np.float32),
                               np.asarray(v2, np.float32), rtol=rtol,
                               atol=atol)
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), rtol=rtol,
                               atol=atol)


def test_fused_update_segsum_all_same_index():
    """Worst case for the combine: every position scatters to ONE vertex row
    and one ctx row (which the negatives also hit) — a single B-long run."""
    Nv, Nc, d, B, S = 40, 50, 32, 128, 8
    vert, ctx, *_ = _step_inputs(Nv, Nc, B, S, d, kbase=110)
    iv = jnp.full((B,), 7, jnp.int32)
    ic = jnp.full((B,), 9, jnp.int32)
    inn = jnp.full((S,), 9, jnp.int32)
    mask = jnp.ones(B)
    lr = jnp.float32(0.05)
    v0, c0, l0 = ref.sgns_step_ref(vert, ctx, iv, ic, inn, mask, lr)
    v2, c2, l2 = sgns.sgns_fused_update(vert, ctx, iv, ic, inn, mask, lr,
                                        block_b=32, combine="segsum",
                                        interpret=True)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-4)
    # a 128-term f32 sum reassociated: modest tolerance vs the oracle
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v2), rtol=1e-3,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c2), rtol=1e-3,
                               atol=1e-5)


@pytest.mark.parametrize("B", [37, 97])
def test_sgns_step_fused2_segsum_odd_batch_padding(B, monkeypatch):
    """Odd B through ops.sgns_step with the combine forced to segsum: the
    padded (index 0, mask 0) tail must fold into row 0's run harmlessly."""
    from repro.kernels import ops
    monkeypatch.setattr(
        ops, "plan_fused_update",
        lambda *a, **kw: ops.FusedPlan(block_b=16, combine="segsum",
                                       chunk_rows=1 << 30))
    Nv, Nc, d, S = 40, 50, 32, 4
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, kbase=120)
    iv = iv.at[0].set(0)   # make row 0 a real update target too
    lr = jnp.float32(0.05)
    v0, c0, l0 = ref.sgns_step_ref(vert, ctx, iv, ic, inn, mask, lr)
    v1, c1, l1 = ops.sgns_step.__wrapped__(
        vert, ctx, iv, ic, inn, mask, lr, impl="pallas_fused2")
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=2e-4,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1), rtol=2e-4,
                               atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(B=st.integers(2, 72), S=st.integers(1, 12),
       stride=st.integers(1, 4))
def test_fused_update_segsum_matches_eq_property(B, S, stride):
    """Property sweep: random geometry + a duplication stride; single-tile
    launch (block_b=B) so any B is legal."""
    Nv, Nc, d = 30, 35, 32
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, kbase=130)
    iv = iv.at[::stride].set(3)
    ic = ic.at[::stride].set(5)
    inn = inn.at[0].set(5)
    lr = jnp.float32(0.05)
    (v1, c1, l1), (v2, c2, l2) = _fused_both_combines(
        vert, ctx, iv, ic, inn, mask, lr, B)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=2e-6,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=2e-6,
                               atol=1e-7)


@pytest.mark.slow
def test_fused_update_segsum_B8192_no_quadratic_intermediate():
    """The acceptance gate: exact parity at B = 8192 (4x past the old ~2k
    equality-matrix cap) AND no (B, B) tensor anywhere in the lowered HLO."""
    import functools
    Nv = Nc = 4096
    d, B, S = 64, 8192, 16
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, kbase=140)
    mask = jnp.ones(B)
    lr = jnp.float32(0.05)
    fn = functools.partial(sgns.sgns_fused_update, block_b=256,
                           combine="segsum", interpret=True)
    hlo = jax.jit(fn).lower(vert, ctx, iv, ic, inn, mask, lr).as_text()
    assert f"{B},{B}" not in hlo, "O(B^2) combine intermediate leaked back in"
    v0, c0, l0 = ref.sgns_step_ref(vert, ctx, iv, ic, inn, mask, lr)
    v1, c1, l1 = fn(vert, ctx, iv, ic, inn, mask, lr)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=3e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1), rtol=3e-4,
                               atol=1e-5)


@pytest.mark.slow
def test_sgns_step_fused2_chunked_launches_B5120():
    """B past the plan's VMEM chunk limit: ops.sgns_step must split into
    sequential fused launches that match a ref oracle applied with the SAME
    chunk boundaries (chunking = coarser-grained sequential SGD)."""
    from repro.kernels import ops
    Nv = Nc = 1024
    d, B, S = 128, 5120, 16
    plan = ops.plan_fused_update(B, d, S, jnp.float32)
    assert plan.chunk_rows < B, plan    # the point of the test
    vert, ctx, iv, ic, inn, mask = _step_inputs(Nv, Nc, B, S, d, kbase=150)
    lr = jnp.float32(0.05)
    v1, c1, l1 = ops.sgns_step(vert, ctx, iv, ic, inn, mask, lr,
                               impl="pallas_fused2")
    v0, c0, loss0 = vert, ctx, 0.0
    for s in range(0, B, plan.chunk_rows):
        e = min(s + plan.chunk_rows, B)
        v0, c0, lc = ref.sgns_step_ref(v0, c0, iv[s:e], ic[s:e], inn,
                                       mask[s:e], lr)
        loss0 += float(lc)
    np.testing.assert_allclose(loss0, float(l1), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=3e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1), rtol=3e-4,
                               atol=1e-5)


def test_scatter_add_rows_per_block_dup_flags():
    """Duplicates ACROSS blocks (none within) stay correct — the sequential
    grid serializes blocks, so only intra-block collisions need the slow
    path. Also: padding sentinels must not fake a collision with real 0s."""
    N, d, rb = 40, 64, 8
    tbl = _rand((N, d), k=94)
    # 4 blocks, each a clean 0..7 permutation -> every row duplicated 4x
    idx = jnp.concatenate([jnp.arange(8, dtype=jnp.int32)] * 4)
    upd = _rand((32, d), k=95)
    out = sgns.scatter_add_rows(tbl, idx, upd, rows_per_block=rb,
                                interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.scatter_add_rows_ref(tbl, idx, upd)),
        rtol=1e-5, atol=1e-6)
    # odd B: last block is padded; real index 0 in it must not be treated
    # as colliding with the pad positions
    idx3 = jnp.zeros(29, jnp.int32).at[:14].set(jnp.arange(1, 15))
    out3 = sgns.scatter_add_rows(tbl, idx3, upd[:29], rows_per_block=rb,
                                 interpret=True)
    np.testing.assert_allclose(
        np.asarray(out3),
        np.asarray(ref.scatter_add_rows_ref(tbl, idx3, upd[:29])),
        rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("N,d,B,rb", [(50, 64, 20, 8), (30, 32, 9, 4),
                                      (64, 128, 64, 16)])
def test_gather_rows_blocked_matches_rowwise(N, d, B, rb):
    tbl = _rand((N, d), k=90)
    idx = jax.random.randint(jax.random.fold_in(KEY, 91), (B,), 0, N)
    blocked = sgns.gather_rows(tbl, idx, rows_per_block=rb, interpret=True)
    rowwise = sgns.gather_rows_rowwise(tbl, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(blocked), np.asarray(rowwise))


@pytest.mark.parametrize("dup", [False, True])
@pytest.mark.parametrize("rb", [4, 8])
def test_scatter_add_rows_blocked_matches_rowwise(dup, rb):
    N, d, B = 40, 64, 30   # B deliberately not a multiple of rb
    tbl = _rand((N, d), k=92)
    if dup:
        idx = jnp.zeros(B, jnp.int32).at[B // 2:].set(3)
    else:
        idx = jnp.asarray(np.random.default_rng(1).permutation(N)[:B])
    upd = _rand((B, d), k=93)
    blocked = sgns.scatter_add_rows(tbl, idx, upd, rows_per_block=rb,
                                    interpret=True)
    rowwise = sgns.scatter_add_rows_rowwise(tbl, idx, upd, interpret=True)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(rowwise),
                               rtol=1e-5, atol=1e-6)


def test_sgns_grads_is_true_gradient():
    """dv/dc/dn must equal autodiff gradients of the SGNS loss."""
    B, d, S = 32, 16, 8
    v, c, n = _rand((B, d), k=20), _rand((B, d), k=21), _rand((S, d), k=22)
    mask = jnp.ones(B)

    def loss_fn(v, c, n):
        return ref.sgns_grads_ref(v, c, n, mask)[0]

    gv, gc, gn = jax.grad(loss_fn, argnums=(0, 1, 2))(v, c, n)
    _, dv, dc, dn = ref.sgns_grads_ref(v, c, n, mask)
    np.testing.assert_allclose(gv, dv, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gc, dc, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gn, dn, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 64), d=st.sampled_from([8, 32, 128]),
       S=st.integers(1, 16))
def test_sgns_mask_zeroes_padding(B, d, S):
    """Property: fully-masked batches produce zero loss and zero grads."""
    v, c, n = _rand((B, d), k=30), _rand((B, d), k=31), _rand((S, d), k=32)
    loss, dv, dc, dn = ref.sgns_grads_ref(v, c, n, jnp.zeros(B))
    assert float(loss) == 0.0
    assert float(jnp.abs(dv).max()) == 0.0
    assert float(jnp.abs(dn).max()) == 0.0
