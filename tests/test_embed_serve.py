"""Retrieval-serving subsystem: Pallas top-k vs the numpy oracle, the
device-sharded store (checkpoint round-trip, shard placement, cross-shard
merge), and the micro-batching frontend under concurrent load.

Exactness strategy: tables/queries are small random INTEGERS cast to the
embedding dtype — every value is exactly representable in bf16 and every
f32 dot product is exact, so kernel and numpy oracle scores are bitwise
identical regardless of accumulation order, and the (frequent) score ties
genuinely exercise the smaller-index tie rule."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HybridConfig, HybridEmbeddingTrainer
from repro.core.partition import build_episode_blocks
from repro.embed_serve import (MicroBatcher, ShardedEmbeddingStore,
                               merge_topk, topk_mips, topk_mips_rowwise,
                               topk_mips_xla)
from repro.kernels import ref
from repro.train.checkpoint import load_arrays, save_checkpoint


def _int_table(n, d, seed=0, dtype=jnp.float32, lo=-4, hi=5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=(n, d)),
                       dtype=jnp.float32).astype(dtype)


# --------------------------------------------------------------------- topk
@pytest.mark.parametrize("k,dtype,N,Q", [
    (1, jnp.float32, 230, 17),
    (10, jnp.float32, 230, 17),
    (10, jnp.bfloat16, 230, 17),
    (100, jnp.float32, 130, 5),   # k > block_n fraction, odd N
])
def test_topk_mips_matches_oracle(k, dtype, N, Q):
    tbl = _int_table(N, 32, seed=1, dtype=dtype)
    q = _int_table(Q, 32, seed=2)
    rv, ri = ref.topk_mips_ref(np.asarray(tbl), np.asarray(q), k)
    v, i = topk_mips(tbl, q, k=k, valid=N, block_q=8, block_n=64,
                     interpret=True)
    np.testing.assert_array_equal(np.asarray(i), ri)
    np.testing.assert_array_equal(np.asarray(v), rv)


def test_topk_mips_heavy_ties():
    """Only 6 distinct rows -> ties everywhere; the smaller index must win
    at every rank, in-tile, across tiles, and across the k boundary."""
    rng = np.random.default_rng(3)
    base = np.asarray(_int_table(6, 16, seed=4))
    tbl = jnp.asarray(base[rng.integers(0, 6, size=200)])
    q = _int_table(9, 16, seed=5)
    rv, ri = ref.topk_mips_ref(np.asarray(tbl), np.asarray(q), 25)
    v, i = topk_mips(tbl, q, k=25, valid=200, block_q=4, block_n=32,
                     interpret=True)
    np.testing.assert_array_equal(np.asarray(i), ri)
    np.testing.assert_array_equal(np.asarray(v), rv)


def test_topk_mips_padded_shard_masked():
    """Rows >= valid (the store's block_n padding) can never be returned,
    even when their zero rows would out-score real (negative) rows."""
    tbl = jnp.asarray(np.full((64, 8), -2.0, np.float32))  # pad rows are 0
    q = jnp.asarray(np.ones((3, 8), np.float32))
    v, i = topk_mips(tbl, q, k=5, valid=40, block_q=4, block_n=16,
                     interpret=True)
    assert int(np.asarray(i).max()) < 40
    rv, ri = ref.topk_mips_ref(np.asarray(tbl)[:40], np.asarray(q), 5)
    np.testing.assert_array_equal(np.asarray(i), ri)


@pytest.mark.parametrize("fn", [topk_mips_rowwise, topk_mips_xla],
                         ids=["rowwise", "xla"])
def test_topk_reference_paths_match_oracle(fn):
    tbl = _int_table(57, 24, seed=6)
    q = _int_table(11, 24, seed=7)
    rv, ri = ref.topk_mips_ref(np.asarray(tbl), np.asarray(q), 8)
    kw = {"interpret": True} if fn is topk_mips_rowwise else {}
    v, i = fn(tbl, q, k=8, valid=57, **kw)
    np.testing.assert_array_equal(np.asarray(i), ri)
    np.testing.assert_array_equal(np.asarray(v), rv)


def test_merge_topk_equals_global_oracle():
    """Per-shard exact top-k lists + the cross-shard reduce == top-k over
    the whole table (3 uneven shards, sentinel-padded short shard)."""
    N, d, Q, k = 150, 16, 7, 12
    tbl = np.asarray(_int_table(N, d, seed=8))
    q = np.asarray(_int_table(Q, d, seed=9))
    bounds = [(0, 64), (64, 128), (128, 150)]   # last shard < k rows? no: 22
    per_v, per_i = [], []
    for lo, hi in bounds:
        v, i = ref.topk_mips_ref(tbl[lo:hi], q, k)   # local top-k...
        per_v.append(v)
        per_i.append(i + lo)                         # ...with global ids
    gv, gi = merge_topk(jnp.asarray(np.stack(per_v)),
                        jnp.asarray(np.stack(per_i)), k=k)
    rv, ri = ref.topk_mips_ref(tbl, q, k)
    np.testing.assert_array_equal(np.asarray(gi), ri)
    np.testing.assert_array_equal(np.asarray(gv), rv)


# -------------------------------------------------------------------- store
@pytest.mark.parametrize("impl", ["xla", "pallas", "rowwise"])
def test_store_multi_shard_query(impl):
    """Two shards (same device twice on this container): shard fan-out +
    global-id merge equal the oracle over the unsharded table."""
    dev = jax.devices()[0]
    tbl = np.asarray(_int_table(143, 16, seed=10))
    store = ShardedEmbeddingStore.from_array(tbl, devices=[dev, dev],
                                             block_n=32)
    q = np.asarray(_int_table(6, 16, seed=11))
    rv, ri = store.oracle_topk(q, 9)
    v, i = store.topk(q, 9, impl=impl)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_array_equal(v, rv)


@pytest.mark.parametrize("impl", ["xla", "pallas", "rowwise"])
def test_store_empty_tail_shards(impl):
    """num_nodes < (P-1) * rows leaves trailing shards with zero valid
    rows (block assignment); they must be skipped, not scanned."""
    dev = jax.devices()[0]
    tbl = np.asarray(_int_table(9, 8, seed=30))
    store = ShardedEmbeddingStore.from_array(tbl, devices=[dev] * 4,
                                             block_n=16)
    assert store.valid == (3, 3, 3, 0)
    q = np.asarray(_int_table(4, 8, seed=31))
    rv, ri = store.oracle_topk(q, 5)
    v, i = store.topk(q, 5, impl=impl)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_array_equal(v, rv)


def test_recall_at_k_tie_tolerance():
    from repro.embed_serve import recall_at_k

    oracle_ids = np.array([[4, 7]])
    oracle_vals = np.array([[2.0, 1.0]])
    # plain set recall: one hit of two
    assert recall_at_k(np.array([[4, 9]]), oracle_ids) == 0.5
    # id 9 scored at the k-th boundary (an ulp-flipped exact tie): counts
    got_vals = np.array([[2.0, 1.0]])
    assert recall_at_k(np.array([[4, 9]]), oracle_ids, got_vals=got_vals,
                       oracle_vals=oracle_vals) == 1.0
    # a genuinely wrong id (score below the boundary) still misses
    got_vals = np.array([[2.0, 0.5]])
    assert recall_at_k(np.array([[4, 9]]), oracle_ids, got_vals=got_vals,
                       oracle_vals=oracle_vals) == 0.5
    # a kernel repeating its rank-1 id cannot double-count its way to 1.0
    got_vals = np.array([[2.0, 2.0]])
    assert recall_at_k(np.array([[4, 4]]), oracle_ids, got_vals=got_vals,
                       oracle_vals=oracle_vals) == 0.5


def test_store_k_clamped_and_cosine():
    tbl = np.asarray(_int_table(12, 8, seed=12, lo=1, hi=5))  # nonzero rows
    store = ShardedEmbeddingStore.from_array(tbl, normalize=True)
    v, i = store.topk(np.asarray(_int_table(2, 8, seed=13)), 50)
    assert v.shape == (2, 12)                  # k clamped to num_nodes
    assert sorted(i[0].tolist()) == list(range(12))
    norms = np.linalg.norm(store.host_table.astype(np.float32), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-2)


@pytest.fixture(scope="module")
def trained_ckpt(tmp_path_factory):
    """A few real training steps -> checkpoint (bf16 default dtype)."""
    nodes, d = 300, 16
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = HybridConfig(dim=d, minibatch=32, negatives=4, subparts=1,
                       neg_pool=256, impl="ref", seed=3)   # dtype: bf16
    tr = HybridEmbeddingTrainer(nodes, mesh, cfg)
    tr.init_embeddings()
    pairs = np.random.default_rng(4).integers(0, nodes, size=(512, 2))
    eb = build_episode_blocks(pairs, tr.part, pad_multiple=cfg.minibatch)
    tr.train_episode(eb)
    V, C = tr.embeddings(), tr.context_embeddings()
    path = str(tmp_path_factory.mktemp("ckpt") / "embeddings.npz")
    save_checkpoint(path, {"vertex": V, "context": C}, step=7)
    return path, V, C


def test_store_checkpoint_roundtrip_bitwise(trained_ckpt):
    """Train a few steps -> save -> reload via the store: tables must come
    back BITWISE (bf16 included — the npz void-dtype fix), and the
    NodePartition row layout must land each shard's rows on its device."""
    path, V, C = trained_ckpt
    assert V.dtype == np.asarray(jnp.zeros(0, jnp.bfloat16)).dtype

    arrays, step = load_arrays(path)
    assert step == 7 and arrays["vertex"].dtype == V.dtype

    dev = jax.devices()[0]
    for table, ref_arr in (("vertex", V), ("context", C)):
        store = ShardedEmbeddingStore.load(path, table=table,
                                           devices=[dev, dev], block_n=64)
        assert store.step == 7
        # bitwise: the served host table and the device shards
        np.testing.assert_array_equal(
            store.host_table.view(np.uint16), ref_arr.view(np.uint16))
        rows = store.part.padded_rows_per_shard
        padded = store.part.pad_table(ref_arr)
        for s, shard in enumerate(store.shards):
            assert shard.devices() == {store.devices[s]}
            got = np.asarray(shard)[:rows]        # drop block_n pad rows
            np.testing.assert_array_equal(
                got.view(np.uint16),
                padded[s * rows:(s + 1) * rows].view(np.uint16))


def test_store_query_from_trained_checkpoint(trained_ckpt):
    """The acceptance path: real (non-integer) trained embeddings, Pallas
    kernel vs numpy oracle at k in {1, 10, 100}."""
    path, _, _ = trained_ckpt
    store = ShardedEmbeddingStore.load(path, block_n=64)
    rng = np.random.default_rng(5)
    q = store.host_table[rng.integers(0, store.num_nodes, 8)].astype(
        np.float32)
    for k in (1, 10, 100):
        rv, ri = store.oracle_topk(q, k)
        v, i = store.topk(q, k, impl="pallas")
        np.testing.assert_array_equal(i, ri)


# ------------------------------------------------------------------ batcher
def test_batcher_concurrent_correctness():
    """Seeded load test: concurrent submitters each get exactly their own
    query's oracle row back, and coalescing actually happened."""
    tbl = np.asarray(_int_table(120, 16, seed=20))
    store = ShardedEmbeddingStore.from_array(tbl, block_n=32)
    pool = np.asarray(_int_table(40, 16, seed=21))
    rv, ri = store.oracle_topk(pool, 6)

    batcher = MicroBatcher(lambda q: store.topk(q, 6, impl="xla"),
                           dim=16, max_batch=16, window_ms=5.0,
                           pad_multiple=8)
    errors = []

    def client(seed):
        rng = np.random.default_rng(seed)
        for _ in range(12):
            j = int(rng.integers(0, 40))
            fut = batcher.submit(pool[j])
            time.sleep(float(rng.uniform(0, 0.002)))
            vals, ids = fut.result(timeout=60)
            if not (np.array_equal(ids, ri[j])
                    and np.array_equal(vals, rv[j])):
                errors.append((seed, j))

    threads = [threading.Thread(target=client, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    batcher.close()
    assert not errors
    st = batcher.stats
    assert st.requests == 6 * 12
    assert st.batches < st.requests          # coalescing happened
    assert st.mean_batch > 1.0


def test_batcher_close_serves_backlog_and_rejects_new():
    tbl = np.asarray(_int_table(30, 8, seed=22))
    store = ShardedEmbeddingStore.from_array(tbl, block_n=16)
    batcher = MicroBatcher(lambda q: store.topk(q, 3, impl="xla"),
                           dim=8, max_batch=4, window_ms=50.0)
    futs = [batcher.submit(tbl[i]) for i in range(10)]
    batcher.close()                           # must drain, not drop
    for f in futs:
        vals, ids = f.result(timeout=10)
        assert ids.shape == (3,)
    with pytest.raises(RuntimeError):
        batcher.submit(tbl[0])


def test_batcher_fixed_batch_shape():
    """fixed_batch pads every backend call to exactly max_batch rows (one
    compiled shape), and per-request results are still correct."""
    tbl = np.asarray(_int_table(50, 8, seed=23))
    store = ShardedEmbeddingStore.from_array(tbl, block_n=16)
    seen = []

    def serve_fn(q):
        seen.append(q.shape)
        return store.topk(q, 4, impl="xla")

    batcher = MicroBatcher(serve_fn, dim=8, max_batch=16, window_ms=5.0,
                           fixed_batch=True)
    futs = [batcher.submit(tbl[i]) for i in range(11)]
    rv, ri = store.oracle_topk(tbl[:11], 4)
    for j, f in enumerate(futs):
        vals, ids = f.result(timeout=30)
        np.testing.assert_array_equal(ids, ri[j])
    batcher.close()
    assert all(s == (16, 8) for s in seen)


def test_batcher_propagates_backend_errors():
    def boom(q):
        raise ValueError("backend down")

    batcher = MicroBatcher(boom, dim=4, max_batch=4, window_ms=1.0)
    fut = batcher.submit(np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="backend down"):
        fut.result(timeout=10)
    with pytest.raises(ValueError):           # shape validation
        batcher.submit(np.zeros(3, np.float32))
    batcher.close()
