"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one train step
(or decode step for serve-only checks) on CPU; output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import transformer as tfm
from repro.train.train_step import make_train_step, synthetic_batch

ARCHS = [a for a in cfgs.list_archs() if a != "tencent-embedding"]
KEY = jax.random.PRNGKey(0)


def reduced_cfg(arch):
    cfg = cfgs.get_config(arch).reduced(layers=2, d_model=256, experts=4)
    return dataclasses.replace(cfg, train_microbatches=1)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced_cfg(arch)
    params = tfm.init_params(KEY, cfg)
    step_fn, opt = make_train_step(cfg, mesh=None, data_axes=())
    opt_state = opt.init(params)
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, 2, 32, seed=1).items()}
    params2, opt_state2, metrics = step_fn(params, opt_state,
                                           jnp.int32(0), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["grad_norm"]) > 0, arch
    # parameters actually moved and kept their shapes
    moved = 0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        assert a.shape == b.shape and a.dtype == b.dtype
        moved += int(not np.array_equal(np.asarray(a), np.asarray(b)))
    assert moved > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = reduced_cfg(arch)
    params = tfm.init_params(KEY, cfg)
    B, S = 2, 16
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, B, S, seed=2).items()}
    logits, caches = tfm.prefill(params, batch, cfg, cache_len=S + 8)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
    logits2, caches = tfm.decode_step(params, tok, caches, cfg)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), arch


def test_tencent_embedding_smoke(sbm_graph):
    """The paper's own arch: one hybrid episode on a small graph."""
    from repro.configs.tencent_embedding import SMALL
    from repro.core import (HybridConfig, HybridEmbeddingTrainer,
                            build_episode_blocks)
    from repro.walk import MemorySampleStore, WalkConfig, WalkEngine

    g = sbm_graph
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = HybridConfig(dim=SMALL.dim, minibatch=SMALL.minibatch,
                       negatives=SMALL.negatives, subparts=SMALL.subparts,
                       neg_pool=SMALL.neg_pool, lr=SMALL.lr)
    tr = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    tr.init_embeddings()
    store = MemorySampleStore()
    WalkEngine(g, WalkConfig(walk_length=8, window=4, episodes=1),
               store).run_epoch(0)
    eb = build_episode_blocks(store.get(0, 0), tr.part,
                              pad_multiple=cfg.minibatch)
    loss = tr.train_episode(eb)
    assert np.isfinite(loss) and loss > 0
    emb = tr.embeddings()
    assert emb.shape == (g.num_nodes, SMALL.dim)
    assert np.isfinite(emb).all()
