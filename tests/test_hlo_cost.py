"""HLO cost model: exact on scans (the reason it exists) and on plain dots."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo


def _xla_cost(comp):
    """compiled.cost_analysis() returned a one-element list on older jax."""
    ca = comp.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_plain_matmul_matches_xla():
    g = jax.jit(lambda a, b: a @ b)
    comp = g.lower(jnp.zeros((128, 256), jnp.float32),
                   jnp.zeros((256, 64), jnp.float32)).compile()
    r = analyze_hlo(comp.as_text())
    assert r["flops"] == _xla_cost(comp)["flops"] == 2 * 128 * 256 * 64


def test_scan_flops_multiplied_by_trip_count():
    L, B, D, F = 6, 32, 64, 96

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w @ w.T), ()
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jnp.zeros((L, D, F))
    x = jnp.zeros((B, D))
    comp = jax.jit(f).lower(ws, x).compile()
    r = analyze_hlo(comp.as_text())
    expected = L * (2 * B * D * F + 2 * B * F * D)
    assert abs(r["flops"] - expected) / expected < 0.01
    # XLA's own count misses the trip multiplication
    assert _xla_cost(comp)["flops"] < r["flops"]


def test_collectives_counted_inside_scans():
    devs = jax.device_count()
    mesh = jax.make_mesh((1, devs), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(ws, x):
        def body(x, w):
            return x @ w, ()
        return jax.lax.scan(body, x, ws)[0]

    L, D = 5, 64
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((8, D), jnp.float32)
    j = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model", None)),
                                 NamedSharding(mesh, P())))
    with mesh:
        comp = j.lower(ws, x).compile()
    r = analyze_hlo(comp.as_text())
    if devs > 1:
        assert r["collectives"]["total"] > 0
    assert np.isfinite(r["bytes"]) and r["bytes"] > 0
