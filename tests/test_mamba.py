"""Mamba-2 SSD: chunked scan vs sequential-decode oracle, chunk invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import mamba
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)


def make_cfg(chunk=8, state=16, hd=16):
    return ModelConfig(name="m", arch_type="ssm", num_layers=1, d_model=32,
                       num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=64,
                       ssm_state=state, ssm_head_dim=hd, ssm_chunk=chunk)


def test_chunked_matches_sequential():
    cfg = make_cfg()
    params = mamba.init_mamba_params(KEY, cfg)
    B, S = 2, 37
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, 32)) * 0.5
    cache = mamba.init_mamba_cache(cfg, B)
    outs = []
    for t in range(S):
        y1, cache = mamba.mamba_decode(params, x[:, t:t + 1], cache, cfg)
        outs.append(y1)
    y_seq = jnp.concatenate(outs, axis=1)
    y_par = mamba.mamba_forward(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(S=st.integers(5, 60), chunk=st.sampled_from([4, 8, 16]))
def test_chunk_size_invariance(S, chunk):
    """Output must not depend on the chunking of the scan."""
    cfg_a = make_cfg(chunk=chunk)
    cfg_b = make_cfg(chunk=64)  # single chunk (padded)
    params = mamba.init_mamba_params(KEY, cfg_a)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (1, S, 32)) * 0.5
    ya = mamba.mamba_forward(params, x, cfg_a)
    yb = mamba.mamba_forward(params, x, cfg_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=3e-4, atol=3e-4)


def test_state_carry_across_chunks():
    """forward(x) == forward(x1) then forward(x2 | state) — the chunked
    prefill contract."""
    cfg = make_cfg()
    params = mamba.init_mamba_params(KEY, cfg)
    B, S = 2, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, 32)) * 0.5
    y_full = mamba.mamba_forward(params, x, cfg)
    y1, st1, tail1 = mamba.mamba_forward(params, x[:, :16], cfg,
                                         return_state=True)
    y2, _, _ = mamba.mamba_forward(params, x[:, 16:], cfg, init_state=st1,
                                   conv_init=tail1, return_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)


def test_decay_bounded():
    """A_log init keeps exp(dt*A) in (0,1) — no state blowup."""
    cfg = make_cfg()
    params = mamba.init_mamba_params(KEY, cfg)
    B = 2
    cache = mamba.init_mamba_cache(cfg, B)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (B, 1, 32))
    for _ in range(100):
        y, cache = mamba.mamba_decode(params, x, cache, cfg)
    assert np.isfinite(np.asarray(cache["state"])).all()
    assert float(jnp.abs(cache["state"]).max()) < 1e4
