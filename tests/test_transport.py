"""Fault-tolerant walk transport: framing, exactly-once chunk assembly,
host-health leases, and remote-producer chaos.

The invariant under test everywhere: a remote-producer run is BITWISE
identical to in-process production — with zero faults and under every
``net.*`` chaos kind — because episodes are keyed ``(seed, epoch, episode,
chunk)`` and redelivery is exactly-once at the assembler. The coordinator
tests run thread-mode producers (same protocol, same sockets as the
subprocess path) so they stay fast on the 1-core CI container.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.graph import powerlaw_graph
from repro.runtime import (FaultSpec, InjectedFault, StoreStalled,
                           TransportError, inject)
from repro.runtime.transport import (MAGIC, ChunkAssembler, FramedSocket,
                                     HostHealth, _FRAME, decode_pairs,
                                     encode_pairs, pack_frame)
from repro.walk import (MemorySampleStore, RemoteWalkCoordinator, WalkConfig,
                        WalkEngine)
from repro.walk.store import DiskSampleStore


def _pair():
    a, b = socket.socketpair()
    return FramedSocket(a), FramedSocket(b)


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------
def test_frame_roundtrip_over_socketpair():
    tx, rx = _pair()
    body = np.arange(1000, dtype=np.int32).tobytes()
    tx.send({"t": "chunk", "episode": 3}, body)
    msg, got = rx.recv()
    assert msg == {"t": "chunk", "episode": 3}
    assert got == body
    assert tx.frames_sent == 1 and rx.frames_recv == 1
    assert tx.bytes_sent == rx.bytes_recv > len(body)
    tx.close(), rx.close()


def test_frame_corrupt_body_fails_checksum():
    tx, rx = _pair()
    frame = bytearray(pack_frame({"t": "chunk"}, b"payload-bytes"))
    frame[-3] ^= 0xFF                      # flip one body byte
    tx.sock.sendall(bytes(frame))
    with pytest.raises(TransportError, match="checksum"):
        rx.recv()
    tx.close(), rx.close()


def test_frame_bad_magic_rejected():
    tx, rx = _pair()
    frame = bytearray(pack_frame({"t": "x"}))
    frame[:4] = b"NOPE"
    tx.sock.sendall(bytes(frame))
    with pytest.raises(TransportError, match="magic"):
        rx.recv()
    tx.close(), rx.close()


def test_frame_absurd_length_rejected():
    tx, rx = _pair()
    tx.sock.sendall(_FRAME.pack(MAGIC, 0, 2, (1 << 31) + 1) + b"{}")
    with pytest.raises(TransportError, match="absurd"):
        rx.recv()
    tx.close(), rx.close()


def test_recv_on_closed_peer_raises_connectionerror():
    tx, rx = _pair()
    tx.close()
    with pytest.raises(ConnectionError):
        rx.recv()
    rx.close()


def test_encode_decode_pairs_roundtrip():
    pairs = np.random.default_rng(0).integers(
        0, 1000, size=(257, 2)).astype(np.int32)
    meta, body = encode_pairs(pairs)
    out = decode_pairs(meta, body)
    assert out.dtype == pairs.dtype
    np.testing.assert_array_equal(out, pairs)


def test_send_fault_sites_fire_only_when_injected():
    tx, rx = _pair()
    with inject("net.drop:fire:at=0") as plan:
        tx.send({"t": "hb"})               # control frame: no injection
        assert not plan.fired and tx.frames_dropped == 0
        tx.send({"t": "chunk"}, b"x", key=(0, 0, 0), inject=True)
        assert plan.fired and tx.frames_dropped == 1
    msg, _ = rx.recv()                     # only the heartbeat arrived
    assert msg == {"t": "hb"}
    tx.close(), rx.close()


def test_send_duplicate_and_reorder_sites():
    tx, rx = _pair()
    with inject("net.duplicate:fire:key=0/0/0",
                "net.reorder:fire:key=0/0/1") as plan:
        tx.send({"c": 0}, key=(0, 0, 0), inject=True)   # sent twice
        tx.send({"c": 1}, key=(0, 0, 1), inject=True)   # held back
        tx.send({"c": 2}, key=(0, 0, 2), inject=True)   # flushes the held one
    order = [rx.recv()[0]["c"] for _ in range(4)]
    assert order == [0, 0, 2, 1]
    assert tx.frames_duplicated == 1
    assert [f[0] for f in plan.fired] == ["net.duplicate", "net.reorder"]
    tx.close(), rx.close()


def test_send_disconnect_site_closes_and_raises():
    tx, rx = _pair()
    with inject("net.disconnect:fire:at=0"):
        with pytest.raises(TransportError, match="disconnect"):
            tx.send({"c": 0}, key=(0, 0, 0), inject=True)
    with pytest.raises(ConnectionError):
        rx.recv()                          # our end really closed
    rx.close()


# ---------------------------------------------------------------------------
# exactly-once chunk assembly
# property-test helpers (shared by the hypothesis tests below and the
# deterministic spot-checks, so the invariant logic is exercised even on the
# no-hypothesis container where @given tests skip)
# ---------------------------------------------------------------------------
def _check_assembler_interleaving(nchunks, rng_seed, extra):
    """Deliver every chunk once plus `extra` redeliveries, in a shuffled
    order: the episode must assemble exactly once, bitwise in chunk order,
    with every redelivery flagged dup (the ack-and-discard contract)."""
    chunks = {c: np.full((c + 1, 2), c, dtype=np.int32)
              for c in range(nchunks)}
    schedule = list(range(nchunks)) + [e % nchunks for e in extra]
    np.random.default_rng(rng_seed).shuffle(schedule)
    asm = ChunkAssembler()
    assembled, dups = [], 0
    for c in schedule:
        dup, out = asm.add(7, 0, 0, c, nchunks, chunks[c])
        dups += dup
        if out is not None:
            assembled.append(out)
    assert len(assembled) == 1
    np.testing.assert_array_equal(
        assembled[0], np.concatenate([chunks[c] for c in range(nchunks)]))
    assert dups == len(schedule) - nchunks
    assert asm.complete(7, 0, 0)
    # redelivery after completion: still acked as dup, never re-assembled
    dup, out = asm.add(7, 0, 0, 0, nchunks, chunks[0])
    assert dup and out is None


def test_assembler_interleaving_spotchecks():
    _check_assembler_interleaving(1, 0, [])
    _check_assembler_interleaving(4, 1, [0, 0, 3])
    _check_assembler_interleaving(8, 2, list(range(16)))


@settings(max_examples=50, deadline=None)
@given(nchunks=st.integers(1, 8), rng_seed=st.integers(0, 1000),
       extra=st.lists(st.integers(0, 63), max_size=16))
def test_assembler_random_interleavings_property(nchunks, rng_seed, extra):
    """Idempotence-key dedup under random duplicate/reorder interleavings."""
    _check_assembler_interleaving(nchunks, rng_seed, extra)


def test_assembler_rejects_bad_chunks():
    asm = ChunkAssembler()
    with pytest.raises(TransportError, match="out of range"):
        asm.add(1, 0, 0, 3, 2, np.zeros((1, 2), np.int32))
    asm.add(1, 0, 0, 0, 2, np.zeros((1, 2), np.int32))
    with pytest.raises(TransportError, match="chunk count changed"):
        asm.add(1, 0, 0, 1, 5, np.zeros((1, 2), np.int32))


def test_assembler_forget_epoch_releases_keys():
    asm = ChunkAssembler()
    _, out = asm.add(1, 0, 0, 0, 1, np.ones((2, 2), np.int32))
    assert out is not None and asm.complete(1, 0, 0)
    asm.forget_epoch(1, 0)
    assert not asm.complete(1, 0, 0)
    dup, out = asm.add(1, 0, 0, 0, 1, np.ones((2, 2), np.int32))
    assert not dup and out is not None    # a forgotten epoch can replay


# ---------------------------------------------------------------------------
# host health leases
# ---------------------------------------------------------------------------
def test_host_health_lease_lifecycle():
    h = HostHealth(lease_s=0.15)
    assert h.any_alive()                   # nobody registered: unknown != dead
    h.beat("walker-0")
    assert h.alive("walker-0") and h.any_alive() and h.hosts() == ["walker-0"]
    assert h.expired() == []
    time.sleep(0.2)
    assert not h.alive("walker-0") and not h.any_alive()
    assert h.expired() == ["walker-0"]
    h.mark_dead("walker-0")
    assert h.expired() == []               # marked hosts are not re-reported
    assert "walker-0: DEAD" in h.describe()
    h.beat("walker-0")                     # a beating host is not dead
    assert h.alive("walker-0") and "alive" in h.describe()
    snap = h.snapshot()
    assert snap["walker-0"]["alive"]


def test_store_stalled_names_dead_host():
    """The watchdog's diagnostic must say WHICH producer host died."""
    h = HostHealth(lease_s=0.05)
    h.beat("walker-1")
    time.sleep(0.1)
    store = MemorySampleStore(stall_timeout_s=30.0)
    store.set_producer(h.any_alive, h.describe)
    with pytest.raises(StoreStalled) as ei:
        store.get(0, 0)
    assert "walker-1: DEAD" in str(ei.value)
    assert ei.value.producer_alive is False


# ---------------------------------------------------------------------------
# fault-spec grammar (round-trip property + the key wildcard)
# ---------------------------------------------------------------------------
def _check_spec_roundtrip(site, kind, at, key, times, delay):
    parts = [site, kind]
    if at is not None:
        parts.append(f"at={at}")
    if key is not None:
        parts.append(f"key={key}")
    parts.append("times=inf" if times == float("inf") else f"times={times}")
    if kind == "delay":
        parts.append(f"delay={delay}")
    s = FaultSpec.parse(":".join(parts))
    assert (s.site, s.kind, s.key, s.times) == (site, kind, key, times)
    if at is None and key is None and times == 1:
        assert s.at == 0                   # bare spec pins to first invocation
    else:
        assert s.at == at
    if kind == "delay":
        assert s.delay_s == delay


def test_fault_spec_roundtrip_spotchecks():
    _check_spec_roundtrip("net.drop", "fire", 2, None, 1, 0.05)
    _check_spec_roundtrip("net.delay", "delay", None, "0/1/0",
                          float("inf"), 0.5)
    _check_spec_roundtrip("producer.episode", "crash", None,
                          "walker-0/*", 1, 0.05)
    _check_spec_roundtrip("disk.write", "corrupt", None, None, 1, 0.05)


@settings(max_examples=60, deadline=None)
@given(site=st.sampled_from(["net.drop", "walk.chunk", "serve.shard"]),
       kind=st.sampled_from(["crash", "delay", "corrupt", "fire"]),
       at=st.one_of(st.just(None), st.integers(0, 99)),
       key=st.one_of(st.just(None), st.just("0/1/2"), st.just("walker-0/*"),
                     st.just("a"), st.just("w-1/3")),
       times=st.one_of(st.just(1), st.integers(2, 9),
                       st.just(float("inf"))),
       delay=st.sampled_from([0.0, 0.05, 1.5]))
def test_fault_spec_roundtrip_property(site, kind, at, key, times, delay):
    """format -> parse recovers every field of the spec grammar."""
    _check_spec_roundtrip(site, kind, at, key, times, delay)


def test_fault_spec_key_wildcard_prefix_match():
    s = FaultSpec.parse("producer.episode:crash:key=walker-0/*:times=inf")
    assert s.matches(0, "walker-0/0/5")
    assert s.matches(3, "walker-0/1/0")
    assert not s.matches(0, "walker-1/0/5")
    assert not s.matches(0, None)
    # exact keys stay exact: no implicit prefixing
    e = FaultSpec.parse("producer.episode:crash:key=walker-0/0:times=inf")
    assert e.matches(0, "walker-0/0")
    assert not e.matches(0, "walker-0/0/1")


# ---------------------------------------------------------------------------
# idempotent store puts
# ---------------------------------------------------------------------------
def test_put_unique_memory_store_dedups():
    store = MemorySampleStore()
    pairs = np.arange(10, dtype=np.int32).reshape(5, 2)
    assert store.put_unique(0, 0, pairs)
    assert not store.put_unique(0, 0, pairs)      # resident: duplicate
    store.drop(0, 0)
    assert not store.put_unique(0, 0, pairs)      # consumed: still duplicate
    assert store.put_unique(0, 1, pairs)
    np.testing.assert_array_equal(store.get(0, 1), pairs)


def test_put_unique_disk_store_dedups(tmp_path):
    store = DiskSampleStore(str(tmp_path))
    pairs = np.arange(10, dtype=np.int32).reshape(5, 2)
    assert store.put_unique(0, 0, pairs)
    assert not store.put_unique(0, 0, pairs)      # file exists: duplicate
    assert store.put_unique(0, 1, pairs)
    np.testing.assert_array_equal(np.asarray(store.get(0, 1)), pairs)


# ---------------------------------------------------------------------------
# remote production end-to-end (thread-mode producers; same protocol and
# sockets as the subprocess path, fast enough for the 1-core container)
# ---------------------------------------------------------------------------
GRAPH = None


def _graph():
    global GRAPH
    if GRAPH is None:
        GRAPH = powerlaw_graph(300, 4, seed=1)
    return GRAPH


def _wcfg():
    # chunk_size=40 gives multiple chunks per episode so chunk-keyed
    # net.* specs have real (epoch, episode, chunk>0) targets
    return WalkConfig(walk_length=6, window=3, episodes=4, seed=3,
                      chunk_size=40)


def test_episode_chunk_stream_matches_episode_pairs():
    eng = WalkEngine(_graph(), _wcfg())
    for ep in range(2):
        chunks = list(eng.episode_chunk_stream(0, ep))
        assert len(chunks) >= 2            # the chaos tests need chunk 1
        assert all(n == len(chunks) for _, n, _ in chunks)
        np.testing.assert_array_equal(
            np.concatenate([p for _, _, p in chunks]),
            eng.episode_pairs(0, ep))


def _run_remote_epochs(specs, *, num_producers=2, lease_s=20.0,
                       epochs=2, expect_fired=True):
    """Run `epochs` epochs through thread-mode remote producers under the
    given fault specs; assert every episode lands bitwise-identical to the
    in-process engine. Returns the coordinator's transport stats."""
    g, wcfg = _graph(), _wcfg()
    ref = WalkEngine(g, wcfg)
    store = MemorySampleStore(depth=3, stall_timeout_s=60.0)
    coord = RemoteWalkCoordinator(g, wcfg, store, num_producers=num_producers,
                                  heartbeat_s=0.2, lease_s=lease_s,
                                  mode="thread", ack_timeout_s=1.5)
    with inject(*specs) as plan:
        coord.start()
        try:
            for epoch in range(epochs):
                h = coord.epoch_walker()
                h.start_async(epoch)
                for ep in range(wcfg.episodes):
                    got = store.get(epoch, ep)
                    np.testing.assert_array_equal(
                        np.asarray(got).view(np.uint8),
                        ref.episode_pairs(epoch, ep).view(np.uint8))
                    store.drop(epoch, ep)
                h.join()
                assert h.finished()
            stats = coord.transport_stats()
        finally:
            coord.close()
    if expect_fired:
        assert plan.fired, f"fault plan {specs} never fired"
    return stats


def test_remote_production_bitwise_identical_no_faults():
    stats = _run_remote_epochs((), expect_fired=False)
    # 2 epochs x 4 episodes x >=2 chunks, zero retransmission
    assert stats["chunks_applied"] >= 16
    assert stats["dup_chunks"] == 0 and stats["resend_rate"] == 0.0
    assert stats["frames_recv"] > 0 and stats["bytes_recv"] > 0


@pytest.mark.parametrize("spec", [
    "net.drop:fire:key=0/1/0",            # chunk vanishes -> ack timeout
    "net.duplicate:fire:key=0/2/1",       # chunk lands twice -> dup-acked
    "net.disconnect:fire:key=0/1/1",      # socket dies mid-episode
    "net.reorder:fire:key=0/0/0",         # chunk 0 arrives after chunk 1
])
def test_remote_production_bitwise_identical_under_chaos(spec):
    """Reconnect-and-resend recovery is invisible to the trainer: the run
    under each network fault is bitwise-identical to in-process walks."""
    stats = _run_remote_epochs((spec,), epochs=1)
    if "drop" in spec or "disconnect" in spec:
        assert stats["dup_chunks"] >= 0    # resends may double-land
    if "duplicate" in spec:
        assert stats["dup_chunks"] >= 1    # the dup MUST have been discarded


def test_killed_producer_episodes_reassigned_to_survivors():
    """Kill walker-0 at its first assigned episode (whichever it is — the
    /* wildcard absorbs assignment races): its lease lapses, the reclaim
    loop reassigns, and walker-1 finishes the epoch bitwise-correct."""
    stats = _run_remote_epochs(("producer.episode:crash:key=walker-0/*",),
                               lease_s=2.0, epochs=1)
    assert stats["chunks_applied"] >= 8


def test_all_producers_dead_fails_fast_with_named_hosts():
    g, wcfg = _graph(), _wcfg()
    store = MemorySampleStore(depth=3, stall_timeout_s=60.0)
    coord = RemoteWalkCoordinator(g, wcfg, store, num_producers=2,
                                  heartbeat_s=0.1, lease_s=0.6,
                                  mode="thread", ack_timeout_s=1.5)
    with inject("producer.episode:crash:key=walker-0/*",
                "producer.episode:crash:key=walker-1/*") as plan:
        coord.start()
        try:
            h = coord.epoch_walker()
            h.start_async(0)
            with pytest.raises(TransportError, match="hosts are dead"):
                coord.server.wait_epoch(0, timeout_s=30.0)
            assert not coord.alive()
            assert len(plan.fired) == 2
        finally:
            coord.close()


def test_producer_resends_after_drop_without_duplicating_samples():
    """A dropped chunk frame forces a full resend pass; the assembler's
    idempotence keys keep every double-landed chunk out of the store."""
    stats = _run_remote_epochs(("net.drop:fire:key=0/0/1",), epochs=1)
    # exactly-once despite retransmission: applied == unique chunk count
    eng = WalkEngine(_graph(), _wcfg())
    unique = sum(len(list(eng.episode_chunk_stream(0, ep)))
                 for ep in range(_wcfg().episodes))
    assert stats["chunks_applied"] == unique
