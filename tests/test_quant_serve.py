"""Two-tier quantized retrieval (embed_serve.quant + topk_mips_quant) and
the VMEM-aware scan-tile planner.

Correctness strategy mirrors test_embed_serve.py: integer tables make every
f32 dot exact, so the int8 first pass is bitwise deterministic across the
Pallas kernel and the jnp path, and the rescored result must equal the
numpy oracle EXACTLY (recall 1.0 is asserted as array equality, which is
stronger). Continuous (trained-like) tables are covered via the seeded
normal tables the bench uses, gated through ``recall_at_k == 1.0`` at the
default overfetch — the acceptance criterion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.embed_serve import (ShardedEmbeddingStore, overfetch_m,
                               recall_at_k, rescore_exact)
from repro.embed_serve import quant as qz
from repro.embed_serve import topk as tk
from repro.kernels import ref
from repro.launch import roofline


def _int_table(n, d, seed=0, dtype=jnp.float32, lo=-4, hi=5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=(n, d)),
                       dtype=jnp.float32).astype(dtype)


# -------------------------------------------------------- quantization
@pytest.mark.parametrize("seed,scale_mag", [(0, 1.0), (1, 1e-3), (2, 1e3)])
def test_quantize_roundtrip_bound(seed, scale_mag):
    """Property-style: for random rows at several magnitudes, the int8
    round-trip error is <= max|row| / 254 per element (the documented
    bound), values stay in the symmetric [-127, 127] range, and all-zero
    rows reconstruct exactly."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, scale_mag, size=(64, 48))
         * rng.uniform(0.01, 1, size=(64, 1))).astype(np.float32)
    x[7] = 0.0                                # all-zero row
    q, scale = qz.quantize_rows(x)
    assert q.dtype == np.int8
    assert int(np.abs(q).max()) <= qz.INT8_QMAX          # -128 never used
    deq = qz.dequantize_rows(q, scale)
    amax = np.max(np.abs(x), axis=1, keepdims=True)
    bound = amax / (2 * qz.INT8_QMAX) * (1 + 1e-6) + 1e-12
    assert np.all(np.abs(deq - x) <= bound)
    np.testing.assert_array_equal(deq[7], 0.0)           # zero row exact
    np.testing.assert_array_equal(q[7], 0)
    assert scale[7] == 1.0                               # and scale benign


def test_quantize_bf16_matches_f32_view():
    """bf16 tables quantize through their exact f32 values — the quant
    tier sees the same numbers the exact tier scores."""
    tbl = _int_table(33, 16, seed=3, dtype=jnp.bfloat16)
    q16, s16 = qz.quantize_rows(tbl)
    q32, s32 = qz.quantize_rows(np.asarray(tbl.astype(jnp.float32)))
    np.testing.assert_array_equal(q16, q32)
    np.testing.assert_array_equal(s16, s32)


def test_overfetch_m_clamps():
    assert qz.overfetch_m(10, 4.0, 10_000) == 40
    assert qz.overfetch_m(10, 4.0, 25) == 25      # shard smaller than m
    assert qz.overfetch_m(10, 1.0, 10_000) == 10  # never below k
    assert qz.overfetch_m(3, 2.5, 10_000) == 8    # ceil
    assert qz.overfetch_m(10, 4.0, 4) == 4        # degraded shard


# ------------------------------------------------- first-pass kernel
@pytest.mark.parametrize("dtype,N,Q,m", [
    (jnp.float32, 230, 17, 25),
    (jnp.bfloat16, 230, 17, 25),
    (jnp.float32, 130, 5, 40),        # odd N, m a big fraction of N
])
def test_topk_quant_kernel_matches_xla(dtype, N, Q, m):
    """Integer tables: the Pallas int8 first pass and the jnp path agree
    bitwise (same scores, same candidate ids, same tie-breaks)."""
    tbl = _int_table(N, 32, seed=1, dtype=dtype)
    q8, sc = qz.quantize_rows(tbl)
    q = _int_table(Q, 32, seed=2)
    kv, ki = tk.topk_mips_quant(jnp.asarray(q8), jnp.asarray(sc), q, m=m,
                                valid=N, block_q=8, block_n=64,
                                interpret=True)
    xv, xi = tk.topk_mips_quant_xla(jnp.asarray(q8), jnp.asarray(sc), q,
                                    m=m, valid=N)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(xi))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(xv))


def test_topk_quant_padded_rows_masked():
    """Rows >= valid can never surface from the int8 pass either, even
    when their zero rows would out-score real (negative) rows."""
    tbl = np.full((64, 8), -2.0, np.float32)
    q8, sc = qz.quantize_rows(tbl)
    q = jnp.asarray(np.ones((3, 8), np.float32))
    _, i = tk.topk_mips_quant(jnp.asarray(q8), jnp.asarray(sc), q, m=12,
                              valid=40, block_q=4, block_n=16,
                              interpret=True)
    got = np.asarray(i)
    assert got[got != tk.IDX_SENTINEL].max() < 40


# ------------------------------------------------- two-tier == oracle
@pytest.mark.parametrize("k", [1, 10, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_two_tier_matches_oracle_exactly(k, dtype, impl):
    """The acceptance criterion: quant first pass + exact rescore equals
    topk_mips_ref EXACTLY at the default overfetch for k in {1, 10, 100},
    across dtypes and an odd (non-tile-multiple) N."""
    N, d, Q = 317, 32, 9                      # odd N; k=100 -> m=317 (all)
    tbl = _int_table(N, d, seed=6, dtype=dtype)
    q8, sc = qz.quantize_rows(tbl)
    q = _int_table(Q, d, seed=7)
    rv, ri = ref.topk_mips_ref(np.asarray(tbl), np.asarray(q), k)
    v, i = qz.topk_mips_quant_rescored(
        tbl, jnp.asarray(q8), jnp.asarray(sc), q, k=k, valid=N,
        block_q=8, block_n=64, impl=impl, interpret=True)
    np.testing.assert_array_equal(np.asarray(i), ri)
    np.testing.assert_array_equal(np.asarray(v), rv)


def test_rescore_handles_sentinels_and_reranks():
    """Tier two must (a) re-rank candidates the quantized scores ordered
    wrongly and (b) keep sentinel slots losing (degraded shards)."""
    tbl = jnp.asarray(np.diag([1.0, 2.0, 3.0, 4.0]).astype(np.float32))
    q = jnp.asarray(np.ones((1, 4), np.float32))
    # candidates deliberately in the wrong order + sentinel padding
    cand = jnp.asarray(
        np.array([[0, 2, 3, 1, tk.IDX_SENTINEL]], np.int32))
    v, i = rescore_exact(tbl, q, cand, k=3, gather="xla")
    np.testing.assert_array_equal(np.asarray(i), [[3, 2, 1]])
    np.testing.assert_array_equal(np.asarray(v), [[4.0, 3.0, 2.0]])
    # pallas gather path, same answer
    v2, i2 = rescore_exact(tbl, q, cand, k=3, gather="pallas",
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(i))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v))


def test_two_tier_continuous_table_recall():
    """Continuous (trained-like) normal table, the bench's data shape:
    recall@k == 1.0 at the default overfetch against the oracle."""
    rng = np.random.default_rng(11)
    N, d, k = 2048, 64, 10
    tbl = rng.normal(0, 0.1, size=(N, d)).astype(np.float32)
    store = ShardedEmbeddingStore.from_array(tbl, quant="int8")
    q = tbl[rng.integers(0, N, size=16)]
    rv, ri = store.oracle_topk(q, k)
    v, i = store.topk(q, k, impl="quant")
    assert recall_at_k(i, ri, got_vals=store.score_ids(q, i),
                       oracle_vals=rv) == 1.0


# ----------------------------------------------------------- store tier
@pytest.mark.parametrize("impl", ["quant", "quant_pallas", "quant_xla"])
def test_store_quant_multi_shard(impl):
    """Two shards: int8 fan-out + rescore + global-id merge equal the
    oracle over the unsharded table."""
    dev = jax.devices()[0]
    tbl = np.asarray(_int_table(143, 16, seed=10))
    store = ShardedEmbeddingStore.from_array(tbl, devices=[dev, dev],
                                             block_n=32, quant="int8")
    q = np.asarray(_int_table(6, 16, seed=11))
    rv, ri = store.oracle_topk(q, 9)
    v, i = store.topk(q, 9, impl=impl)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_array_equal(v, rv)


@pytest.mark.parametrize("impl", ["quant_pallas", "quant_xla"])
def test_store_quant_degraded_shards(impl):
    """Shards with fewer valid rows than k (and an empty tail shard)
    through the quant path: m clamps to the shard, sentinels keep losing
    the merge, result still equals the oracle."""
    dev = jax.devices()[0]
    tbl = np.asarray(_int_table(9, 8, seed=30))
    store = ShardedEmbeddingStore.from_array(tbl, devices=[dev] * 4,
                                             block_n=16, quant="int8")
    assert store.valid == (3, 3, 3, 0)        # every live shard < k rows
    q = np.asarray(_int_table(4, 8, seed=31))
    rv, ri = store.oracle_topk(q, 5)
    v, i = store.topk(q, 5, impl=impl)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_array_equal(v, rv)


def test_store_quant_tier_required():
    tbl = np.asarray(_int_table(30, 8, seed=32))
    store = ShardedEmbeddingStore.from_array(tbl)
    assert store.qshards is None and store.quant is None
    with pytest.raises(RuntimeError, match="no quantized tier"):
        store.topk(np.zeros((2, 8), np.float32), 3, impl="quant")
    with pytest.raises(ValueError, match="unknown quant tier"):
        ShardedEmbeddingStore.from_array(tbl, quant="int4")


def test_store_quant_overfetch_override():
    """overfetch=<all rows> forces an exhaustive-exact first pass — the
    query-time override knob works end to end."""
    tbl = np.asarray(_int_table(60, 8, seed=33))
    store = ShardedEmbeddingStore.from_array(tbl, quant="int8",
                                             overfetch=1.0)
    q = np.asarray(_int_table(3, 8, seed=34))
    rv, ri = store.oracle_topk(q, 4)
    v, i = store.topk(q, 4, impl="quant_xla", overfetch=60.0)
    np.testing.assert_array_equal(i, ri)
    np.testing.assert_array_equal(v, rv)


# -------------------------------------------------------------- planner
def test_choose_block_n_respects_vmem_budget():
    """At shapes whose (2*bn, d) double-buffer would bust a 16 MB budget
    the planner shrinks the tile until the modeled working set fits (half
    budget, headroom for compiler temporaries); small shapes keep the cap."""
    budget = roofline.VMEM_BYTES
    for d, dtype in [(4096, jnp.float32), (8192, jnp.float32),
                     (8192, jnp.bfloat16)]:
        bn = tk.choose_block_n(d, dtype)
        assert tk.topk_scan_vmem_bytes(bn, d, dtype) <= budget // 2, (d, bn)
        assert bn >= 8
        # the default-256 tile of PR 3 would NOT have fit at d=8192 f32
    assert tk.topk_scan_vmem_bytes(256, 8192, jnp.float32) > budget // 2
    # small shapes: the cap, not the budget, binds
    assert tk.choose_block_n(64, jnp.float32) == 512
    # int8 tiles are 4x denser, so the planner can afford bigger tiles
    assert (tk.choose_block_n(8192, jnp.int8)
            >= tk.choose_block_n(8192, jnp.float32))
    # d so large the resident (bq, d) query block alone is half the
    # budget: the planner bottoms out at the sublane floor (the tile is
    # no longer what busts VMEM — shrinking block_q is the caller's knob)
    assert tk.choose_block_n(16384, jnp.int8) == 8


def test_choose_block_n_default_paths_are_exact():
    """block_n=None end to end: the planner-sized exact kernel, quant
    kernel, and store all still match the oracle."""
    tbl = _int_table(300, 24, seed=40)
    q = _int_table(7, 24, seed=41)
    rv, ri = ref.topk_mips_ref(np.asarray(tbl), np.asarray(q), 6)
    v, i = tk.topk_mips(tbl, q, k=6, valid=300, interpret=True)
    np.testing.assert_array_equal(np.asarray(i), ri)
    q8, sc = qz.quantize_rows(tbl)
    v2, i2 = qz.topk_mips_quant_rescored(
        tbl, jnp.asarray(q8), jnp.asarray(sc), q, k=6, valid=300,
        impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(i2), ri)
    store = ShardedEmbeddingStore.from_array(np.asarray(tbl), quant="int8")
    # the planner's tile, clamped to the shard's rows (tiny table here)
    assert store.block_n == min(tk.choose_block_n(24, np.float32),
                                store.part.padded_rows_per_shard)
    v3, i3 = store.topk(np.asarray(q), 6, impl="quant_pallas")
    np.testing.assert_array_equal(i3, ri)
