"""AUC-parity gate for the bf16 table default (ROADMAP "bf16 table default").

bf16 tables halve ring-rotation bytes and HBM footprint; grads are computed
in f32 inside the kernels either way. The default flip in
``HybridConfig.dtype`` is gated on this small-graph link-prediction run:
bf16 must land within 0.5% AUC of f32 on the identical schedule/seeds.
"""
import jax
import numpy as np
import pytest

from repro.core import (HybridConfig, HybridEmbeddingTrainer,
                        build_episode_blocks)
from repro.core import eval as ev
from repro.graph.csr import build_csr
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine


def test_default_dtype_is_bf16():
    """The flip this module gates: bf16 is the table default, f32 stays one
    CLI flag away (launch.train --dtype float32)."""
    assert HybridConfig().dtype == "bfloat16"
    assert np.dtype("bfloat16").itemsize == 2


@pytest.fixture(scope="module")
def lp_graph(sbm_graph):
    train_e, test_e = ev.split_edges(sbm_graph, 0.05, seed=1)
    g = build_csr(train_e, sbm_graph.num_nodes, symmetrize=False,
                  dedup=False)
    neg_e = ev.sample_negative_pairs(sbm_graph, len(test_e), seed=3)
    return g, test_e, neg_e


def _train_auc(dtype: str, g, test_e, neg_e, epochs: int = 12) -> float:
    # NOTE on the schedule: the gate must compare CONVERGED runs. Under an
    # under-converged schedule (lr=0.025, 8 epochs: f32 AUC ~0.68) bf16
    # trails by several points because tiny early updates round away in the
    # bf16 tables; at this schedule (f32 AUC ~0.88) the two dtypes agree to
    # ~0.1%.
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = HybridConfig(dim=48, minibatch=32, negatives=8, subparts=2,
                       neg_pool=2048, lr=0.05, dtype=dtype)
    tr = HybridEmbeddingTrainer(g.num_nodes, mesh, cfg, degrees=g.degrees())
    tr.init_embeddings()
    store = MemorySampleStore()
    for epoch in range(epochs):
        WalkEngine(g, WalkConfig(walk_length=10, window=5, episodes=1,
                                 seed=epoch), store).run_epoch(epoch)
        eb = build_episode_blocks(np.asarray(store.get(epoch, 0)), tr.part,
                                  pad_multiple=cfg.minibatch)
        tr.train_episode(eb, lr=cfg.lr * max(1 - epoch / epochs, 0.05))
        store.drop_epoch(epoch)
    V = tr.embeddings().astype(np.float32)
    Vn = V / (np.linalg.norm(V, axis=1, keepdims=True) + 1e-9)
    return ev.auc_score(
        np.einsum("ij,ij->i", Vn[test_e[:, 0]], Vn[test_e[:, 1]]),
        np.einsum("ij,ij->i", Vn[neg_e[:, 0]], Vn[neg_e[:, 1]]))


def test_bf16_auc_parity_with_f32(lp_graph):
    """bf16 within 0.5% AUC of f32 on the identical small-graph run."""
    g, test_e, neg_e = lp_graph
    auc_f32 = _train_auc("float32", g, test_e, neg_e)
    auc_bf16 = _train_auc("bfloat16", g, test_e, neg_e)
    assert auc_f32 > 0.8, auc_f32          # the run itself must be learning
    assert auc_bf16 >= auc_f32 - 0.005, (auc_bf16, auc_f32)
