"""Coordinator failover: restartable episode server, producer outage grace.

The invariant under test: killing the episode server mid-epoch and starting
a recovering successor on the same port is invisible to the trainer — the
run stays BITWISE identical to an uninterrupted one, with zero lost and
zero double-stored chunks. The pieces that make that hold, each gated
here: store-reconstructed work-queue state (``accepted_episodes`` →
contiguous-prefix put cursor), producer reconnect under a jittered
grace-bounded backoff (``RetryPolicy.jitter``/``max_elapsed_s``),
``wait_epoch`` failing fast instead of masquerading errors as timeouts,
and the ``HostHealth`` lease edges a takeover leans on.
"""
import os
import socket
import threading
import time

import numpy as np
import pytest

from repro.graph import powerlaw_graph
from repro.runtime import TransportError
from repro.runtime.retry import RetryPolicy, call_with_retry
from repro.runtime.transport import FramedSocket, HostHealth
from repro.walk import (MemorySampleStore, RemoteWalkCoordinator, WalkConfig,
                        WalkEngine)
from repro.walk.remote import RemoteEpisodeServer, RemoteProducer
from repro.walk.store import DiskSampleStore

GRAPH = None


def _graph():
    global GRAPH
    if GRAPH is None:
        GRAPH = powerlaw_graph(300, 4, seed=1)
    return GRAPH


def _wcfg():
    return WalkConfig(walk_length=6, window=3, episodes=4, seed=3,
                      chunk_size=40)


# ---------------------------------------------------------------------------
# retry: jitter determinism, caps, grace windows
# ---------------------------------------------------------------------------
def test_retry_jitter_deterministic_per_seed_and_bounded():
    p = RetryPolicy(attempts=7, backoff_s=0.1, mult=2.0, max_backoff_s=0.4,
                    jitter=0.5)
    a = list(p.delays(seed=11))
    b = list(p.delays(seed=11))
    c = list(p.delays(seed=12))
    assert a == b                          # replayable per seed
    assert a != c                          # decorrelated across seeds
    for i, d in enumerate(a):              # each delay within ±jitter of base
        base = min(0.1 * 2.0 ** i, 0.4)
        assert 0.5 * base <= d <= 1.5 * base


def test_retry_zero_jitter_keeps_geometric_stream_with_cap():
    p = RetryPolicy(attempts=4, backoff_s=0.1)
    assert list(p.delays()) == pytest.approx([0.1, 0.2, 0.4])
    capped = RetryPolicy(attempts=4, backoff_s=0.1, max_backoff_s=0.15)
    assert list(capped.delays()) == pytest.approx([0.1, 0.15, 0.15])


def test_retry_max_elapsed_window_reraises_last_error():
    calls = []

    def fn():
        calls.append(time.monotonic())
        raise ValueError("still down")

    p = RetryPolicy(attempts=None, backoff_s=0.01, mult=1.0,
                    max_elapsed_s=0.15, retry_on=(ValueError,))
    t0 = time.monotonic()
    with pytest.raises(ValueError, match="still down"):
        call_with_retry(fn, policy=p)
    assert len(calls) > 3                  # it really retried inside the window
    assert time.monotonic() - t0 < 2.0     # ...and gave up soon after it closed


def test_retry_unbounded_attempts_retries_past_small_counts():
    state = {"n": 0}

    def fn():
        state["n"] += 1
        if state["n"] < 20:
            raise OSError("flaky")
        return "ok"

    p = RetryPolicy(attempts=None, backoff_s=0.0, retry_on=(OSError,))
    assert call_with_retry(fn, policy=p) == "ok"
    assert state["n"] == 20


# ---------------------------------------------------------------------------
# wait_epoch: errors beat timeouts; shutdown fails fast
# ---------------------------------------------------------------------------
def test_wait_epoch_reraises_recorded_error_immediately():
    srv = RemoteEpisodeServer(MemorySampleStore(), 4, seed=3)
    try:
        srv._fail(TransportError("producers imploded"))
        t0 = time.monotonic()
        with pytest.raises(TransportError, match="imploded"):
            srv.wait_epoch(0, timeout_s=30.0)
        assert time.monotonic() - t0 < 1.0   # never waited out the timeout
    finally:
        srv.close()


def test_wait_epoch_error_set_while_waiting_wakes_promptly():
    srv = RemoteEpisodeServer(MemorySampleStore(), 4, seed=3)
    try:
        threading.Timer(0.2, srv._fail,
                        args=(TransportError("late death"),)).start()
        t0 = time.monotonic()
        with pytest.raises(TransportError, match="late death"):
            srv.wait_epoch(0, timeout_s=30.0)
        assert time.monotonic() - t0 < 5.0
    finally:
        srv.close()


def test_wait_epoch_fails_fast_after_kill():
    srv = RemoteEpisodeServer(MemorySampleStore(), 4, seed=3)
    srv.start()
    srv.kill()
    t0 = time.monotonic()
    with pytest.raises(TransportError, match="shut down"):
        srv.wait_epoch(0, timeout_s=30.0)
    assert time.monotonic() - t0 < 1.0


# ---------------------------------------------------------------------------
# HostHealth lease edges
# ---------------------------------------------------------------------------
def test_lease_boundary_exact_expiry_is_dead_and_reported():
    h = HostHealth(lease_s=10.0)
    h.beat("w0")
    with h._mu:
        h._last["w0"] = time.monotonic() - h.lease_s   # age == lease exactly
    # the boundary is closed on the dead side: alive uses strict <,
    # expired uses >= — the same instant can never be both
    assert not h.alive("w0") and not h.any_alive()
    assert h.expired() == ["w0"]
    with h._mu:
        h._last["w0"] = time.monotonic() - h.lease_s + 5.0   # well inside
    assert h.alive("w0") and h.expired() == []


def test_lease_resurrection_after_expiry_and_mark_dead():
    h = HostHealth(lease_s=10.0)
    h.beat("w0")
    with h._mu:
        h._last["w0"] = time.monotonic() - 60.0
    assert h.expired() == ["w0"]
    h.mark_dead("w0")
    assert h.expired() == []               # marked: not re-reported
    assert not h.alive("w0")
    h.beat("w0")                           # the host reconnected and beats
    assert h.alive("w0") and h.any_alive()
    assert h.expired() == []
    assert h.snapshot()["w0"]["alive"]
    # a second expiry cycle on the resurrected host behaves identically
    with h._mu:
        h._last["w0"] = time.monotonic() - 60.0
    assert h.expired() == ["w0"]


def test_lease_concurrent_beats_vs_expiry_sweep():
    """Reclaim-loop shape under load: beat threads hammer while a sweeper
    runs expired()/mark_dead/any_alive — no dict-mutation crashes, no host
    both beating and staying dead."""
    h = HostHealth(lease_s=0.02)
    stop = threading.Event()
    errors = []

    def beater(host):
        try:
            while not stop.is_set():
                h.beat(host)
        except Exception as e:             # noqa: BLE001 — the assertion
            errors.append(e)

    threads = [threading.Thread(target=beater, args=(f"w{i}",), daemon=True)
               for i in range(3)]
    for t in threads:
        t.start()
    t_end = time.monotonic() + 0.5
    while time.monotonic() < t_end:
        for host in h.expired():
            h.mark_dead(host)
        h.any_alive()
        h.describe()
        h.snapshot()
    stop.set()
    for t in threads:
        t.join(timeout=5.0)
    assert not errors
    h.beat("w0")                           # beats always win over mark_dead
    assert h.alive("w0")
    time.sleep(0.05)
    assert h.expired() != []               # and leases still lapse afterwards


# ---------------------------------------------------------------------------
# store scan: the recovery source
# ---------------------------------------------------------------------------
def test_accepted_episodes_memory_store_counts_resident_and_dropped():
    store = MemorySampleStore()
    pairs = np.arange(8, dtype=np.int32).reshape(4, 2)
    assert store.accepted_episodes(0) == []
    store.put(0, 0, pairs)
    store.put(0, 1, pairs)
    store.get(0, 0)
    store.drop(0, 0)                       # consumed: still accepted
    store.put(1, 0, pairs)
    assert store.accepted_episodes(0) == [0, 1]
    assert store.accepted_episodes(1) == [0]
    assert store.accepted_episodes(2) == []


def test_accepted_episodes_disk_store_survives_new_instance(tmp_path):
    pairs = np.arange(8, dtype=np.int32).reshape(4, 2)
    store = DiskSampleStore(str(tmp_path), keep=True)
    store.put(0, 0, pairs)
    store.put(0, 2, pairs)                 # a gap: episode 1 never landed
    # a FRESH instance — the post-coordinator-death view — sees the files
    reborn = DiskSampleStore(str(tmp_path), keep=True, fresh=False)
    assert reborn.accepted_episodes(0) == [0, 2]
    assert reborn.accepted_episodes(1) == []
    # keep=False drops delete their file but stay accepted in-process
    vol = DiskSampleStore(str(tmp_path / "vol"), keep=False)
    vol.put(0, 0, pairs)
    vol.get(0, 0)
    vol.drop(0, 0)
    assert vol.accepted_episodes(0) == [0]


# ---------------------------------------------------------------------------
# the tentpole: kill the server mid-epoch, recover, stay bitwise-identical
# ---------------------------------------------------------------------------
def test_coordinator_restart_mid_epoch_bitwise_and_exactly_once():
    g, wcfg = _graph(), _wcfg()
    ref = WalkEngine(g, wcfg)
    # depth=1 forces puts to trail consumption, so the kill below is
    # guaranteed to land mid-epoch (the last episode cannot have been put)
    store = MemorySampleStore(depth=1, stall_timeout_s=60.0)
    coord = RemoteWalkCoordinator(g, wcfg, store, num_producers=2,
                                  heartbeat_s=0.1, lease_s=5.0,
                                  mode="thread", ack_timeout_s=1.0,
                                  server_grace_s=20.0)
    coord.start()
    try:
        h = coord.epoch_walker()
        h.start_async(0)
        for ep in range(2):
            got = store.get(0, ep)
            np.testing.assert_array_equal(
                np.asarray(got).view(np.uint8),
                ref.episode_pairs(0, ep).view(np.uint8))
            store.drop(0, ep)

        takeover_s = coord.restart_server()
        assert takeover_s < 10.0

        for ep in range(2, wcfg.episodes):
            got = store.get(0, ep)
            np.testing.assert_array_equal(
                np.asarray(got).view(np.uint8),
                ref.episode_pairs(0, ep).view(np.uint8),
                err_msg=f"episode {ep} diverged across the takeover")
            store.drop(0, ep)
        h.join()                           # reads coord.server: the successor
        assert h.finished()

        fo = coord.failover_stats()
        assert fo["takeovers"] == 1
        # the consumed episodes (and possibly one the put thread raced in)
        # were recovered from the store, never re-produced
        k = fo["recovered_episodes"]
        assert 2 <= k < wcfg.episodes
        assert fo["producer_reconnects"] >= 1
        # exactly-once across the takeover: the successor applied precisely
        # the unique chunks of the episodes it re-produced — anything a
        # reattaching producer double-sent was counted dup and discarded
        unique = sum(len(list(ref.episode_chunk_stream(0, ep)))
                     for ep in range(k, wcfg.episodes))
        assert coord.server.assembler.chunks_applied == unique
        # carried aggregates stay monotonic: the merged view counts at
        # least every unique chunk of the whole epoch
        total = sum(len(list(ref.episode_chunk_stream(0, ep)))
                    for ep in range(wcfg.episodes))
        assert coord.transport_stats()["chunks_applied"] >= total
    finally:
        coord.close()


def test_coordinator_restart_between_epochs_recovers_full_epoch():
    """A takeover after an epoch fully landed must finish it from the scan
    alone (no re-production) and produce the NEXT epoch normally."""
    g, wcfg = _graph(), _wcfg()
    ref = WalkEngine(g, wcfg)
    store = MemorySampleStore(depth=wcfg.episodes, stall_timeout_s=60.0)
    coord = RemoteWalkCoordinator(g, wcfg, store, num_producers=1,
                                  heartbeat_s=0.1, lease_s=5.0,
                                  mode="thread", ack_timeout_s=1.0,
                                  server_grace_s=20.0)
    coord.start()
    try:
        h0 = coord.epoch_walker()
        h0.start_async(0)
        h0.join()                          # epoch 0 fully resident
        coord.restart_server()
        # resubmitting the finished epoch is idempotent; epoch 1 activates
        # with an empty scan and produces normally
        coord.server.submit_epoch(0)
        h1 = coord.epoch_walker()
        h1.start_async(1)
        for epoch in (0, 1):
            for ep in range(wcfg.episodes):
                got = store.get(epoch, ep)
                np.testing.assert_array_equal(
                    np.asarray(got).view(np.uint8),
                    ref.episode_pairs(epoch, ep).view(np.uint8))
                store.drop(epoch, ep)
        h1.join()
        assert coord.failover_stats()["takeovers"] == 1
    finally:
        coord.close()


# ---------------------------------------------------------------------------
# producer grace window
# ---------------------------------------------------------------------------
def _dead_address():
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    addr = s.getsockname()
    s.close()                              # nobody listens here any more
    return addr


def test_producer_outage_grace_window_expires_with_informative_error():
    prod = RemoteProducer(_dead_address(), "w0", _graph(), _wcfg(),
                          ack_timeout_s=0.3, connect_timeout_s=0.6,
                          server_grace_s=0.6)
    t0 = time.monotonic()
    with pytest.raises(TransportError, match="unreachable.*grace"):
        prod._connection()
    waited = time.monotonic() - t0
    assert 0.5 <= waited < 10.0            # gave up only once the window shut


def test_producer_rides_out_outage_shorter_than_grace():
    """Kill the server with no clean handshake; the producer's backoff loop
    must reattach to a successor on the same port inside the grace window
    and report the outage it rode out."""
    g, wcfg = _graph(), _wcfg()
    store = MemorySampleStore(depth=4)
    srv = RemoteEpisodeServer(store, wcfg.episodes, wcfg.seed, lease_s=10.0)
    srv.start()
    prod = RemoteProducer(srv.address, "w0", g, wcfg, ack_timeout_s=1.0,
                          server_grace_s=15.0)
    prod._connection()                     # attached to the first server
    port = srv.address[1]
    srv.kill()
    prod._drop_connection()
    succ = RemoteEpisodeServer(store, wcfg.episodes, wcfg.seed,
                               lease_s=10.0, port=port, recover=True)
    succ.start()
    try:
        conn = prod._connection()          # reattaches inside the grace
        assert isinstance(conn, FramedSocket)
        assert prod.reconnects == 1
        assert prod.outage_s > 0.0
    finally:
        succ.close()
        prod._drop_connection()


def test_producer_hello_timeout_counts_against_grace():
    """A server that accepts but never answers hello (half-dead coordinator)
    must burn the grace window, not hang forever."""
    lsock = socket.socket()
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(4)
    held = []
    stop = threading.Event()

    def hold():
        lsock.settimeout(0.1)
        while not stop.is_set():
            try:
                s, _ = lsock.accept()      # accept, say nothing
                held.append(s)
            except socket.timeout:
                continue
            except OSError:
                return

    t = threading.Thread(target=hold, daemon=True)
    t.start()
    prod = RemoteProducer(lsock.getsockname(), "w0", _graph(), _wcfg(),
                          ack_timeout_s=0.2, connect_timeout_s=0.7,
                          server_grace_s=0.7)
    try:
        with pytest.raises(TransportError, match="grace"):
            prod._connection()
    finally:
        stop.set()
        t.join(timeout=5.0)
        for s in held:
            s.close()
        lsock.close()


# ---------------------------------------------------------------------------
# launcher: --coordinator-resume end-to-end, bitwise vs uninterrupted run
# ---------------------------------------------------------------------------
_TRAIN_ARGS = ["--arch", "tencent-embedding", "--nodes", "240", "--dim", "16",
               "--epochs", "2", "--episodes", "3", "--subparts", "2",
               "--minibatch", "32", "--negatives", "4", "--neg-pool", "256",
               "--walk-workers", "2", "--seed", "3"]


@pytest.mark.slow
def test_coordinator_resume_training_is_bitwise_identical(tmp_path):
    """Kill a remote-walker training run mid-epoch, restart it with
    --resume --coordinator-resume against the surviving disk store: the
    recovering server skips every episode the store already accepted, and
    the final embeddings are bitwise-identical to an uninterrupted
    in-process run."""
    from repro.launch.train import main as train_main
    from repro.runtime import InjectedFault
    from repro.train.checkpoint import load_arrays

    ref_dir = str(tmp_path / "ref")
    chaos_dir = str(tmp_path / "chaos")
    train_main(_TRAIN_ARGS + ["--out-dir", ref_dir])

    rw = ["--remote-walkers", "1", "--heartbeat-s", "0.2", "--lease-s", "5",
          "--server-grace-s", "20", "--store", "disk", "--keep-samples"]
    with pytest.raises(InjectedFault):
        train_main(_TRAIN_ARGS + rw
                   + ["--out-dir", chaos_dir, "--ckpt-every", "1",
                      "--inject", "train.episode:crash:key=1/1"])
    assert not os.path.exists(os.path.join(chaos_dir, "embeddings_2.npz"))

    train_main(_TRAIN_ARGS + rw + ["--out-dir", chaos_dir,
                                   "--ckpt-every", "1",
                                   "--resume", "--coordinator-resume"])
    ref, _ = load_arrays(os.path.join(ref_dir, "embeddings_2.npz"))
    got, _ = load_arrays(os.path.join(chaos_dir, "embeddings_2.npz"))
    for key in ("vertex", "context"):
        assert ref[key].dtype == got[key].dtype
        np.testing.assert_array_equal(
            np.asarray(ref[key]).view(np.uint8),
            np.asarray(got[key]).view(np.uint8),
            err_msg=f"{key} table diverged across coordinator failover")


def test_coordinator_resume_flag_validation():
    from repro.launch.train import main as train_main

    with pytest.raises(SystemExit, match="remote-walkers"):
        train_main(_TRAIN_ARGS + ["--out-dir", "/tmp/x", "--resume",
                                  "--coordinator-resume"])
    with pytest.raises(SystemExit, match="resume"):
        train_main(_TRAIN_ARGS + ["--out-dir", "/tmp/x",
                                  "--remote-walkers", "1",
                                  "--coordinator-resume"])
