"""Fault-tolerant runtime: deterministic injection, retry, watchdogs,
crash-resume training, degraded serving.

Failure-path coverage the happy-path suites can't give: every fault here is
injected deterministically (``repro.runtime.faults``), so each scenario —
crashed chunk, torn episode file, mid-epoch kill, slow shard — replays
identically run after run, and the recovery invariants (bitwise-identical
retry/resume, surviving-shards exactness) are assertable, not statistical.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.graph import powerlaw_graph
from repro.runtime import (CorruptEpisodeError, Deadline, DeadlineExceeded,
                           FaultPlan, FaultSpec, InjectedFault, Overloaded,
                           RetryPolicy, StoreStalled, call_with_retry,
                           clear_plan, fault_point, inject, install_plan)
from repro.walk import MemorySampleStore, WalkConfig, WalkEngine
from repro.walk.store import DiskSampleStore


# ---------------------------------------------------------------------------
# fault plan
# ---------------------------------------------------------------------------
def test_fault_spec_parse():
    s = FaultSpec.parse("walk.chunk:crash:at=5")
    assert (s.site, s.kind, s.at, s.key) == ("walk.chunk", "crash", 5, None)
    s = FaultSpec.parse("serve.shard:delay:key=1:delay=0.5:times=inf")
    assert s.key == "1" and s.delay_s == 0.5 and s.times == float("inf")
    s = FaultSpec.parse("train.episode:crash:key=6/1")
    assert s.key == "6/1"
    with pytest.raises(ValueError):
        FaultSpec.parse("walk.chunk")            # no kind
    with pytest.raises(ValueError):
        FaultSpec.parse("walk.chunk:explode")    # unknown kind
    with pytest.raises(ValueError):
        FaultSpec.parse("walk.chunk:crash:frobnicate=1")


def test_fault_plan_fires_on_ordinal_exactly_once():
    plan = FaultPlan(["site.a:crash:at=2"])
    install_plan(plan)
    try:
        assert fault_point("site.a") is False     # ordinal 0
        assert fault_point("site.a") is False     # ordinal 1
        with pytest.raises(InjectedFault):
            fault_point("site.a")                 # ordinal 2: fires
        assert fault_point("site.a") is False     # spec is spent
        assert plan.count("site.a") == 4
        assert plan.fired == [("site.a", "crash", None)]
    finally:
        clear_plan()


def test_fault_plan_fires_on_key_and_corrupt_returns_true():
    with inject("disk.write:corrupt:key=0/2") as plan:
        assert fault_point("disk.write", (0, 0)) is False
        assert fault_point("disk.write", (0, 2)) is True
        assert fault_point("disk.write", (0, 2)) is False   # times=1: spent
        assert plan.fired == [("disk.write", "corrupt", (0, 2))]
    # context manager restored the empty registry
    assert fault_point("disk.write", (0, 2)) is False


def test_fault_plan_no_plan_is_noop():
    clear_plan()
    assert fault_point("anything", (1, 2, 3)) is False


def test_fault_plan_is_deterministic_across_replays():
    def run():
        log = []
        with inject("s:crash:at=1:times=2"):
            for i in range(6):
                try:
                    fault_point("s", (i,))
                    log.append("ok")
                except InjectedFault:
                    log.append("crash")
        return log

    assert run() == run() == ["ok", "crash", "ok", "ok", "ok", "ok"]


def test_fault_plan_delay_sleeps():
    with inject("s:delay:at=0:delay=0.15"):
        t0 = time.perf_counter()
        fault_point("s")
        assert time.perf_counter() - t0 >= 0.14


# ---------------------------------------------------------------------------
# retry
# ---------------------------------------------------------------------------
def test_call_with_retry_recovers_and_reraises():
    calls = []

    def flaky(n):
        calls.append(n)
        if len(calls) < 3:
            raise ValueError("transient")
        return n * 2

    assert call_with_retry(flaky, 21,
                           policy=RetryPolicy(attempts=3,
                                              backoff_s=0.001)) == 42
    assert len(calls) == 3

    def hopeless():
        raise ValueError("permanent")

    seen = []
    with pytest.raises(ValueError, match="permanent"):
        call_with_retry(hopeless,
                        policy=RetryPolicy(attempts=3, backoff_s=0.001),
                        on_retry=lambda a, e: seen.append(a))
    assert seen == [1, 2]      # no on_retry after the final failure


def test_retry_policy_backoff_schedule():
    p = RetryPolicy(attempts=4, backoff_s=0.1, mult=2.0)
    assert list(p.delays()) == pytest.approx([0.1, 0.2, 0.4])


# ---------------------------------------------------------------------------
# walk-chunk crash -> retry -> bitwise parity
# ---------------------------------------------------------------------------
def _drain(store, epoch, episodes):
    return [np.asarray(store.get(epoch, ep)) for ep in range(episodes)]


def test_walk_chunk_crash_retry_is_bitwise_identical():
    g = powerlaw_graph(400, 4, seed=7)
    cfg = WalkConfig(walk_length=8, window=3, episodes=3, seed=11,
                     chunk_size=64, workers=2, retry_backoff_s=0.001)

    ref_store = MemorySampleStore()
    WalkEngine(g, cfg, ref_store).run_epoch(0)
    ref = _drain(ref_store, 0, cfg.episodes)

    # crash the 4th and 9th chunk attempts: retry replays each chunk's
    # RNG stream from its (seed, epoch, episode, chunk) key
    with inject("walk.chunk:crash:at=3", "walk.chunk:crash:at=8") as plan:
        got_store = MemorySampleStore()
        WalkEngine(g, cfg, got_store).run_epoch(0)
        got = _drain(got_store, 0, cfg.episodes)
    assert [k for _, k, _ in plan.fired] == ["crash", "crash"]
    assert len(ref) == len(got)
    for r, o in zip(ref, got):
        np.testing.assert_array_equal(r, o)


def test_walk_retries_exhausted_fails_loudly():
    g = powerlaw_graph(100, 3, seed=1)
    cfg = WalkConfig(episodes=2, workers=1, retries=2, retry_backoff_s=0.001,
                     chunk_size=256)
    store = MemorySampleStore()
    eng = WalkEngine(g, cfg, store)
    # times=inf: the crash outlives every retry attempt
    with inject("walk.chunk:crash:key=0/0/0:times=inf"):
        eng.start_async(0)
        with pytest.raises(KeyError):
            store.get(0, 0)       # error path finishes the epoch -> KeyError
        with pytest.raises(InjectedFault):
            eng.join()


def test_episode_pairs_matches_streamed_output():
    g = powerlaw_graph(300, 4, seed=2)
    cfg = WalkConfig(episodes=2, seed=5, chunk_size=128)
    store = MemorySampleStore()
    eng = WalkEngine(g, cfg, store)
    eng.run_epoch(0)
    for ep in range(cfg.episodes):
        np.testing.assert_array_equal(np.asarray(store.get(0, ep)),
                                      eng.episode_pairs(0, ep))


# ---------------------------------------------------------------------------
# watchdogs: no wait loop blocks forever
# ---------------------------------------------------------------------------
def test_get_fails_fast_when_producer_is_dead():
    store = MemorySampleStore(stall_timeout_s=30.0)
    store.set_producer(lambda: False)       # walker is provably gone
    t0 = time.perf_counter()
    with pytest.raises(StoreStalled) as ei:
        store.get(0, 0)
    assert time.perf_counter() - t0 < 5.0   # liveness, not the deadline
    assert ei.value.producer_alive is False
    assert ei.value.op == "get" and ei.value.key == (0, 0)
    assert "DEAD" in str(ei.value)


def test_get_stall_deadline_with_unknown_producer():
    store = MemorySampleStore(stall_timeout_s=0.4)
    t0 = time.perf_counter()
    with pytest.raises(StoreStalled) as ei:
        store.get(0, 0)
    waited = time.perf_counter() - t0
    assert 0.3 <= waited < 5.0
    assert ei.value.producer_alive is None


def test_put_backpressure_stall_names_resident_episodes():
    store = MemorySampleStore(depth=1, stall_timeout_s=0.4)
    pairs = np.zeros((4, 2), np.int32)
    store.put(0, 0, pairs)
    with pytest.raises(StoreStalled) as ei:
        store.put(0, 1, pairs)              # nobody is draining
    assert ei.value.op == "put"
    assert (0, 0) in ei.value.resident


def test_progress_resets_the_stall_deadline():
    store = MemorySampleStore(depth=1, stall_timeout_s=0.8)
    pairs = np.zeros((4, 2), np.int32)
    store.put(0, 0, pairs)

    def slow_consumer():
        for ep in range(3):
            time.sleep(0.5)                 # slower than poll, under deadline
            store.drop(0, ep)

    t = threading.Thread(target=slow_consumer, daemon=True)
    t.start()
    for ep in range(1, 4):                  # total wall > deadline, but each
        store.put(0, ep, pairs)             # wait sees progress and resets
    t.join()


def test_disk_get_fails_fast_when_producer_is_dead(tmp_path):
    store = DiskSampleStore(str(tmp_path), stall_timeout_s=30.0)
    store.set_producer(lambda: False)
    t0 = time.perf_counter()
    with pytest.raises(StoreStalled):
        store.get(0, 0)
    assert time.perf_counter() - t0 < 5.0


def test_dead_async_walker_fails_consumer_via_liveness():
    g = powerlaw_graph(100, 3, seed=1)
    store = MemorySampleStore(stall_timeout_s=30.0)
    eng = WalkEngine(g, WalkConfig(episodes=2), store)

    # a walker that dies WITHOUT the error path's finish_epoch (simulating
    # a hard kill): run_epoch raises before any cleanup
    def hard_die(epoch):
        raise RuntimeError("killed")

    eng.run_epoch = hard_die
    eng._thread = threading.Thread(target=lambda: None, daemon=True)
    eng._thread.start()
    eng._thread.join()                      # thread object exists and is dead
    store.set_producer(eng.alive)
    with pytest.raises(StoreStalled) as ei:
        store.get(0, 0)
    assert ei.value.producer_alive is False


# ---------------------------------------------------------------------------
# disk integrity: torn writes detected + recovered
# ---------------------------------------------------------------------------
def test_disk_corrupt_write_detected(tmp_path):
    store = DiskSampleStore(str(tmp_path))
    pairs = np.arange(40, dtype=np.int32).reshape(-1, 2)
    with inject("disk.write:corrupt:at=0"):
        store.put(0, 0, pairs)
    with pytest.raises(CorruptEpisodeError) as ei:
        store.get(0, 0, block=False)
    assert ei.value.key == (0, 0)
    # the repair path: rewrite republishes checksummed content
    store.rewrite(0, 0, pairs)
    np.testing.assert_array_equal(np.asarray(store.get(0, 0)), pairs)


def test_disk_bitflip_detected_by_checksum(tmp_path):
    store = DiskSampleStore(str(tmp_path))
    pairs = np.arange(40, dtype=np.int32).reshape(-1, 2)
    store.put(0, 0, pairs)
    path = store._path(0, 0)
    blob = bytearray(open(path, "rb").read())
    blob[-1] ^= 0xFF                        # same length, different bytes
    with open(path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(CorruptEpisodeError, match="checksum"):
        store.get(0, 0, block=False)


def test_pipeline_rewalks_corrupt_episode_bitwise(tmp_path):
    from repro.core import EpisodePipeline
    from repro.core.partition import NodePartition

    g = powerlaw_graph(300, 4, seed=3)
    cfg = WalkConfig(episodes=2, seed=9, chunk_size=128)
    store = DiskSampleStore(str(tmp_path))
    eng = WalkEngine(g, cfg, store)
    with inject("disk.write:corrupt:key=0/1"):
        eng.run_epoch(0)

    part = NodePartition(g.num_nodes, dims=(1,), subparts=2)
    rewalker = WalkEngine(g, cfg, store)    # never started: pure regenerator
    pipe = EpisodePipeline(store, part, pad_multiple=8,
                           rewalk=rewalker.episode_pairs)
    try:
        ref = EpisodePipeline(store, part, pad_multiple=8)
        clean = ref._get_pairs(0, 0)        # episode 0 was written clean
        eb0 = pipe.get(0, 0)
        eb1 = pipe.get(0, 1)                # corrupt on disk: re-walked
        assert pipe.recovered == [(0, 1)]
        assert eb1.blocks is not None
        ref.close()
    finally:
        pipe.close()
    # the repair rewrote the file: a fresh reader now gets valid content,
    # bitwise equal to the deterministic replay
    np.testing.assert_array_equal(np.asarray(store.get(0, 1)),
                                  rewalker.episode_pairs(0, 1))
    del clean, eb0


def test_disk_drop_removes_checksum_sidecar(tmp_path):
    store = DiskSampleStore(str(tmp_path), keep=False)
    store.put(0, 0, np.zeros((4, 2), np.int32))
    assert os.path.exists(store._path(0, 0) + ".crc")
    store.drop(0, 0)
    assert not os.path.exists(store._path(0, 0))
    assert not os.path.exists(store._path(0, 0) + ".crc")


def test_disk_fresh_clears_stale_checksums(tmp_path):
    a = DiskSampleStore(str(tmp_path))
    a.put(0, 0, np.zeros((4, 2), np.int32))
    a.finish_epoch(0)
    b = DiskSampleStore(str(tmp_path), fresh=True)
    assert not any(f.endswith((".npy", ".crc", ".done"))
                   for f in os.listdir(str(tmp_path)))
    del b


def test_disk_publish_crash_between_renames_stays_detectable(tmp_path):
    """Regression for the ``_publish`` rename ordering: a process dying in
    the window between the sidecar rename and the payload rename must leave
    the store SAFE — either no payload at all (KeyError, nothing to read)
    or a stale payload that fails the fresh sidecar's checksum
    (CorruptEpisodeError, retriable) — never a silently readable torn
    episode. Covers both the ``put`` and the ``rewrite`` repair path."""
    store = DiskSampleStore(str(tmp_path))
    pairs = np.arange(64, dtype=np.int32).reshape(32, 2)

    # crash mid-publish during put: sidecar visible, payload never renamed
    with inject("disk.write:crash:key=0/0/publish") as plan:
        with pytest.raises(InjectedFault):
            store.put(0, 0, pairs)
    assert plan.fired
    assert os.path.exists(store._path(0, 0) + ".crc")
    assert not os.path.exists(store._path(0, 0))
    with pytest.raises(KeyError):
        store.get(0, 0, block=False)

    # recovery: a plain rewrite republishes payload + sidecar atomically
    store.rewrite(0, 0, pairs)
    np.testing.assert_array_equal(np.asarray(store.get(0, 0)), pairs)

    # now the harder orientation: corrupt the visible payload, then crash a
    # repair rewrite in the same window — the fresh sidecar lands but the
    # stale corrupt payload survives. Sidecar-first ordering means the
    # mismatch is still DETECTED (fail loud, retriable), not served.
    with open(store._path(0, 0), "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(CorruptEpisodeError):
        store.get(0, 0)
    with inject("disk.write:crash:key=0/0/publish"):
        with pytest.raises(InjectedFault):
            store.rewrite(0, 0, pairs)
    with pytest.raises(CorruptEpisodeError):
        store.get(0, 0)                    # still corrupt, still detected
    store.rewrite(0, 0, pairs)             # completed repair really repairs
    np.testing.assert_array_equal(np.asarray(store.get(0, 0)), pairs)


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------
def test_checkpoint_crc_roundtrip_and_tamper(tmp_path):
    import ml_dtypes

    from repro.train.checkpoint import (CheckpointCorrupt, load_arrays,
                                        save_checkpoint)

    path = str(tmp_path / "ck.npz")
    vert = np.arange(64, dtype=np.float32).reshape(8, 8).astype(
        ml_dtypes.bfloat16)
    ctx = np.ones((8, 8), np.float32)
    save_checkpoint(path, {"vertex": vert, "context": ctx}, step=3,
                    extra={"__cursor__": np.asarray([1, 2], np.int64)})
    data, step = load_arrays(path)
    assert step == 3
    assert data["vertex"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(data["vertex"], vert)    # bitwise
    np.testing.assert_array_equal(data["__cursor__"], [1, 2])

    # tamper with one table without refreshing its checksum
    raw = dict(np.load(path))
    bad = raw["context"].copy()
    bad[0, 0] += 1.0
    raw["context"] = bad
    tampered = str(tmp_path / "bad.npz")
    np.savez(tampered, **raw)
    with pytest.raises(CheckpointCorrupt, match="checksum"):
        load_arrays(tampered)
    # a dropped key is a manifest failure
    raw2 = {k: v for k, v in dict(np.load(path)).items() if k != "context"}
    short = str(tmp_path / "short.npz")
    np.savez(short, **raw2)
    with pytest.raises(CheckpointCorrupt, match="manifest"):
        load_arrays(short)
    # truncated container fails loudly too
    with open(path, "rb") as f:
        head = f.read(100)
    trunc = str(tmp_path / "trunc.npz")
    with open(trunc, "wb") as f:
        f.write(head)
    with pytest.raises(CheckpointCorrupt):
        load_arrays(trunc)


# ---------------------------------------------------------------------------
# crash-resume training: kill mid-epoch, resume, bitwise-identical result
# ---------------------------------------------------------------------------
_TRAIN_ARGS = ["--arch", "tencent-embedding", "--nodes", "240", "--dim", "16",
               "--epochs", "2", "--episodes", "3", "--subparts", "2",
               "--minibatch", "32", "--negatives", "4", "--neg-pool", "256",
               "--walk-workers", "2", "--seed", "3"]


def test_crash_resume_training_is_bitwise_identical(tmp_path):
    from repro.launch.train import main as train_main
    from repro.train.checkpoint import load_arrays

    ref_dir = str(tmp_path / "ref")
    chaos_dir = str(tmp_path / "chaos")
    train_main(_TRAIN_ARGS + ["--out-dir", ref_dir])

    # the run dies right before training episode (1, 1) — mid-epoch, with a
    # resume checkpoint written after every episode
    with pytest.raises(InjectedFault):
        train_main(_TRAIN_ARGS + ["--out-dir", chaos_dir, "--ckpt-every", "1",
                                  "--inject", "train.episode:crash:key=1/1"])
    cur, _ = load_arrays(os.path.join(chaos_dir, "resume.npz"))
    assert cur["__cursor__"].tolist() == [1, 1]
    assert not os.path.exists(os.path.join(chaos_dir, "embeddings_2.npz"))

    train_main(_TRAIN_ARGS + ["--out-dir", chaos_dir, "--ckpt-every", "1",
                              "--resume"])
    ref, _ = load_arrays(os.path.join(ref_dir, "embeddings_2.npz"))
    got, _ = load_arrays(os.path.join(chaos_dir, "embeddings_2.npz"))
    for key in ("vertex", "context"):
        assert ref[key].dtype == got[key].dtype
        np.testing.assert_array_equal(
            np.asarray(ref[key]).view(np.uint8),
            np.asarray(got[key]).view(np.uint8),
            err_msg=f"{key} table diverged after crash-resume")


def test_walker_crash_mid_pipeline_resume(tmp_path):
    """Chunk crashes under retry + a later hard kill: the retried stream is
    worker-count-invariant and the resumed run still converges bitwise."""
    from repro.launch.train import main as train_main
    from repro.train.checkpoint import load_arrays

    ref_dir = str(tmp_path / "ref")
    chaos_dir = str(tmp_path / "chaos")
    train_main(_TRAIN_ARGS + ["--out-dir", ref_dir])
    with pytest.raises(InjectedFault):
        train_main(_TRAIN_ARGS + ["--out-dir", chaos_dir, "--ckpt-every", "2",
                                  "--inject", "walk.chunk:crash:at=2",
                                  "--inject", "train.episode:crash:key=1/2"])
    train_main(_TRAIN_ARGS + ["--out-dir", chaos_dir, "--ckpt-every", "2",
                              "--resume"])
    ref, _ = load_arrays(os.path.join(ref_dir, "embeddings_2.npz"))
    got, _ = load_arrays(os.path.join(chaos_dir, "embeddings_2.npz"))
    np.testing.assert_array_equal(np.asarray(ref["vertex"]).view(np.uint8),
                                  np.asarray(got["vertex"]).view(np.uint8))


# ---------------------------------------------------------------------------
# degraded serving
# ---------------------------------------------------------------------------
def _mk_store(n=60, d=16, shards=3, **kw):
    import jax

    from repro.embed_serve import ShardedEmbeddingStore

    rng = np.random.default_rng(0)
    table = rng.normal(size=(n, d)).astype(np.float32)
    dev = jax.devices()[0]
    return ShardedEmbeddingStore.from_array(table, devices=[dev] * shards,
                                            **kw)


def test_degraded_topk_matches_surviving_shards_oracle():
    from repro.embed_serve import recall_at_k

    store = _mk_store()
    q = np.random.default_rng(1).normal(size=(8, 16)).astype(np.float32)
    k = 5

    # healthy timed path == healthy fast path == full oracle
    gv, gi, meta = store.topk(q, k, impl="xla", shard_timeout_s=10.0,
                              return_meta=True)
    assert not meta.degraded and meta.failed_shards == ()
    fv, fi = store.topk(q, k, impl="xla")
    np.testing.assert_array_equal(gi, fi)

    # shard 1 sleeps past the deadline on every scan
    with inject("serve.shard:delay:key=1:delay=2.0:times=inf"):
        gv, gi, meta = store.topk(q, k, impl="xla", shard_timeout_s=0.3,
                                  return_meta=True)
    assert meta.degraded and meta.failed_shards == (1,)
    ov, oi = store.oracle_topk(q, k, exclude_shards=(1,))
    recall = recall_at_k(gi, oi, got_vals=store.score_ids(q, gi),
                         oracle_vals=ov)
    assert recall == 1.0
    # degraded answers must NOT contain the failed shard's rows
    rows = store.part.padded_rows_per_shard
    assert not np.any((gi >= rows) & (gi < 2 * rows))


def test_degraded_topk_crashed_shard_is_excluded():
    store = _mk_store()
    q = np.random.default_rng(2).normal(size=(4, 16)).astype(np.float32)
    with inject("serve.shard:crash:key=2:times=inf"):
        _, gi, meta = store.topk(q, 5, impl="xla", shard_timeout_s=5.0,
                                 return_meta=True)
    assert meta.failed_shards == (2,)
    ov, oi = store.oracle_topk(q, 5, exclude_shards=(2,))
    from repro.embed_serve import recall_at_k
    assert recall_at_k(gi, oi, got_vals=store.score_ids(q, gi),
                       oracle_vals=ov) == 1.0


def test_all_shards_failed_raises():
    store = _mk_store()
    q = np.zeros((2, 16), np.float32)
    with inject(*[f"serve.shard:crash:key={s}:times=inf" for s in range(3)]):
        with pytest.raises(RuntimeError, match="all .* shard"):
            store.topk(q, 5, impl="xla", shard_timeout_s=5.0)


def test_store_default_shard_timeout_applies():
    store = _mk_store(shard_timeout_s=0.3)
    q = np.zeros((2, 16), np.float32)
    store.topk(q, 5, impl="xla", shard_timeout_s=None)   # compile warmup
    with inject("serve.shard:delay:key=0:delay=2.0:times=inf"):
        _, _, meta = store.topk(q, 5, impl="xla", return_meta=True)
    assert meta.degraded and meta.failed_shards == (0,)


# ---------------------------------------------------------------------------
# batcher: deadlines + shedding
# ---------------------------------------------------------------------------
def test_batcher_expires_requests_past_deadline():
    from repro.embed_serve import MicroBatcher

    def slow_serve(q):
        time.sleep(0.25)
        return np.zeros((q.shape[0], 3), np.float32), \
            np.zeros((q.shape[0], 3), np.int32)

    with MicroBatcher(slow_serve, dim=4, max_batch=1, window_ms=0.1,
                      pad_multiple=1, deadline_ms=60.0) as b:
        futs = [b.submit(np.zeros(4, np.float32)) for _ in range(5)]
        outcomes = []
        for f in futs:
            try:
                f.result(timeout=10.0)      # nothing hangs past its deadline
                outcomes.append("served")
            except DeadlineExceeded:
                outcomes.append("expired")
    assert outcomes[0] == "served"
    assert "expired" in outcomes            # the tail waited > 60ms queued
    assert b.stats.expired == outcomes.count("expired")


def test_batcher_sheds_on_full_queue():
    from repro.embed_serve import MicroBatcher

    release = threading.Event()

    def gated_serve(q):
        release.wait(5.0)
        return np.zeros((q.shape[0], 1), np.float32), \
            np.zeros((q.shape[0], 1), np.int32)

    b = MicroBatcher(gated_serve, dim=2, max_batch=1, window_ms=0.1,
                     pad_multiple=1, queue_cap=1, shed_on_full=True)
    try:
        shed = served = 0
        for _ in range(20):
            try:
                b.submit(np.zeros(2, np.float32))
                served += 1
            except Overloaded:
                shed += 1
        assert shed > 0                     # admission control actually shed
        assert b.stats.shed == shed
    finally:
        release.set()
        b.close()


def test_batcher_attaches_degraded_meta():
    from repro.embed_serve import MicroBatcher, TopKMeta

    meta = TopKMeta(degraded=True, failed_shards=(0,), timeout_s=0.1)

    def serve(q):
        return (np.zeros((q.shape[0], 2), np.float32),
                np.zeros((q.shape[0], 2), np.int32), meta)

    with MicroBatcher(serve, dim=2, max_batch=4, window_ms=1.0,
                      pad_multiple=1) as b:
        out = b.submit(np.zeros(2, np.float32)).result(timeout=10.0)
    assert len(out) == 3 and out[2] is meta
    assert b.stats.degraded == 1


def test_batcher_stats_hammer_totals_are_consistent():
    """Regression for the stats race: ``shed`` is bumped by submitter
    threads while the worker bumps the rest — all writes now take the stats
    lock, so under a multi-thread hammer every submitted request shows up in
    EXACTLY one counter outcome and the snapshot totals add up."""
    from repro.embed_serve import MicroBatcher

    def serve(q):
        time.sleep(0.0005)
        return (np.zeros((q.shape[0], 2), np.float32),
                np.zeros((q.shape[0], 2), np.int32))

    b = MicroBatcher(serve, dim=4, max_batch=8, window_ms=0.5,
                     pad_multiple=1, queue_cap=8, shed_on_full=True,
                     deadline_ms=200.0)
    N, THREADS = 150, 4
    outcomes, mu = [], threading.Lock()

    def pound():
        served = shed = expired = 0
        for _ in range(N):
            try:
                fut = b.submit(np.ones(4, np.float32))
            except Overloaded:
                shed += 1
                continue
            try:
                fut.result(timeout=30.0)
                served += 1
            except DeadlineExceeded:
                expired += 1
        with mu:
            outcomes.append((served, shed, expired))

    threads = [threading.Thread(target=pound) for _ in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b.close()
    served = sum(o[0] for o in outcomes)
    shed = sum(o[1] for o in outcomes)
    expired = sum(o[2] for o in outcomes)
    assert served + shed + expired == N * THREADS
    st = b.stats_snapshot()
    assert st.shed == shed
    assert st.expired == expired
    assert st.requests == served            # each served counted exactly once
    assert st.batches > 0 and st.mean_batch >= 1.0


# ---------------------------------------------------------------------------
# Deadline unit behaviour
# ---------------------------------------------------------------------------
def test_deadline_wait_slice_is_bounded():
    dl = Deadline(10.0, op="get", key=(0, 0))
    assert 0.0 < dl.wait_s() <= 0.25
    dl2 = Deadline(None, op="get", key=(0, 0))
    assert dl2.wait_s() == 0.25


def test_deadline_version_change_resets_clock():
    dl = Deadline(0.2, op="get", key=(0, 0))
    dl.check(0)
    time.sleep(0.15)
    dl.check(1)                              # progress: clock resets
    time.sleep(0.15)
    dl.check(2)
    time.sleep(0.25)
    with pytest.raises(StoreStalled):
        dl.check(2)                          # no progress past the deadline
