#!/usr/bin/env python
"""Schema gate for the telemetry artifacts (--metrics-dir / --trace).

Validates what the CI telemetry leg uploads, so a refactor that silently
stops emitting a stage's spans — or breaks the metrics.jsonl schema that
downstream dashboards parse — fails the build instead of shipping a blind
observability layer:

* ``--metrics FILE``  — every line of metrics.jsonl is a JSON registry
  snapshot with ``ts``/``elapsed_s``/``counters``/``gauges``/
  ``histograms``/``sources``, and every histogram summary carries
  ``count``/``sum``/``min``/``max``/``mean``/``p50``/``p95``/``p99``.
* ``--summary FILE``  — the final metrics_summary.json parses and carries
  ``lines_written``.
* ``--trace FILE``    — Chrome trace-event JSON: ``traceEvents`` is a
  list, every event's ``tid`` maps to a ``thread_name`` metadata event
  (Perfetto renders unnamed tids as garbage lanes), and every track named
  in ``--require-tracks`` has at least one complete ("X") span — matched
  by exact track name or ``name:*`` dynamic-lane prefix (walk workers,
  producer hosts).

Exit 0 with a one-line summary per artifact; exit 1 naming the first
violation.
"""
from __future__ import annotations

import argparse
import json
import sys

_SNAP_KEYS = {"ts", "elapsed_s", "counters", "gauges", "histograms",
              "sources"}
_HIST_KEYS = {"count", "sum", "min", "max", "mean", "p50", "p95", "p99"}


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def check_snapshot(snap: dict, where: str) -> None:
    missing = _SNAP_KEYS - set(snap)
    if missing:
        fail(f"{where}: snapshot missing keys {sorted(missing)}")
    for name, c in snap["counters"].items():
        if not isinstance(c, int) or c < 0:
            fail(f"{where}: counter {name!r} is {c!r}, want non-negative int")
    for name, h in snap["histograms"].items():
        missing = _HIST_KEYS - set(h)
        if missing:
            fail(f"{where}: histogram {name!r} missing {sorted(missing)}")
        if h["count"] > 0 and h["p50"] is None:
            fail(f"{where}: histogram {name!r} has count {h['count']} "
                 f"but no percentiles")


def check_metrics(path: str) -> None:
    with open(path) as f:
        lines = [line for line in f if line.strip()]
    if not lines:
        fail(f"{path}: empty — the writer never flushed a snapshot")
    for i, line in enumerate(lines):
        try:
            snap = json.loads(line)
        except json.JSONDecodeError as e:
            fail(f"{path}:{i + 1}: not JSON ({e})")
        check_snapshot(snap, f"{path}:{i + 1}")
    last = json.loads(lines[-1])
    print(f"ok: {path}: {len(lines)} snapshots, "
          f"{len(last['counters'])} counters, "
          f"{len(last['histograms'])} histograms, "
          f"{len(last['sources'])} sources over {last['elapsed_s']:.1f}s")


def check_summary(path: str) -> None:
    with open(path) as f:
        summary = json.load(f)
    check_snapshot(summary, path)
    if "lines_written" not in summary:
        fail(f"{path}: missing lines_written")
    if "sink_error" in summary:
        fail(f"{path}: sink reported an error: {summary['sink_error']}")
    print(f"ok: {path}: final summary, "
          f"{summary['lines_written']} jsonl lines written")


def check_trace(path: str, require_tracks: list[str]) -> None:
    with open(path) as f:
        trace = json.load(f)
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    names = {e["tid"]: e["args"]["name"] for e in events
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    spans_per_track: dict[str, int] = {}
    for e in events:
        ph = e.get("ph")
        if ph == "M":
            continue
        if e["tid"] not in names:
            fail(f"{path}: event {e.get('name')!r} on unnamed tid "
                 f"{e['tid']} (no thread_name metadata)")
        if ph == "X":
            if e.get("ts", -1) < 0 or e.get("dur", -1) < 0:
                fail(f"{path}: span {e['name']!r} has bad ts/dur: {e}")
            track = names[e["tid"]]
            spans_per_track[track] = spans_per_track.get(track, 0) + 1
    for want in require_tracks:
        hits = sum(n for track, n in spans_per_track.items()
                   if track == want or track.startswith(want + ":"))
        if hits == 0:
            fail(f"{path}: no complete span on required track {want!r} "
                 f"(tracks seen: {sorted(spans_per_track)})")
    dropped = trace.get("otherData", {}).get("dropped_events", 0)
    print(f"ok: {path}: {sum(spans_per_track.values())} spans over "
          f"{len(spans_per_track)} tracks "
          f"({', '.join(sorted(spans_per_track))}), {dropped} dropped")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics", help="metrics.jsonl to validate")
    ap.add_argument("--summary", help="metrics_summary.json to validate")
    ap.add_argument("--trace", help="Chrome trace-event JSON to validate")
    ap.add_argument("--require-tracks", default="",
                    help="comma-separated track names that must each have "
                         "at least one span (name or name:* dynamic lane)")
    args = ap.parse_args(argv)
    if not (args.metrics or args.summary or args.trace):
        ap.error("nothing to check: pass --metrics, --summary, or --trace")
    if args.metrics:
        check_metrics(args.metrics)
    if args.summary:
        check_summary(args.summary)
    if args.trace:
        tracks = [t for t in args.require_tracks.split(",") if t]
        check_trace(args.trace, tracks)


if __name__ == "__main__":
    main()
